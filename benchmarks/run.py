"""Benchmark entry point: one function per paper table.  Prints
``name,value,unit`` CSV rows (per-query us, total-us, bytes, counts).

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --quick    # CI-speed subset
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    from . import paper_tables as pt
    from . import kernels_bench as kb

    rows = []
    # Exp-1: query time (paper Fig. 2)
    for ds in (["NC-s", "BK-s"] if args.quick else
               ["NC-s", "BK-s", "PS-s", "EE-s"]):
        rows += pt.exp1_query_time(ds, n_q=300 if args.quick else 1000,
                                   include_online=not args.quick or ds == "NC-s")
    # Exp-2: indexing time (Table IV, time)
    for ds in (["NC-s"] if args.quick else ["NC-s", "BK-s", "PS-s"]):
        rows += pt.exp2_indexing_time(ds, include_basic=(ds == "NC-s"))
    # Exp-3: space (Table IV, space)
    for ds in (["BK-s"] if args.quick else ["NC-s", "BK-s", "EE-s"]):
        rows += pt.exp3_space(ds)
    # Exp-4: scalability (Fig. 3)
    if not args.quick:
        rows += pt.exp4_scalability("WA-s")
    # Exp-5: case study (Fig. 4)
    rows += pt.exp5_case_study()
    # unified engine API: every registered backend built, benchmarked and
    # cross-validated through the repro.api facade
    rows += pt.engine_suite("ENG-s", n_q=64 if args.quick else 128)
    # sharded backend vs single-device closure, both collective schedules
    # (multi-device numbers come from benchmarks/bench_sharded.py)
    rows += pt.sharded_suite("ENG-s", n_q=64 if args.quick else 128)
    # kernel/closure layer
    rows += kb.closure_bench(m=256 if args.quick else 512)

    print("name,value,unit")
    for name, val, unit in rows:
        print(f"{name},{float(val):.3f},{unit}")


if __name__ == "__main__":
    main()
