"""§Roofline: three-term analysis per (arch × shape × mesh) cell.

    compute term    = FLOPs_executed / (chips × 197e12 bf16 FLOP/s)
    memory term     = HBM_bytes      / (chips × 819e9 B/s)
    collective term = coll_bytes_per_device / 50e9 B/s/link

FLOPs and HBM bytes are *analytic* (formulas below, per executed step,
global): XLA's cost_analysis counts scan bodies once (verified — see
EXPERIMENTS.md §Dry-run), so compiled counters undercount by the
microbatch × layer trip product; the collective term is *measured* from
the compiled HLO with loop-aware trip multiplication
(repro.launch.hlo_analysis), i.e. the one number that needs the dry-run.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); the ratio
MODEL_FLOPS / FLOPs_executed exposes remat/redundancy waste (≈0.75 with
full remat: fwd+bwd+re-fwd = 8·N·D).

roofline_fraction = [MODEL_FLOPS/(chips·peak)] / max(terms): the MFU
upper bound the compiled program permits — the score §Perf hillclimbs.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs import get_config, ALIASES
from repro.launch.shapes import SHAPES
from repro.models.common import ArchConfig

__all__ = ["analytic_cell_model", "roofline_row", "roofline_table",
           "PEAK_FLOPS", "HBM_BW", "LINK_BW", "CHIPS"]

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e)
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / ICI link


def _attn_flops_fwd(cfg: ArchConfig, b: int, s: int) -> float:
    """Self-attention score+value contractions, causal (×1/2)."""
    if cfg.family == "ssm":
        # selective scan: ~6 flops per (token, d_inner, d_state) + conv
        return b * s * cfg.d_inner * cfg.ssm_state * 6.0 * cfg.n_layers
    w = min(cfg.window, s) if cfg.window else s
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if pat[i % len(pat)] == "attn")
        n_rec = cfg.n_layers - n_attn
        attn = 4 * n_attn * b * s * w * cfg.n_heads * cfg.hd * 0.5
        rec = n_rec * b * s * cfg.drnn * 12.0       # gates + scan
        return attn + rec
    layers = cfg.n_layers + (cfg.enc_layers if cfg.family == "encdec" else 0)
    causal = 0.5 if cfg.family != "encdec" else 0.75   # enc is bidirectional
    return 4 * layers * b * s * w * cfg.n_heads * cfg.hd * causal


def analytic_cell_model(cfg: ArchConfig, shape) -> Dict[str, float]:
    """Per executed step, global (all chips)."""
    n_act = cfg.n_active_params()
    n_emb_in = cfg.vocab * cfg.d_model        # input embedding (gather, ~0 flop)
    n_mat = max(n_act - n_emb_in, 1)          # matmul-visible params
    b, s = shape.global_batch, shape.seq_len
    kv_bytes_tok = (2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 2
                    if cfg.family not in ("ssm", "hybrid") else
                    4 * cfg.d_inner * (cfg.ssm_state + cfg.d_conv)
                    if cfg.family == "ssm" else 4 * cfg.drnn * 8)

    if shape.kind == "train":
        t = b * s
        fwd = 2 * n_mat * t + _attn_flops_fwd(cfg, b, s)
        factor = 4.0 if cfg.remat else 3.0     # fwd+bwd(2x)+refwd(1x)
        flops = factor * fwd
        model_flops = 6.0 * n_mat * t
        # HBM: weights re-read per pass per microbatch (bf16) + optimizer
        # f32 m/v read+write + activation boundary traffic
        p_bytes = cfg.n_params() * 2
        passes = 3 * cfg.microbatch
        act = 12 * t * cfg.d_model * cfg.n_layers * 2
        hbm = passes * p_bytes + 16 * cfg.n_params() + act
    elif shape.kind == "prefill":
        t = b * s
        flops = 2 * n_mat * t + _attn_flops_fwd(cfg, b, s)
        model_flops = 2.0 * n_mat * t
        hbm = cfg.n_params() * 2 + 10 * t * cfg.d_model * cfg.n_layers * 2
    else:  # decode: one token against an s-long cache
        w = min(cfg.window, s) if cfg.window else s
        if cfg.family == "ssm":
            attn_read = b * 4 * cfg.d_inner * cfg.ssm_state
            attn_flops = b * cfg.d_inner * cfg.ssm_state * 6.0 * cfg.n_layers
        elif cfg.family == "hybrid":
            attn_read = b * 9 * kv_bytes_tok
            attn_flops = 4 * b * w * cfg.n_heads * cfg.hd * (cfg.n_layers // 3)
        else:
            attn_read = b * s * kv_bytes_tok
            attn_flops = 4 * cfg.n_layers * b * s * cfg.n_kv_heads * cfg.hd
        flops = 2 * n_mat * b + attn_flops
        model_flops = 2.0 * n_mat * b
        hbm = cfg.n_params() * 2 + attn_read
    return dict(flops=flops, hbm_bytes=hbm, model_flops=model_flops)


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    a = analytic_cell_model(cfg, shape)
    t_compute = a["flops"] / (chips * PEAK_FLOPS)
    t_memory = a["hbm_bytes"] / (chips * HBM_BW)
    coll_dev = rec.get("collective_executed", rec["collective"])["total_bytes"]
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values())
    mfu_bound = (a["model_flops"] / (chips * PEAK_FLOPS)) / t_bound \
        if t_bound > 0 else 0.0
    return dict(
        arch=rec["arch"], shape=rec["shape"],
        mesh="2x16x16" if rec["multi_pod"] else "16x16", chips=chips,
        t_compute_s=t_compute, t_memory_s=t_memory, t_collective_s=t_coll,
        dominant=dominant, model_flops=a["model_flops"],
        exec_flops=a["flops"],
        useful_ratio=a["model_flops"] / a["flops"],
        mfu_bound=mfu_bound,
        hlo_flops_per_dev=rec.get("flops", 0.0),
        coll_bytes_per_dev=coll_dev,
    )


def roofline_table(records_dir: str = "results/dryrun",
                   mesh: str = "sp") -> List[Dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(records_dir, f"*__{mesh}.json"))):
        with open(fn) as f:
            rec = json.load(f)
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':<24}{'shape':<13}{'comp(s)':>10}{'mem(s)':>10}"
           f"{'coll(s)':>10}{'dominant':>11}{'useful':>8}{'MFU≤':>7}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        out.append(f"{r['arch']:<24}{r['shape']:<13}"
                   f"{r['t_compute_s']:>10.4f}{r['t_memory_s']:>10.4f}"
                   f"{r['t_collective_s']:>10.4f}{r['dominant']:>11}"
                   f"{r['useful_ratio']:>8.2f}{r['mfu_bound']:>7.1%}")
    return "\n".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="sp", choices=["sp", "mp"])
    args = ap.parse_args()
    rows = roofline_table(args.dir, args.mesh)
    print(format_table(rows))
    out = os.path.join(args.dir, f"roofline_{args.mesh}.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
