"""Request-based serving vs per-call queries, plus snapshot-refresh cost
after scoped updates.

Two claims, tracked as numbers in ``BENCH_serving.json``:

1. **Admission micro-batching** — the same mixed 10k-query workload
   (MR + s-reach, mixed s) served request-by-request through
   ``eng.mr`` / ``eng.s_reach`` vs submitted to a
   ``ReachabilityService`` and coalesced into fused padded device
   batches.  The headline row uses the ``sharded`` backend — the
   production serving path, where every per-call query pays a full
   device dispatch and micro-batching is the designed fix (>= 5x
   asserted).  An ``hl-index`` row rides along for honesty: the paper's
   host merge-join answers in single-digit microseconds, so on a CPU
   host a Python admission queue cannot beat it — the service's win
   there is the snapshot lifecycle, not raw throughput.
   Every service answer is asserted equal to the independent
   ``mst-oracle`` reference.
2. **Snapshot caching across updates** — after a scoped ``update()``
   on a multi-component graph, the service's snapshot refresh
   re-derives only the touched label rows (counted via
   ``ServiceStats.rows_rederived`` / ``rows_full``), and answers still
   match the oracle.

Timed passes run against pre-warmed bucket shapes (steady-state
serving; the whole point of power-of-two bucketing is that compilation
is paid once per bucket, not per batch).

  PYTHONPATH=src python -m benchmarks.bench_serving            # full
  PYTHONPATH=src python -m benchmarks.bench_serving --quick    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _mixed_workload(h, rng, q):
    from repro.api import MRRequest, SReachRequest

    us = rng.integers(0, h.n, q)
    vs = rng.integers(0, h.n, q)
    is_mr = rng.random(q) < 0.5
    svals = rng.integers(1, 5, q)
    reqs = [MRRequest(int(u), int(v)) if k
            else SReachRequest(int(u), int(v), int(s))
            for u, v, k, s in zip(us, vs, is_mr, svals)]
    return reqs


def _oracle_answers(h, reqs):
    from repro.core import MSTOracle

    oracle = MSTOracle(h)
    out = []
    for r in reqs:
        mr = oracle.mr(r.u, r.v)
        out.append(mr if r.kind == "mr" else mr >= r.s)
    return out


def _per_call_loop(eng, reqs) -> float:
    t0 = time.perf_counter()
    for r in reqs:
        if r.kind == "mr":
            eng.mr(r.u, r.v)
        else:
            eng.s_reach(r.u, r.v, r.s)
    return time.perf_counter() - t0


def bench_backend(backend: str, h, reqs, want, per_call_sample: int) -> dict:
    """Per-call loop vs micro-batched service on one backend; service
    answers asserted equal to the mst-oracle reference."""
    from repro.api import serve

    svc = serve(h, backend, start=False)
    eng = svc.engine
    eng.mr(0, 1)                                     # warm the scalar path

    sample = reqs[:per_call_sample] if per_call_sample else reqs
    per_call_s = _per_call_loop(eng, sample) * (len(reqs) / len(sample))

    futs = svc.submit_many(reqs)                     # warm bucket shapes
    svc.drain()
    [f.result(timeout=0) for f in futs]
    t0 = time.perf_counter()
    futs = svc.submit_many(reqs)
    svc.drain()
    got = [f.result(timeout=0) for f in futs]
    service_s = time.perf_counter() - t0

    for r, g, w in zip(reqs, got, want):
        assert g == w, (backend, r, g, w)

    st = svc.stats()
    q = len(reqs)
    return {
        "backend": backend,
        "queries": q,
        "per_call_s": per_call_s,
        "per_call_sampled": len(sample),
        "service_s": service_s,
        "service_qps": q / service_s,
        "speedup": per_call_s / service_s,
        "batches": st.batches - st.batches // 2,     # timed pass only
        "bucket_histogram": {str(k): v
                             for k, v in sorted(st.bucket_histogram.items())},
        "answers_verified": q,
    }


def bench_scoped_refresh(n_components: int, chain_len: int,
                         n_queries: int) -> dict:
    """Service snapshot refresh after a scoped update: rows re-derived
    must be a fraction of n, answers still equal to the oracle."""
    from repro.api import serve
    from repro.core import apply_edge_edits, planted_chain_hypergraph

    h = planted_chain_hypergraph(n_components, chain_len, overlap=3,
                                 extra_size=2, seed=0)
    svc = serve(h, "hl-index", start=False)
    rng = np.random.default_rng(0)
    futs = svc.submit_many(_mixed_workload(h, rng, 64))
    svc.drain()                                      # resident snapshot up
    [f.result(timeout=0) for f in futs]

    anchor = h.edge(0)
    ins = [[int(anchor[0]), int(anchor[1]), h.n]]
    t0 = time.perf_counter()
    svc.update(inserts=ins)
    h2, _, _ = apply_edge_edits(h, ins, [])
    reqs = _mixed_workload(h2, rng, n_queries)
    futs = svc.submit_many(reqs)
    svc.drain()
    got = [f.result(timeout=0) for f in futs]
    update_and_refresh_s = time.perf_counter() - t0

    want = _oracle_answers(h2, reqs)
    for r, g, w in zip(reqs, got, want):
        assert g == w, (r, g, w)
    st = svc.stats()
    rows_per_refresh = st.rows_rederived - h.n       # first refresh was full
    assert 0 < rows_per_refresh < h2.n, (rows_per_refresh, h2.n)
    return {
        "components": n_components,
        "n": int(h2.n),
        "m": int(h2.m),
        "rows_rederived_after_scoped_update": int(rows_per_refresh),
        "rows_full": int(h2.n),
        "row_fraction": rows_per_refresh / h2.n,
        "update_and_refresh_s": update_and_refresh_s,
        "answers_verified": len(reqs),
    }


def run(n: int, m: int, n_queries: int, per_call_sample: int,
        components: int, chain_len: int, out_path: str,
        enforce_speedup: bool = True) -> dict:
    from repro.core import random_hypergraph

    # low vertex degree keeps the independent MSTOracle check over the
    # full workload tractable (its cost is deg_u * deg_v forest-BFS)
    h = random_hypergraph(n, m, seed=0)
    rng = np.random.default_rng(1)
    reqs = _mixed_workload(h, rng, n_queries)
    want = _oracle_answers(h, reqs)

    rows = [bench_backend("sharded", h, reqs, want, per_call_sample),
            bench_backend("hl-index", h, reqs, want, 0)]
    for row in rows:
        print(f"serving {row['backend']}: per-call {row['per_call_s']:.2f}s "
              f"vs service {row['service_s']:.2f}s "
              f"({row['service_qps']:.0f} q/s) -> {row['speedup']:.1f}x "
              f"[{row['answers_verified']} answers verified]")
    headline = rows[0]
    if enforce_speedup:
        assert headline["speedup"] >= 5.0, (
            f"micro-batched serving must be >= 5x a per-call loop on the "
            f"device-resident backend; measured {headline['speedup']:.2f}x")
    elif headline["speedup"] < 5.0:
        # --quick runs on noisy shared CI runners with a subsampled
        # per-call loop: record the miss loudly, don't fail the job
        print(f"WARNING: quick-mode speedup {headline['speedup']:.2f}x "
              f"< 5x (timing noise at tiny sizes; the full run enforces)")

    refresh = bench_scoped_refresh(components, chain_len,
                                   min(n_queries, 512))
    print(f"scoped refresh: {refresh['rows_rederived_after_scoped_update']}"
          f"/{refresh['rows_full']} rows re-derived "
          f"({refresh['row_fraction']:.1%}) after update on "
          f"{refresh['components']} components")

    doc = {
        "workload": {"n": n, "m": m, "queries": n_queries,
                     "mix": "50% MRRequest / 50% SReachRequest, s in 1..4"},
        "headline_speedup": headline["speedup"],
        "note": ("Steady-state (bucket shapes pre-warmed) service vs a "
                 "per-call eng.mr/eng.s_reach loop on the same engine; "
                 "every service answer asserted equal to the mst-oracle "
                 "reference.  The sharded row is the headline: per-call "
                 "queries on a device-resident snapshot pay one dispatch "
                 "each, micro-batching fuses them.  The hl-index row "
                 "documents the host merge-join floor a Python admission "
                 "queue cannot beat on CPU."),
        "backends": rows,
        "scoped_refresh": refresh,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes for the CI smoke job")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--per-call-sample", type=int, default=None,
                    help="subsample for the (slow) sharded per-call loop; "
                         "0 = run every query")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serving.json"))
    args = ap.parse_args()
    if args.quick:
        n = args.n or 500
        m = args.m or 160
        queries = args.queries or 2000
        sample = 200 if args.per_call_sample is None else args.per_call_sample
        components, chain_len = 4, 8
    else:
        n = args.n or 2000
        m = args.m or 512
        queries = args.queries or 10_000
        sample = 500 if args.per_call_sample is None else args.per_call_sample
        components, chain_len = 16, 20
    run(n, m, queries, sample, components, chain_len, args.out,
        enforce_speedup=not args.quick)


if __name__ == "__main__":
    main()
