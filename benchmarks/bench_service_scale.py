"""Multi-tenant service at scale: fairness, priorities, replicas.

The saturation benchmark behind ``BENCH_service_scale.json``: sweeps
tenants x replicas x priority mixes through the weighted-fair admission
queue and the replica fan-out path, reporting p99 tail latency and a
fairness metric, with **every answer asserted equal to the mst-oracle**.

Three claims, tracked as numbers:

1. **Adversarial fairness** — one greedy tenant floods the queue, a
   light tenant arrives behind the flood.  Under FIFO admission (the
   pre-multi-tenant behavior, emulated by tagging everything as one
   tenant) the light tenant's first answer waits behind the whole
   flood; under weighted-fair scheduling it rides the very next
   micro-batch.  Reported: per-tenant p99 latency both ways, the
   starvation factor (FIFO wait / WFQ wait in batches), and the
   fairness metric — max/min per-tenant weight-normalized throughput
   over the contended window (1.0 = perfectly proportional).
2. **Tenants x replicas x priority mixes** — the saturation grid.  Each
   cell submits one mixed MR/s-reach workload split across N weighted
   tenants and three priority classes, serves it through 1 or R
   mesh-resident snapshot replicas, and reports per-priority p99
   (strict bands: interactive p99 <= batch p99 under backlog),
   per-tenant fairness ratio, and throughput.
3. **Replica churn** — updates interleave with serving at each replica
   count; only dirty rows fan out (``rows_patched`` counted) and
   answers stay oracle-correct across versions.

  PYTHONPATH=src python -m benchmarks.bench_service_scale           # full
  PYTHONPATH=src python -m benchmarks.bench_service_scale --quick   # CI
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

PRIORITY_MIXES = {
    "uniform": (("standard", 1.0),),
    "mixed": (("interactive", 0.1), ("standard", 0.6), ("batch", 0.3)),
    "bimodal": (("interactive", 0.5), ("batch", 0.5)),
}


def _query_pool(h, rng, q):
    """One reusable pool of (kind, u, v, s) tuples; metadata is layered
    on per scenario so the oracle pass is paid once."""
    us = rng.integers(0, h.n, q)
    vs = rng.integers(0, h.n, q)
    is_mr = rng.random(q) < 0.5
    svals = rng.integers(1, 5, q)
    return [("mr", int(u), int(v), 0) if k else
            ("s_reach", int(u), int(v), int(s))
            for u, v, k, s in zip(us, vs, is_mr, svals)]


def _oracle_table(h, pool):
    from repro.core import MSTOracle

    oracle = MSTOracle(h)
    table = {}
    for kind, u, v, s in set(pool):
        mr = oracle.mr(u, v)
        table[(kind, u, v, s)] = mr if kind == "mr" else mr >= s
    return table


def _requests(pool, *, tenant="default", priority="standard", rng=None,
              tenants=None, mix=None):
    """Materialize the pool as typed requests; ``tenants`` round-robins
    the tenant field, ``mix`` draws priorities by the named weights."""
    from repro.api import MRRequest, SReachRequest

    reqs = []
    if mix is not None:
        names = [name for name, _ in mix]
        probs = np.array([p for _, p in mix], float)
        draws = rng.choice(len(names), size=len(pool), p=probs / probs.sum())
    for i, (kind, u, v, s) in enumerate(pool):
        t = tenants[i % len(tenants)] if tenants else tenant
        p = names[draws[i]] if mix is not None else priority
        if kind == "mr":
            reqs.append(MRRequest(u, v, tenant=t, priority=p))
        else:
            reqs.append(SReachRequest(u, v, s, tenant=t, priority=p))
    return reqs


def _assert_oracle(pool, futs, table, where):
    for (kind, u, v, s), fut in zip(pool, futs):
        got = fut.result(timeout=0)
        want = table[(kind, u, v, s)]
        assert got == want, (where, kind, u, v, s, got, want)


def _serve_stepped(svc, reqs):
    """Submit everything, then step the service one micro-batch at a
    time, recording per-batch per-tenant completions and per-request
    resolution timestamps (queueing delay under saturation)."""
    done_at = {}
    futs = [svc.submit(r, on_result=lambda rq, f:
                       done_at.__setitem__(id(rq), time.perf_counter()))
            for r in reqs]
    steps = []
    prev = {}
    t0 = time.perf_counter()
    while True:
        pending_before = svc.backlog()
        if not pending_before:
            break
        svc.drain(max_batches=1)
        st = svc.stats()
        delta = {t: c - prev.get(t, 0)
                 for t, c in st.tenant_answered.items() if c - prev.get(t, 0)}
        prev = dict(st.tenant_answered)
        steps.append({"pending_before": pending_before,
                      "pending_after": svc.backlog(), "delta": delta})
    wall_s = time.perf_counter() - t0
    lat = {id(r): done_at[id(r)] - t0 for r in reqs}
    return futs, steps, lat, wall_s


def _fairness_ratio(steps, weights):
    """max/min weight-normalized per-tenant throughput over the batches
    where every tenant stayed backlogged for the whole batch (1.0 =
    proportional).  Batches where a queue drains mid-batch are excluded:
    the emptied tenant's surplus slots legitimately go to the others."""
    totals = {t: 0 for t in weights}
    contended = 0
    for step in steps:
        if any(step["pending_before"].get(t, 0) == 0
               or step["pending_after"].get(t, 0) == 0 for t in weights):
            continue
        contended += 1
        for t in weights:
            totals[t] += step["delta"].get(t, 0)
    if not contended or any(v == 0 for v in totals.values()):
        return None, contended
    normed = [totals[t] / weights[t] for t in weights]
    return max(normed) / min(normed), contended


def _p99(values):
    return float(np.percentile(np.asarray(values, float), 99)) \
        if values else None


def bench_adversarial(eng, pool, table, *, greedy_q, light_q,
                      max_batch) -> dict:
    """Greedy flood vs light tenant: weighted-fair vs FIFO emulation."""
    from repro.api import ReachabilityService, ServiceConfig, TenantSpec

    greedy_pool, light_pool = pool[:greedy_q], pool[greedy_q:greedy_q + light_q]
    out = {}
    for policy in ("wfq", "fifo"):
        if policy == "wfq":
            cfg = ServiceConfig(max_batch=max_batch,
                                tenants=(TenantSpec("greedy", 1.0),
                                         TenantSpec("light", 1.0)))
            g_t, l_t = "greedy", "light"
        else:
            # FIFO emulation: one tenant queue preserves submission
            # order exactly — the pre-multi-tenant admission behavior
            cfg = ServiceConfig(max_batch=max_batch)
            g_t = l_t = "all"
        svc = ReachabilityService(eng, config=cfg, start=False)
        greedy_reqs = _requests(greedy_pool, tenant=g_t)
        light_reqs = _requests(light_pool, tenant=l_t)
        g_futs = svc.submit_many(greedy_reqs)     # flood lands first
        l_futs = svc.submit_many(light_reqs)
        light_ids = {id(r) for r in light_reqs}

        # step batches; note the first batch after which the light
        # tenant is fully answered
        light_done_batch = None
        steps = []
        prev = {}
        done_at = {}
        t0 = time.perf_counter()
        batch_no = 0
        while svc.pending():
            svc.drain(max_batches=1)
            batch_no += 1
            st = svc.stats()
            delta = {t: c - prev.get(t, 0)
                     for t, c in st.tenant_answered.items()}
            prev = dict(st.tenant_answered)
            steps.append({"pending_before": {}, "delta": delta})
            now = time.perf_counter()
            for r, f in zip(light_reqs + greedy_reqs, l_futs + g_futs):
                if f.done() and id(r) not in done_at:
                    done_at[id(r)] = now - t0
            if light_done_batch is None and all(f.done() for f in l_futs):
                light_done_batch = batch_no
        _assert_oracle(greedy_pool, g_futs, table, f"adversarial/{policy}")
        _assert_oracle(light_pool, l_futs, table, f"adversarial/{policy}")
        light_lat = [done_at[i] for i in light_ids]
        greedy_lat = [v for i, v in done_at.items() if i not in light_ids]
        out[policy] = {
            "greedy_queries": greedy_q,
            "light_queries": light_q,
            "batches": batch_no,
            "light_done_after_batches": light_done_batch,
            "light_p99_s": _p99(light_lat),
            "greedy_p99_s": _p99(greedy_lat),
            "answers_verified": greedy_q + light_q,
        }
    wfq, fifo = out["wfq"], out["fifo"]
    # the starvation bound: under WFQ the light tenant rides batch 1
    assert wfq["light_done_after_batches"] == 1, wfq
    assert fifo["light_done_after_batches"] > wfq["light_done_after_batches"]
    out["starvation_factor_batches"] = (fifo["light_done_after_batches"]
                                        / wfq["light_done_after_batches"])
    return out


def bench_grid_cell(eng, pool, table, *, n_tenants, replicas, mix_name,
                    max_batch) -> dict:
    """One saturation-grid cell: N weighted tenants x R replicas x one
    priority mix, everything submitted up front (saturated queue)."""
    from repro.api import (ReachabilityService, ReplicaGroup, ServiceConfig,
                           TenantSpec)

    rng = np.random.default_rng(hash((n_tenants, replicas, mix_name)) % 2**32)
    names = [f"t{i}" for i in range(n_tenants)]
    weights = {name: float(i + 1) for i, name in enumerate(names)}
    cfg = ServiceConfig(
        max_batch=max_batch, replicas=replicas,
        tenants=tuple(TenantSpec(n, w) for n, w in weights.items()))
    svc = (ReplicaGroup(eng, config=cfg, start=False) if replicas > 1
           else ReachabilityService(eng, config=cfg, start=False))
    reqs = _requests(pool, tenants=names, rng=rng,
                     mix=PRIORITY_MIXES[mix_name])
    futs, steps, lat, wall_s = _serve_stepped(svc, reqs)
    _assert_oracle(pool, futs, table,
                   f"grid/{n_tenants}x{replicas}x{mix_name}")
    fairness, contended = _fairness_ratio(steps, weights)
    by_prio = {}
    for r in reqs:
        by_prio.setdefault(r.priority, []).append(lat[id(r)])
    st = svc.stats()
    cell = {
        "tenants": n_tenants,
        "replicas": replicas,
        "priority_mix": mix_name,
        "queries": len(reqs),
        "wall_s": wall_s,
        "qps": len(reqs) / wall_s,
        "batches": st.batches,
        "fairness_ratio": fairness,
        "contended_batches": contended,
        "p99_s_by_priority": {p: _p99(v) for p, v in sorted(by_prio.items())},
        "tenant_weights": weights,
        "answers_verified": len(reqs),
    }
    if replicas > 1:
        rstats = svc.replica_stats()
        cell["replica_batches"] = [r["batches"] for r in rstats]
    # strict bands under a saturated queue: interactive tail never worse
    # than batch tail (equal only when everything fits in one batch)
    p99 = cell["p99_s_by_priority"]
    if "interactive" in p99 and "batch" in p99 and st.batches > 2:
        assert p99["interactive"] <= p99["batch"] * 1.05, p99
    # the DRR proportionality guarantee is per priority band, so the
    # aggregate ratio is only a tight bound on single-class mixes (on
    # multi-class cells the small interactive band is served equally
    # before weights matter, diluting the aggregate toward 1/weight)
    if fairness is not None and len(PRIORITY_MIXES[mix_name]) == 1:
        assert fairness <= 1.5, (fairness, "weighted shares off")
    return cell


def bench_replica_churn(replicas: int, n_chains: int, queries: int) -> dict:
    """Interleaved update/serve stream at one replica count: dirty-row
    fan-out counted, every answer oracle-checked at every version."""
    from repro.api import MRRequest, ReplicaGroup, ServiceConfig, build_engine
    from repro.core import MSTOracle, from_edge_lists

    edges = [[0, 1, 2], [1, 2, 3], [10, 11, 12], [11, 12, 13]]
    for i in range(n_chains):
        edges.append([20 + 2 * i, 21 + 2 * i, 22 + 2 * i, 23 + 2 * i])
    h = from_edge_lists(edges)
    eng = build_engine(h, "hl-index")
    grp = ReplicaGroup(eng, replicas,
                       config=ServiceConfig(max_batch=128), start=False)
    rng = np.random.default_rng(0)
    edits = [[[0, 1, 2, 3]], [[10, 11, 12, 13]], [[0, 2, 3]]]
    verified = 0
    t0 = time.perf_counter()
    for ins in edits:
        cur = grp.engine.h
        oracle = MSTOracle(cur)
        us = rng.integers(0, cur.n, queries)
        vs = rng.integers(0, cur.n, queries)
        futs = grp.submit_many([MRRequest(int(u), int(v))
                                for u, v in zip(us, vs)])
        grp.drain()
        for u, v, f in zip(us, vs, futs):
            assert f.result(timeout=0) == oracle.mr(int(u), int(v))
        verified += queries
        grp.update(inserts=ins)
    wall_s = time.perf_counter() - t0
    st = grp.stats()
    rstats = grp.replica_stats()
    assert all(r["full_relands"] == 1 for r in rstats), rstats
    return {
        "replicas": replicas,
        "versions_served": len(edits),
        "queries_per_version": queries,
        "wall_s": wall_s,
        "rows_patched_total": st.mesh_rows_patched,
        "full_relands_per_replica": [r["full_relands"] for r in rstats],
        "answers_verified": verified,
    }


def run(n, m, queries, greedy_q, light_q, max_batch, tenant_counts,
        replica_counts, mixes, out_path) -> dict:
    from repro.api import build_engine, random_hypergraph

    h = random_hypergraph(n, m, seed=0)
    rng = np.random.default_rng(1)
    pool = _query_pool(h, rng, queries)
    table = _oracle_table(h, pool)
    eng = build_engine(h, "hl-index")
    eng.snapshot()                                   # warm the shared engine

    adversarial = bench_adversarial(
        eng, pool[:greedy_q + light_q], table,
        greedy_q=greedy_q, light_q=light_q, max_batch=max_batch)
    print(f"adversarial: light tenant done after "
          f"{adversarial['wfq']['light_done_after_batches']} batch(es) "
          f"under WFQ vs {adversarial['fifo']['light_done_after_batches']} "
          f"under FIFO ({adversarial['starvation_factor_batches']:.0f}x "
          f"starvation factor)")

    grid = []
    for n_tenants in tenant_counts:
        for replicas in replica_counts:
            for mix_name in mixes:
                cell = bench_grid_cell(eng, pool, table,
                                       n_tenants=n_tenants,
                                       replicas=replicas, mix_name=mix_name,
                                       max_batch=max_batch)
                grid.append(cell)
                fr = cell["fairness_ratio"]
                print(f"grid {n_tenants}t x {replicas}r x {mix_name}: "
                      f"{cell['qps']:.0f} q/s, fairness "
                      f"{fr if fr is None else round(fr, 3)}, p99 "
                      f"{ {p: None if v is None else round(v * 1e3, 2) for p, v in cell['p99_s_by_priority'].items()} } ms")

    churn = [bench_replica_churn(r, n_chains=10, queries=min(queries, 256))
             for r in replica_counts]
    for row in churn:
        print(f"churn {row['replicas']}r: {row['versions_served']} versions, "
              f"{row['rows_patched_total']} rows patched, "
              f"{row['answers_verified']} answers verified")

    doc = {
        "workload": {"n": n, "m": m, "queries": queries,
                     "mix": "50% MRRequest / 50% SReachRequest, s in 1..4",
                     "max_batch": max_batch},
        "note": ("Saturated-queue serving (everything submitted before "
                 "draining, stepped one micro-batch at a time); latency = "
                 "queueing delay to each request's resolution; fairness "
                 "ratio = max/min weight-normalized per-tenant throughput "
                 "over contended batches (1.0 = proportional); every "
                 "answer asserted equal to the mst-oracle reference."),
        "adversarial": adversarial,
        "grid": grid,
        "replica_churn": churn,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes for the CI smoke job")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_service_scale.json"))
    args = ap.parse_args()
    if args.quick:
        run(n=args.n or 300, m=args.m or 100,
            queries=args.queries or 768, greedy_q=512, light_q=16,
            max_batch=128, tenant_counts=(2,), replica_counts=(1, 2),
            mixes=("uniform", "mixed"), out_path=args.out)
    else:
        run(n=args.n or 1500, m=args.m or 420,
            queries=args.queries or 6144, greedy_q=4096, light_q=32,
            max_batch=256, tenant_counts=(2, 4), replica_counts=(1, 2),
            mixes=("uniform", "mixed", "bimodal"), out_path=args.out)


if __name__ == "__main__":
    main()
