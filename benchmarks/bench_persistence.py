"""Warm restart vs cold rebuild (repro.store).

The durable-store claim is tracked as a number, not prose: a serving
restart should pay page-in cost, not construction cost.  For each graph
size this sweep measures

* **cold** — build the HL-index from the in-memory hypergraph (what
  every restart used to cost),
* **warm** — ``load_index`` of a saved checkpoint (mmap + view setup),
* **warm+replay** — ``IndexStore.restore``: checkpoint load plus a
  K-record WAL suffix replayed through scoped maintenance (the
  crash-recovery path),

asserts the loaded labels byte-identical to the freshly built ones and
every answer equal to the independent ``mst-oracle``, and writes
``BENCH_persistence.json`` at the repo root — the accumulating record
the CI smoke job regenerates at tiny sizes.

  PYTHONPATH=src python -m benchmarks.bench_persistence            # sweep
  PYTHONPATH=src python -m benchmarks.bench_persistence --quick    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np


def _update_stream(h, k, seed=11):
    """Deterministic K-batch update stream (valid at every step)."""
    rng = np.random.default_rng(seed)
    m = h.m
    batches = []
    for i in range(k):
        ins = [sorted(int(x) for x in rng.choice(h.n, 3, replace=False))]
        dels = [int(rng.integers(0, m))] if i % 3 == 2 else []
        m += len(ins) - len(dels)
        batches.append((ins, dels))
    return batches


def bench_size(n: int, m: int, wal_records: int, n_queries: int,
               seed: int = 0) -> dict:
    from repro.api import build_engine, random_hypergraph
    from repro.core.baselines import MSTOracle
    from repro.store import IndexStore, load_index, save_index

    h = random_hypergraph(n, m, min_size=2, max_size=6, seed=seed)

    t0 = time.perf_counter()
    eng = build_engine(h, "hl-index")
    cold_s = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt.hlidx")
        t0 = time.perf_counter()
        save_index(path, eng)
        save_s = time.perf_counter() - t0
        index_bytes = os.path.getsize(path)

        t0 = time.perf_counter()
        warm = load_index(path)
        warm_s = time.perf_counter() - t0

        # the tentpole assertion: loaded labels byte-identical to built
        assert np.array_equal(eng.idx.rank, warm.idx.rank)
        assert np.array_equal(eng.idx.perm, warm.idx.perm)
        for u in range(h.n):
            for attr in ("labels_edge", "labels_rank", "labels_s"):
                a, b = getattr(eng.idx, attr)[u], getattr(warm.idx, attr)[u]
                assert a.dtype == b.dtype and np.array_equal(a, b), (n, u)

        rng = np.random.default_rng(1)
        us = rng.integers(0, h.n, n_queries)
        vs = rng.integers(0, h.n, n_queries)
        oracle = MSTOracle(h)
        want = np.array([oracle.mr(int(u), int(v)) for u, v in zip(us, vs)],
                        np.int64)
        np.testing.assert_array_equal(
            np.asarray(warm.mr_batch(us, vs)).astype(np.int64), want)

        # crash-recovery path: checkpoint + K journaled updates replayed
        store_dir = os.path.join(td, "store")
        store = IndexStore(store_dir)
        store.attach(eng)
        batches = _update_stream(h, wal_records)
        for ins, dels in batches:
            eng.update(inserts=ins, deletes=dels)
        store.close()
        t0 = time.perf_counter()
        replayed = IndexStore(store_dir).restore(attach=False)
        replay_s = time.perf_counter() - t0
        assert replayed.version == eng.version == wal_records
        oracle2 = MSTOracle(eng.h)
        us2 = rng.integers(0, eng.h.n, n_queries)
        vs2 = rng.integers(0, eng.h.n, n_queries)
        want2 = np.array([oracle2.mr(int(u), int(v))
                          for u, v in zip(us2, vs2)], np.int64)
        np.testing.assert_array_equal(
            np.asarray(replayed.mr_batch(us2, vs2)).astype(np.int64), want2)

    return {
        "n": int(n),
        "m": int(m),
        "wal_records": int(wal_records),
        "index_bytes": int(index_bytes),
        "cold_build_ms": cold_s * 1e3,
        "save_ms": save_s * 1e3,
        "warm_load_ms": warm_s * 1e3,
        "load_replay_ms": replay_s * 1e3,
        "warm_speedup": cold_s / max(warm_s, 1e-12),
        "answers_checked": 2 * n_queries,
    }


def sweep(sizes, wal_records: int, n_queries: int, out_path: str) -> dict:
    results = [bench_size(n, m, wal_records, n_queries) for n, m in sizes]
    for row in results:
        print(f"persistence n={row['n']} m={row['m']}: cold build "
              f"{row['cold_build_ms']:.1f} ms vs warm load "
              f"{row['warm_load_ms']:.2f} ms -> {row['warm_speedup']:.0f}x "
              f"(load+{row['wal_records']}-record replay "
              f"{row['load_replay_ms']:.1f} ms, "
              f"{row['index_bytes'] / 1024:.0f} KiB on disk, "
              f"{row['answers_checked']} answers verified)")
    doc = {
        "wal_records": wal_records,
        "note": ("cold = build_engine(h, 'hl-index') from the in-memory "
                 "graph; warm = load_index of the saved checkpoint (mmap, "
                 "no construction); load_replay = IndexStore.restore with "
                 "a K-record WAL suffix replayed through scoped "
                 "maintenance.  Loaded labels asserted byte-identical to "
                 "freshly built ones and every answer asserted equal to "
                 "the mst-oracle."),
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes for the CI smoke job")
    ap.add_argument("--wal-records", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=40)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_persistence.json"))
    args = ap.parse_args()
    if args.quick:
        sizes = [(120, 150), (300, 380)]
        wal_records = args.wal_records or 4
    else:
        sizes = [(300, 380), (900, 1100), (2000, 2600), (4000, 5200)]
        wal_records = args.wal_records or 8
    sweep(sizes, wal_records, args.n_queries, args.out)


if __name__ == "__main__":
    main()
