"""Benchmarks: one per paper table/figure + roofline harness."""
