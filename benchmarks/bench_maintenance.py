"""Scoped maintenance vs full rebuild across line-graph component counts.

The scoped-maintenance claim (repro.core.maintenance: construction reruns
only on the affected component) is tracked as a number, not prose: for a
graph of C disjoint chain components, each update touches one component,
so the ideal scoped/rebuild speedup is ~C.  This sweep measures both
paths on identical update sequences, asserts answer-equality on every
step, and writes ``BENCH_maintenance.json`` at the repo root — the
accumulating record the CI smoke job regenerates at tiny sizes.

  PYTHONPATH=src python -m benchmarks.bench_maintenance            # sweep
  PYTHONPATH=src python -m benchmarks.bench_maintenance --quick    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _sample_queries(h, rng, q):
    us = rng.integers(0, h.n, q)
    vs = rng.integers(0, h.n, q)
    return us, vs


def bench_components(n_components: int, chain_len: int, reps: int,
                     n_queries: int, seed: int = 0) -> dict:
    """Time ``reps`` insert+delete update pairs, scoped vs full rebuild."""
    from repro.core import (planted_chain_hypergraph, build_fast, mr_query,
                            apply_updates)

    rng = np.random.default_rng(seed)
    h = planted_chain_hypergraph(n_components, chain_len, overlap=3,
                                 extra_size=2, seed=seed)
    idx = build_fast(h)
    m0 = h.m

    scoped_s = 0.0
    rebuild_s = 0.0
    scopes = []
    for r in range(reps):
        # insert a hyperedge into one chain (attach to that chain's head),
        # then delete it again — the graph returns to its start state, so
        # every rep measures the same-shaped update
        anchor = h.edge((r * chain_len) % h.m)
        ins = [int(anchor[0]), int(anchor[1]), h.n + r]

        t0 = time.perf_counter()
        h_ins, idx_ins, _ = apply_updates(h, idx, inserts=[ins])
        t1 = time.perf_counter()
        full_ins = build_fast(h_ins)
        t2 = time.perf_counter()
        scoped_s += t1 - t0
        rebuild_s += t2 - t1
        scopes.append(int(idx_ins.stats["maintenance_scope"]))

        us, vs = _sample_queries(h_ins, rng, n_queries)
        for u, v in zip(us, vs):
            a = mr_query(idx_ins, int(u), int(v))
            b = mr_query(full_ins, int(u), int(v))
            assert a == b, (n_components, r, int(u), int(v), a, b)

        t0 = time.perf_counter()
        h_del, idx_del, _ = apply_updates(h_ins, idx_ins,
                                          deletes=[h_ins.m - 1])
        t1 = time.perf_counter()
        full_del = build_fast(h_del)
        t2 = time.perf_counter()
        scoped_s += t1 - t0
        rebuild_s += t2 - t1
        scopes.append(int(idx_del.stats["maintenance_scope"]))

        us, vs = _sample_queries(h_del, rng, n_queries)
        for u, v in zip(us, vs):
            a = mr_query(idx_del, int(u), int(v))
            b = mr_query(full_del, int(u), int(v))
            assert a == b, (n_components, r, int(u), int(v), a, b)

    ops = 2 * reps
    return {
        "components": n_components,
        "m": int(m0),
        "n": int(h.n),
        "ops": ops,
        "mean_scope_edges": float(np.mean(scopes)),
        "scoped_ms_per_op": scoped_s / ops * 1e3,
        "rebuild_ms_per_op": rebuild_s / ops * 1e3,
        "speedup": rebuild_s / max(scoped_s, 1e-12),
        "answers_checked": ops * n_queries,
    }


def bench_sharded(n_components: int, chain_len: int, reps: int,
                  n_queries: int, *, labels: bool, seed: int = 0) -> dict:
    """Scoped ``ShardedEngine.update`` vs a fresh sharded build on the
    same edits, answers asserted against the MST oracle every step."""
    from repro.api import build_engine
    from repro.core import (MSTOracle, apply_edge_edits,
                            planted_chain_hypergraph)

    rng = np.random.default_rng(seed)
    h = planted_chain_hypergraph(n_components, chain_len, overlap=3,
                                 extra_size=2, seed=seed)
    eng = build_engine(h, "sharded", build_labels=labels)
    eng.block_until_built()
    m0, cur = h.m, h

    def _check(engine, graph):
        us, vs = _sample_queries(graph, rng, n_queries)
        mst = MSTOracle(graph)
        got = np.asarray(engine.mr_batch(us, vs)).astype(np.int64)
        want = np.array([mst.mr(int(u), int(v)) for u, v in zip(us, vs)],
                        np.int64)
        assert np.array_equal(got, want), (n_components, labels)

    # one untimed insert+delete pair first: jit compilation of the
    # scoped-patch closures must not be billed to the steady state
    warm = [int(cur.edge(0)[0]), int(cur.edge(0)[1]), cur.n]
    eng.update(inserts=[warm])
    eng.update(deletes=[cur.m])

    scoped_s = rebuild_s = 0.0
    for r in range(reps):
        anchor = cur.edge((r * chain_len) % cur.m)
        ins = [int(anchor[0]), int(anchor[1]), cur.n + r]
        h_ins, _, _ = apply_edge_edits(cur, [ins], [])
        h_del, _, _ = apply_edge_edits(h_ins, [], [h_ins.m - 1])
        for inserts, deletes, graph in (([ins], [], h_ins),
                                        ([], [h_ins.m - 1], h_del)):
            t0 = time.perf_counter()
            eng.update(inserts=inserts, deletes=deletes)
            t1 = time.perf_counter()
            fresh = build_engine(graph, "sharded", build_labels=labels)
            fresh.block_until_built()
            t2 = time.perf_counter()
            scoped_s += t1 - t0
            rebuild_s += t2 - t1
            _check(eng, graph)
            _check(fresh, graph)
        cur = h_del

    ops = 2 * reps
    return {
        "backend": "sharded[labels]" if labels else "sharded",
        "components": n_components,
        "m": int(m0),
        "n": int(h.n),
        "ops": ops,
        "scoped_ms_per_op": scoped_s / ops * 1e3,
        "rebuild_ms_per_op": rebuild_s / ops * 1e3,
        "speedup": rebuild_s / max(scoped_s, 1e-12),
        "answers_checked": 2 * ops * n_queries,
    }


def sweep(component_counts, chain_len: int, reps: int, n_queries: int,
          out_path: str, sharded_chain_len: int = 24) -> dict:
    results = [bench_components(c, chain_len, reps, n_queries)
               for c in component_counts]
    for row in results:
        print(f"maintenance C={row['components']} m={row['m']}: "
              f"scoped {row['scoped_ms_per_op']:.2f} ms/op vs rebuild "
              f"{row['rebuild_ms_per_op']:.2f} ms/op "
              f"-> {row['speedup']:.1f}x (scope ~{row['mean_scope_edges']:.0f} "
              f"edges, {row['answers_checked']} answers verified)")
    sharded_results = [bench_sharded(c, sharded_chain_len, reps, n_queries,
                                     labels=labels)
                       for labels in (False, True)
                       for c in component_counts]
    for row in sharded_results:
        print(f"maintenance {row['backend']} C={row['components']} "
              f"m={row['m']}: scoped {row['scoped_ms_per_op']:.2f} ms/op "
              f"vs rebuild {row['rebuild_ms_per_op']:.2f} ms/op "
              f"-> {row['speedup']:.1f}x "
              f"({row['answers_checked']} answers verified)")
    doc = {
        "chain_len": chain_len,
        "sharded_chain_len": sharded_chain_len,
        "reps": reps,
        "note": ("scoped apply_updates vs build_fast on the full graph, "
                 "identical insert+delete sequences; answers asserted "
                 "equal on every step.  Ideal speedup ~= component count "
                 "(one component is touched per update)."),
        "sharded_note": ("scoped ShardedEngine.update (incremental closure "
                         "block / parallel component splice) vs a fresh "
                         "sharded build of the same regime; every "
                         "post-update answer asserted against the MST "
                         "oracle for both engines."),
        "results": results,
        "sharded_results": sharded_results,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes for the CI smoke job")
    ap.add_argument("--components", type=int, nargs="+", default=None)
    ap.add_argument("--chain-len", type=int, default=None)
    ap.add_argument("--sharded-chain-len", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=40)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_maintenance.json"))
    args = ap.parse_args()
    if args.quick:
        components = args.components or [2, 4]
        chain_len = args.chain_len or 8
        sharded_chain_len = args.sharded_chain_len or 4
        reps = args.reps or 1
    else:
        components = args.components or [2, 4, 8, 16, 32]
        chain_len = args.chain_len or 40
        sharded_chain_len = args.sharded_chain_len or 24
        reps = args.reps or 3
    sweep(components, chain_len, reps, args.n_queries, args.out,
          sharded_chain_len=sharded_chain_len)


if __name__ == "__main__":
    main()
