"""Workload ops vs their brute-force references across graph sizes.

Every family in the workload subsystem (repro.workloads) is timed
against the independent brute-force reference that defines it — and
every answer is asserted on the spot, so the numbers can't drift from
correctness: witness walks verify and realize exactly the brute-force
MR, hop-bounded and set answers are byte-identical, and the landmark
oracle's bounds respect the certified contract (zero iff zero,
bound >= exact).  The set-to-set family is additionally timed on the
Pallas kernel path vs the host join (same answers asserted).  Writes
``BENCH_workloads.json`` at the repo root — the accumulating record the
CI smoke job regenerates at tiny sizes.

  PYTHONPATH=src python -m benchmarks.bench_workloads            # sweep
  PYTHONPATH=src python -m benchmarks.bench_workloads --quick    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _timed(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    return out, time.perf_counter() - t0


def bench_size(n: int, m: int, n_queries: int, seed: int = 0) -> dict:
    from repro.api import build_engine, random_hypergraph, verify_witness
    from repro.core import (brute_force_mr_set, brute_force_s_distance,
                            brute_force_s_reach_k, brute_force_top_s,
                            brute_force_witness)

    h = random_hypergraph(n, m, seed=seed)
    eng = build_engine(h, "hl-index")
    rng = np.random.default_rng(seed + 1)
    pairs = [(int(u), int(v)) for u, v in rng.integers(0, h.n,
                                                       (n_queries, 2))]
    checked = 0
    row = {"n": int(h.n), "m": int(h.m), "queries": n_queries}

    # witness: engine walk == brute-force strength, both walks verify
    eng_s = brute_s = 0.0
    for u, v in pairs:
        w, dt = _timed(eng.mr_witness, u, v)
        eng_s += dt
        (bk, bwalk), dt = _timed(brute_force_witness, h, u, v)
        brute_s += dt
        assert w.s == bk and verify_witness(h, w), (u, v, w, bk)
        checked += 1
    row["witness"] = {"engine_ms": eng_s / n_queries * 1e3,
                      "brute_ms": brute_s / n_queries * 1e3}

    # hop-bounded s-reach: byte-identical booleans
    eng_s = brute_s = 0.0
    for u, v in pairs:
        for s, k in ((1, 2), (2, 3)):
            a, dt = _timed(eng.s_reach_k, u, v, s, k)
            eng_s += dt
            b, dt = _timed(brute_force_s_reach_k, h, u, v, s, k)
            brute_s += dt
            assert a == b, (u, v, s, k, a, b)
            checked += 1
    q2 = n_queries * 2
    row["s_reach_k"] = {"engine_ms": eng_s / q2 * 1e3,
                        "brute_ms": brute_s / q2 * 1e3}

    # set-to-set MR: identical ints (one batched join vs the pair loop)
    eng_s = brute_s = 0.0
    set_reps = max(n_queries // 4, 1)
    for r in range(set_reps):
        us = rng.integers(0, h.n, 8)
        vs = rng.integers(0, h.n, 8)
        a, dt = _timed(eng.mr_set, us, vs)
        eng_s += dt
        b, dt = _timed(brute_force_mr_set, h, us, vs)
        brute_s += dt
        assert int(a) == int(b), (r, a, b)
        checked += 1
    row["mr_set"] = {"engine_ms": eng_s / set_reps * 1e3,
                     "brute_ms": brute_s / set_reps * 1e3}

    # top-k ranking: identical (vertex, mr) arrays
    eng_s = brute_s = 0.0
    for u, _ in pairs:
        (verts, vals), dt = _timed(eng.top_s, u, 10)
        eng_s += dt
        (bv, bs), dt = _timed(brute_force_top_s, h, u, 10)
        brute_s += dt
        assert (np.array_equal(np.asarray(verts), bv)
                and np.array_equal(np.asarray(vals), bs)), u
        checked += 1
    row["top_s"] = {"engine_ms": eng_s / n_queries * 1e3,
                    "brute_ms": brute_s / n_queries * 1e3}

    # landmark s-distance: certified contract (zero iff zero, bound >=
    # exact); oracle build cost reported separately from query cost
    _, build_s = _timed(eng.distance_oracle, 2)
    eng_s = brute_s = 0.0
    for u, v in pairs:
        bound, dt = _timed(eng.s_distance, u, v, 2)
        eng_s += dt
        exact, dt = _timed(brute_force_s_distance, h, u, v, 2)
        brute_s += dt
        assert (bound == 0) == (exact == 0) and bound >= exact, \
            (u, v, bound, exact)
        checked += 1
    row["s_distance"] = {"engine_ms": eng_s / n_queries * 1e3,
                         "brute_ms": brute_s / n_queries * 1e3,
                         "oracle_build_ms": build_s * 1e3}
    row["answers_checked"] = checked
    return row


def bench_mr_set_kernel(n: int, m: int, reps: int, seed: int = 0) -> dict:
    """Set-to-set MR through the Pallas label-join kernel path vs the
    host join — identical answers asserted on every rep."""
    from repro.api import build_engine, random_hypergraph

    h = random_hypergraph(n, m, seed=seed)
    host = build_engine(h, "hl-index")
    kern = build_engine(h, "hl-index", use_kernels=True)
    rng = np.random.default_rng(seed)
    sets = [(rng.integers(0, h.n, 16), rng.integers(0, h.n, 16))
            for _ in range(reps)]
    kern.mr_set(*sets[0])                    # compile outside the clock
    host_s = kern_s = 0.0
    for us, vs in sets:
        a, dt = _timed(host.mr_set, us, vs)
        host_s += dt
        b, dt = _timed(kern.mr_set, us, vs)
        kern_s += dt
        assert int(a) == int(b), (a, b)
    return {"n": int(h.n), "m": int(h.m), "reps": reps,
            "host_ms": host_s / reps * 1e3,
            "kernel_ms": kern_s / reps * 1e3,
            "answers_checked": reps}


def sweep(sizes, n_queries: int, kernel_reps: int, out_path: str) -> dict:
    results = [bench_size(n, m, n_queries) for n, m in sizes]
    for row in results:
        ops = {op: row[op] for op in ("witness", "s_reach_k", "mr_set",
                                      "top_s", "s_distance")}
        summary = ", ".join(
            f"{op} {v['engine_ms']:.2f}/{v['brute_ms']:.2f}"
            for op, v in ops.items())
        print(f"workloads n={row['n']} m={row['m']}: engine/brute ms — "
              f"{summary} ({row['answers_checked']} answers verified)")
    kn, km = sizes[-1]
    kernel = bench_mr_set_kernel(kn, km, kernel_reps)
    print(f"mr_set kernel vs host at n={kernel['n']} m={kernel['m']}: "
          f"{kernel['kernel_ms']:.2f} ms vs {kernel['host_ms']:.2f} ms "
          f"({kernel['answers_checked']} answers verified)")
    doc = {
        "note": ("each workload op vs its brute-force reference; every "
                 "answer asserted (byte-identical where exact, certified "
                 "bound contract for s_distance).  mr_set additionally "
                 "timed on the Pallas kernel path vs the host join."),
        "results": results,
        "mr_set_kernel_vs_host": kernel,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes for the CI smoke job")
    ap.add_argument("--n-queries", type=int, default=None)
    ap.add_argument("--kernel-reps", type=int, default=None)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_workloads.json"))
    args = ap.parse_args()
    if args.quick:
        sizes = [(20, 30), (30, 45), (40, 60)]
        n_queries = args.n_queries or 6
        kernel_reps = args.kernel_reps or 2
    else:
        sizes = [(60, 90), (120, 180), (240, 360)]
        n_queries = args.n_queries or 20
        kernel_reps = args.kernel_reps or 5
    sweep(sizes, n_queries, kernel_reps, args.out)


if __name__ == "__main__":
    main()
