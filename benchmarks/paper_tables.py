"""One benchmark per paper table/figure.

Exp-1 (Fig. 2): total MR query time — Base, Base*, ETE-reach, VTE-reach,
               Min-reach, TCI (HypED-analog), JAX-batched, kernel join.
Exp-2 (Tab. IV time): indexing time — Construct-Base / Construct /
               Construct* (+ the exact-necessity variant).
Exp-3 (Tab. IV space): |H|, |L|, |L*|, full adjacency N, peak
               neighbor-index M̂.
Exp-4 (Fig. 3): scalability — 20..100% hyperedge subsets.
Exp-5 (Fig. 4): epidemic case study on a co-location hypergraph.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.api import available_backends, build_engine
from repro.core import (Hypergraph, from_edge_lists, build_basic, build_fast,
                        minimize, exact_minimize, precompute_neighbors)
from .datasets import BENCH_DATASETS, make_dataset

__all__ = ["exp1_query_time", "exp2_indexing_time", "exp3_space",
           "exp4_scalability", "exp5_case_study", "engine_suite",
           "sharded_suite"]


def _timeit(fn: Callable, *, reps: int = 1) -> float:
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _query_pairs(h: Hypergraph, k: int = 1000, seed: int = 0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, h.n, k), rng.integers(0, h.n, k)


def exp1_query_time(dataset: str = "BK-s", n_q: int = 1000,
                    include_online: bool = True) -> List[Tuple[str, float, str]]:
    """Total time for n_q MR queries per method (paper Fig. 2).

    Every method is built and queried through the ``repro.api`` facade —
    the paper's method names map onto registry backends:
    Base/Base* -> "online", ETE-reach -> "ete", TCI -> "threshold",
    VTE-reach -> "hl-index" (unminimized), Min-reach -> "hl-index",
    Min-batched-jax -> its device snapshot, Sparse-frontier -> "frontier".
    """
    h = make_dataset(dataset)
    us, vs = _query_pairs(h, n_q)
    rows = []

    vte = build_engine(h, "hl-index", minimize_labels=False)
    mn = build_engine(h, "hl-index", index=vte.idx)   # reuse construction
    ete = build_engine(h, "ete")
    tci = build_engine(h, "threshold")

    if include_online:
        sub = min(n_q, 50)              # online is orders slower; extrapolate
        base = build_engine(h, "online", precompute=False)
        base_star = build_engine(h, "online")
        t = _timeit(lambda: [base.mr(int(u), int(v))
                             for u, v in zip(us[:sub], vs[:sub])])
        rows.append((f"exp1.{dataset}.Base", t / sub * 1e6, "per-query-us"))
        t = _timeit(lambda: [base_star.mr(int(u), int(v))
                             for u, v in zip(us[:sub], vs[:sub])])
        rows.append((f"exp1.{dataset}.Base*", t / sub * 1e6, "per-query-us"))

    t = _timeit(lambda: [ete.mr(int(u), int(v)) for u, v in zip(us, vs)])
    rows.append((f"exp1.{dataset}.ETE-reach", t / n_q * 1e6, "per-query-us"))
    t = _timeit(lambda: [tci.mr(int(u), int(v)) for u, v in zip(us, vs)])
    rows.append((f"exp1.{dataset}.TCI(HypED-like)", t / n_q * 1e6, "per-query-us"))
    t = _timeit(lambda: [vte.mr(int(u), int(v)) for u, v in zip(us, vs)])
    rows.append((f"exp1.{dataset}.VTE-reach", t / n_q * 1e6, "per-query-us"))
    t = _timeit(lambda: [mn.mr(int(u), int(v)) for u, v in zip(us, vs)])
    rows.append((f"exp1.{dataset}.Min-reach", t / n_q * 1e6, "per-query-us"))

    snap = mn.snapshot()
    _ = np.asarray(snap.mr(us, vs))     # compile
    t = _timeit(lambda: np.asarray(snap.mr(us, vs)), reps=5)
    rows.append((f"exp1.{dataset}.Min-batched-jax", t / n_q * 1e6,
                 "per-query-us"))

    # index-free sparse frontier engine (for graphs beyond dense scale)
    fr = build_engine(h, "frontier", rounds=min(h.m, 64))
    sub = min(n_q, 100)
    _ = fr.mr_batch(us[:4], vs[:4])                          # compile
    t = _timeit(lambda: fr.mr_batch(us[:sub], vs[:sub]))
    rows.append((f"exp1.{dataset}.Sparse-frontier", t / sub * 1e6,
                 "per-query-us"))
    return rows


def _bench_backend(prefix: str, builder: Callable, us, vs,
                   want: np.ndarray) -> List[Tuple[str, float, str]]:
    """Build, warm, time, and cross-validate one engine: emits
    ``{prefix}.build`` (total-us), ``{prefix}.batch-query``
    (per-query-us), ``{prefix}.agrees-with-oracle`` (bool; raises on
    disagreement).  jax dispatch is asynchronous, so the build clock only
    stops after ``block_until_built()`` — the engine-protocol hook async
    backends override — returns."""
    n_q = len(want)
    t0 = time.perf_counter()
    eng = builder()
    getattr(eng, "block_until_built", lambda: None)()
    t_build = time.perf_counter() - t0
    _ = eng.mr_batch(us, vs)          # compile/warm at the timed shape
    t0 = time.perf_counter()
    got = np.asarray(eng.mr_batch(us, vs))
    t_q = time.perf_counter() - t0
    agrees = np.array_equal(got.astype(np.int64), want)
    if not agrees:
        raise AssertionError(
            f"{prefix} disagrees with mst-oracle "
            f"({int((got.astype(np.int64) != want).sum())}/{n_q} mismatches)")
    return [(f"{prefix}.build", t_build * 1e6, "total-us"),
            (f"{prefix}.batch-query", t_q / n_q * 1e6, "per-query-us"),
            (f"{prefix}.agrees-with-oracle", float(agrees), "bool")]


def engine_suite(dataset: str = "ENG-s",
                 n_q: int = 128) -> List[Tuple[str, float, str]]:
    """Every registered backend through the one facade: build time, batched
    query time, and a cross-validation bit against the "mst-oracle"
    reference answers (1.0 = identical on all n_q pairs)."""
    h = make_dataset(dataset)
    us, vs = _query_pairs(h, n_q, seed=13)
    want = build_engine(h, "mst-oracle").mr_batch(us, vs).astype(np.int64)
    rows: List[Tuple[str, float, str]] = []
    for backend in available_backends():
        # no rounds cap for frontier: the agreement assert needs exactness
        rows += _bench_backend(f"engine.{dataset}.{backend}",
                               lambda b=backend: build_engine(h, b),
                               us, vs, want)
    return rows


def sharded_suite(dataset: str = "ENG-s", n_q: int = 128,
                  mesh=None) -> List[Tuple[str, float, str]]:
    """The ``sharded`` backend vs the single-device ``closure`` backend:
    build (= closure) time and batched query time for both collective
    schedules (allgather, ring), each cross-validated against the
    ``mst-oracle`` reference.  ``mesh=None`` uses a near-square 2-D mesh
    over every visible device — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to sweep N
    (``benchmarks/bench_sharded.py`` automates the 1/2/4 sweep)."""
    from repro.core.distributed import default_line_graph_mesh

    h = make_dataset(dataset)
    us, vs = _query_pairs(h, n_q, seed=13)
    want = build_engine(h, "mst-oracle").mr_batch(us, vs).astype(np.int64)
    if mesh is None:
        mesh = default_line_graph_mesh()
    ndev = int(mesh.devices.size)
    rows: List[Tuple[str, float, str]] = [
        (f"sharded.{dataset}.devices", float(ndev), "count")]
    rows += _bench_backend(f"sharded.{dataset}.closure-1dev",
                           lambda: build_engine(h, "closure"), us, vs, want)
    for sched in ("allgather", "ring"):
        rows += _bench_backend(
            f"sharded.{dataset}.sharded-{sched}-{ndev}dev",
            lambda s=sched: build_engine(h, "sharded", mesh=mesh, schedule=s),
            us, vs, want)
    return rows


def exp2_indexing_time(dataset: str = "NC-s",
                       include_basic: bool = True) -> List[Tuple[str, float, str]]:
    h = make_dataset(dataset)
    rows = []
    if include_basic:
        t = _timeit(lambda: build_basic(h))
        rows.append((f"exp2.{dataset}.Construct-Base", t * 1e6, "total-us"))
    t = _timeit(lambda: build_fast(h))
    rows.append((f"exp2.{dataset}.Construct", t * 1e6, "total-us"))
    idx = build_fast(h)
    t2 = _timeit(lambda: minimize(idx))
    rows.append((f"exp2.{dataset}.Construct*", (t + t2) * 1e6, "total-us"))
    t3 = _timeit(lambda: exact_minimize(idx))
    rows.append((f"exp2.{dataset}.Construct-exactmin", (t + t3) * 1e6,
                 "total-us"))
    return rows


def exp3_space(dataset: str = "BK-s") -> List[Tuple[str, float, str]]:
    h = make_dataset(dataset)
    idx = build_fast(h)
    mn = minimize(idx)
    nc = precompute_neighbors(h)
    rows = [
        (f"exp3.{dataset}.H-bytes", h.e_idx.nbytes + h.v_idx.nbytes, "bytes"),
        (f"exp3.{dataset}.L-bytes", idx.nbytes(), "bytes"),
        (f"exp3.{dataset}.Lmin-bytes", mn.nbytes(), "bytes"),
        (f"exp3.{dataset}.N-adjacency-bytes", nc.nbytes(), "bytes"),
        (f"exp3.{dataset}.M-peak-bytes",
         idx.stats.get("m_peak_entries", 0) * 12, "bytes"),
        (f"exp3.{dataset}.labels", idx.num_labels, "count"),
        (f"exp3.{dataset}.labels-min", mn.num_labels, "count"),
    ]
    return rows


def exp4_scalability(dataset: str = "WA-s") -> List[Tuple[str, float, str]]:
    h = make_dataset(dataset)
    rng = np.random.default_rng(0)
    rows = []
    for frac in (0.2, 0.4, 0.6, 0.8, 1.0):
        k = max(int(h.m * frac), 1)
        keep = rng.choice(h.m, size=k, replace=False)
        sub = from_edge_lists([h.edge(int(e)) for e in keep], n=h.n)
        t = _timeit(lambda: build_fast(sub))
        idx = build_fast(sub)
        t2 = _timeit(lambda: minimize(idx))
        rows.append((f"exp4.{dataset}.{int(frac*100)}pct.construct",
                     t * 1e6, "total-us"))
        rows.append((f"exp4.{dataset}.{int(frac*100)}pct.construct*",
                     (t + t2) * 1e6, "total-us"))
        rows.append((f"exp4.{dataset}.{int(frac*100)}pct.index-labels",
                     idx.num_labels, "count"))
    return rows


def exp5_case_study() -> List[Tuple[str, float, str]]:
    h = make_dataset("COLO")
    snap = build_engine(h, "hl-index").snapshot()
    patient_zero = int(np.argmax(h.vertex_degrees))
    others = np.arange(h.n)
    risk = np.asarray(snap.mr(np.full(h.n, patient_zero), others))
    rows = [
        ("exp5.colo.n-people", h.n, "count"),
        ("exp5.colo.n-groups", h.m, "count"),
        ("exp5.colo.max-risk", int(risk[others != patient_zero].max()
                                   if h.n > 1 else 0), "MR"),
        ("exp5.colo.at-risk>=2", int((risk >= 2).sum()), "count"),
        ("exp5.colo.at-risk>=3", int((risk >= 3).sum()), "count"),
    ]
    return rows
