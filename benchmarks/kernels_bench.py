"""Kernel-layer benchmarks.

On this CPU container the Pallas kernels execute under interpret mode
(semantics checks, not speed), so wall-clock numbers here time the XLA
CPU lowering of the *reference* formulations — the throughput signal is
the derived FLOP/byte counts used by the §Roofline closure analysis.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (random_hypergraph, distinct_thresholds,
                        maxmin_closure, threshold_closure_mr, maxmin_matmul)
from repro.kernels import ref

__all__ = ["closure_bench"]


def _t(fn, reps=3):
    fn()                                 # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def closure_bench(m: int = 512) -> List[Tuple[str, float, str]]:
    h = random_hypergraph(m // 2, m, min_size=2, max_size=6, seed=0)
    w = jnp.asarray(h.line_graph(np.int32).astype(np.float32))
    mm = w.shape[0]
    rounds = int(np.ceil(np.log2(mm)))
    thr = distinct_thresholds(np.asarray(w))
    s = thr.size
    rows = []

    f1 = jax.jit(lambda x: maxmin_closure(x, max_rounds=rounds))
    t1 = _t(lambda: f1(w))
    # maxmin closure: rounds × m³ compare+select ops (VPU work, 2 ops/elem)
    ops1 = rounds * 2 * mm ** 3
    rows.append((f"kernel.maxmin-closure.m{mm}", t1 * 1e6, "us-per-call"))
    rows.append((f"kernel.maxmin-closure.m{mm}.Gop", ops1 / 1e9, "Gops"))

    f2 = jax.jit(lambda x: threshold_closure_mr(x, thr, rounds=rounds))
    t2 = _t(lambda: f2(w))
    # threshold closure: rounds × S × 2m³ MAC (MXU work)
    ops2 = rounds * s * 2 * mm ** 3
    rows.append((f"kernel.threshold-closure.m{mm}.S{s}", t2 * 1e6,
                 "us-per-call"))
    rows.append((f"kernel.threshold-closure.m{mm}.Gop", ops2 / 1e9, "Gops"))

    # the single (max,min) matmul building block
    f3 = jax.jit(lambda x: maxmin_matmul(x, x))
    t3 = _t(lambda: f3(w))
    rows.append((f"kernel.maxmin-matmul.m{mm}", t3 * 1e6, "us-per-call"))
    return rows
