"""Kernel-layer benchmarks.

Two jobs:

1. ``closure_bench`` — the original closure-layer rows for
   ``benchmarks.run`` (name,value,unit CSV).
2. ``main`` / ``BENCH_kernels.json`` — the device-native query path
   measured against its host baselines: the Pallas ``label_join``
   batched merge-join vs the per-call host merge-join loop and the
   fused-XLA ``DeviceSnapshot`` batch, and ``maxmin_matmul`` vs its
   jnp reference — each with an analytic roofline utilization from the
   kernel's tiled HBM traffic model (benchmarks.roofline constants).

Honesty note baked into the JSON: on a CPU host the Pallas kernels run
under **interpret mode**, so their wall-clock measures the Python
interpreter, not device bandwidth — the roofline fractions are only
meaningful on a real TPU/GPU, and the CPU numbers exist to pin the
bytes/FLOP model and the byte-identical answers, not to claim speed.
Every label-join answer is asserted equal to the fused-XLA batch and
spot-checked against the independent mst-oracle.

  PYTHONPATH=src python -m benchmarks.kernels_bench            # full
  PYTHONPATH=src python -m benchmarks.kernels_bench --quick    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (random_hypergraph, distinct_thresholds,
                        maxmin_closure, threshold_closure_mr, maxmin_matmul)
from repro.kernels import ref

__all__ = ["closure_bench", "label_join_bench", "maxmin_bench", "main"]


def _t(fn, reps=3):
    fn()                                 # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def closure_bench(m: int = 512) -> List[Tuple[str, float, str]]:
    h = random_hypergraph(m // 2, m, min_size=2, max_size=6, seed=0)
    w = jnp.asarray(h.line_graph(np.int32).astype(np.float32))
    mm = w.shape[0]
    rounds = int(np.ceil(np.log2(mm)))
    thr = distinct_thresholds(np.asarray(w))
    s = thr.size
    rows = []

    f1 = jax.jit(lambda x: maxmin_closure(x, max_rounds=rounds))
    t1 = _t(lambda: f1(w))
    # maxmin closure: rounds × m³ compare+select ops (VPU work, 2 ops/elem)
    ops1 = rounds * 2 * mm ** 3
    rows.append((f"kernel.maxmin-closure.m{mm}", t1 * 1e6, "us-per-call"))
    rows.append((f"kernel.maxmin-closure.m{mm}.Gop", ops1 / 1e9, "Gops"))

    f2 = jax.jit(lambda x: threshold_closure_mr(x, thr, rounds=rounds))
    t2 = _t(lambda: f2(w))
    # threshold closure: rounds × S × 2m³ MAC (MXU work)
    ops2 = rounds * s * 2 * mm ** 3
    rows.append((f"kernel.threshold-closure.m{mm}.S{s}", t2 * 1e6,
                 "us-per-call"))
    rows.append((f"kernel.threshold-closure.m{mm}.Gop", ops2 / 1e9, "Gops"))

    # the single (max,min) matmul building block
    f3 = jax.jit(lambda x: maxmin_matmul(x, x))
    t3 = _t(lambda: f3(w))
    rows.append((f"kernel.maxmin-matmul.m{mm}", t3 * 1e6, "us-per-call"))
    return rows


# ---------------------------------------------------------------------------
# device-native query path: kernel vs host merge-join
# ---------------------------------------------------------------------------

def _roofline_fraction(bytes_moved: float, flops: float, secs: float):
    from benchmarks.roofline import HBM_BW, PEAK_FLOPS

    return {
        "hbm_bytes": bytes_moved,
        "flops": flops,
        "achieved_GBps": bytes_moved / secs / 1e9,
        "hbm_utilization": bytes_moved / secs / HBM_BW,
        "flops_utilization": flops / secs / PEAK_FLOPS,
    }


def label_join_bench(n: int, m: int, q: int, sample: int,
                     interpret: bool) -> dict:
    """Batched MR: per-call host merge-join vs fused-XLA snapshot batch
    vs the Pallas ``label_join`` kernel, answers pinned both ways."""
    from repro.api import build_engine
    from repro.core import MSTOracle
    from repro.core.query import KernelSnapshot

    h = random_hypergraph(n, m, seed=0)
    eng = build_engine(h, "hl-index")
    snap = eng.snapshot()
    kern = KernelSnapshot(snap, interpret=interpret)
    rng = np.random.default_rng(1)
    us = rng.integers(0, h.n, q).astype(np.int32)
    vs = rng.integers(0, h.n, q).astype(np.int32)

    sample = min(sample, q)
    t0 = time.perf_counter()
    host = [eng.mr(int(u), int(v))
            for u, v in zip(us[:sample], vs[:sample])]
    host_per_call = (time.perf_counter() - t0) / sample

    xla_t = _t(lambda: snap.mr(us, vs))
    kern_t = _t(lambda: kern.mr(us, vs))

    xla_out = np.asarray(snap.mr(us, vs)).astype(np.int64)
    kern_out = np.asarray(kern.mr(us, vs)).astype(np.int64)
    np.testing.assert_array_equal(kern_out, xla_out)   # byte-identical
    oracle = MSTOracle(h)
    for u, v, got in zip(us[:sample], vs[:sample], kern_out[:sample]):
        assert got == oracle.mr(int(u), int(v))
    assert host == list(kern_out[:sample])

    # tiled traffic model for grid (Q/bq, L/bl, L/bl), k innermost:
    # u rows (ranks+svals, int32 pairs) stream once per (i, j); v rows
    # re-stream for every (j, k); the [bq] output tile lives in VMEM
    # across the whole (j, k) sweep and is written once.
    L = int(snap.ranks.shape[1])
    bl = min(256, max(L, 1))
    qpad = max(q, 1)
    sweeps = max(1, -(-L // bl))
    bytes_moved = (qpad * L * 8) + (qpad * L * 8 * sweeps) + qpad * 4
    flops = 3.0 * qpad * L * L            # eq, min, max per rank pair

    return {
        "graph": {"n": h.n, "m": h.m, "label_width_L": L},
        "batch_q": q,
        "host_merge_join_per_call_us": host_per_call * 1e6,
        "host_merge_join_batch_us": host_per_call * q * 1e6,
        "xla_snapshot_batch_us": xla_t * 1e6,
        "pallas_label_join_batch_us": kern_t * 1e6,
        "interpret_mode": interpret,
        "answers_verified": int(q),
        "roofline": _roofline_fraction(bytes_moved, flops, kern_t),
    }


def maxmin_bench(mm: int, interpret: bool) -> dict:
    """One (max,min) contraction step of the sharded closure: Pallas
    kernel vs the jnp reference, both over the same [m, m] operand."""
    from repro.kernels.maxmin_matmul import maxmin_matmul_pallas

    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, 100, (mm, mm)).astype(np.int32))

    ref_fn = jax.jit(ref.maxmin_matmul_ref)
    ref_t = _t(lambda: ref_fn(a, a))
    kern_t = _t(lambda: maxmin_matmul_pallas(a, a, interpret=interpret))

    np.testing.assert_array_equal(
        np.asarray(maxmin_matmul_pallas(a, a, interpret=interpret)),
        np.asarray(ref_fn(a, a)))

    # tiled traffic, grid (M/bm, N/bn, K/bk) k innermost: a block (i, k)
    # streams once per j sweep, b block (k, j) once per (i, j, k); the
    # [bm, bn] accumulator is VMEM-resident across the k sweep.
    bm = bn = 128
    bytes_moved = (mm * mm * 4 * max(1, -(-mm // bn)) +
                   mm * mm * 4 * max(1, -(-mm // bm)) + mm * mm * 4)
    flops = 2.0 * mm ** 3                  # min + max per element

    return {
        "m": mm,
        "xla_reference_us": ref_t * 1e6,
        "pallas_kernel_us": kern_t * 1e6,
        "interpret_mode": interpret,
        "roofline": _roofline_fraction(bytes_moved, flops, kern_t),
    }


def run(n: int, m: int, q: int, sample: int, mm: int,
        out_path: str) -> dict:
    from repro.kernels.ops import use_interpret

    interpret = use_interpret()
    lj = label_join_bench(n, m, q, sample, interpret)
    mx = maxmin_bench(mm, interpret)
    print(f"label_join: host {lj['host_merge_join_batch_us']:.0f}us "
          f"(per-call x{q}) | xla batch {lj['xla_snapshot_batch_us']:.0f}us "
          f"| pallas {lj['pallas_label_join_batch_us']:.0f}us "
          f"(interpret={interpret})")
    print(f"maxmin_matmul m={mm}: xla ref {mx['xla_reference_us']:.0f}us "
          f"| pallas {mx['pallas_kernel_us']:.0f}us")
    doc = {
        "note": ("Pallas device-native query path vs host baselines.  "
                 "interpret_mode=true means the kernels ran under the "
                 "Pallas interpreter (no TPU/GPU on this host): their "
                 "wall-clock measures the interpreter, the roofline "
                 "utilizations are meaningful only on device, and the "
                 "numbers pin the traffic model and byte-identical "
                 "answers, not speed.  Every label_join answer is "
                 "asserted equal to the fused-XLA snapshot batch and "
                 "spot-checked against the mst-oracle."),
        "label_join": lj,
        "maxmin_matmul": mx,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes for the CI smoke job")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_kernels.json"))
    args = ap.parse_args()
    if args.quick:
        run(n=200, m=160, q=512, sample=128, mm=128, out_path=args.out)
    else:
        run(n=1000, m=800, q=2048, sample=256, mm=512, out_path=args.out)


if __name__ == "__main__":
    main()
