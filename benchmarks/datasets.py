"""Benchmark datasets.

The paper's 20 datasets are not redistributable in this offline container,
so the benches run on *synthetic stand-ins matched to the published
statistics* (|V|, |E|, η_avg scaled down to CPU-bench scale) plus the
structured generators (chains, co-location).  The mapping to the paper's
Table III is recorded in each entry; EXPERIMENTS.md reports both the
paper's numbers and ours side by side.

External hypergraphs load through the same entry point: any name ending
in ``.hif.json`` (or ``.hif``) is treated as a path to an HIF
(Hypergraph Interchange Format) file and imported via
``repro.store.read_hif`` — the published datasets, once obtained, drop
straight into every bench.
"""
from __future__ import annotations

import os
from typing import Dict, List, Tuple

import numpy as np

from repro.core import Hypergraph, random_hypergraph, colocation_hypergraph, \
    planted_chain_hypergraph, from_edge_lists
from repro.store import read_hif

__all__ = ["BENCH_DATASETS", "make_dataset"]

# name -> (paper analog, n, m, min_size, max_size, seed)
BENCH_DATASETS: Dict[str, Tuple[str, int, int, int, int, int]] = {
    "NC-s": ("NDC-classes (1.2k/1.2k, η=5)", 600, 620, 2, 8, 1),
    "SS-s": ("small-world (10k/10k, η=6.6)", 1500, 1500, 2, 7, 2),
    "BK-s": ("BrightKite (4.3k/5.2k, η=3.9)", 900, 1100, 2, 6, 3),
    "PS-s": ("primary-school (242/12.7k, η=126)", 120, 2500, 2, 5, 4),
    "EE-s": ("email-Eu (998/25.8k, η=85)", 400, 4000, 2, 6, 5),
    "WA-s": ("walmart-trips (89k/70k, η=5)", 4000, 3200, 2, 8, 6),
    # small enough that every registry backend (incl. the dense closure)
    # can be built and cross-validated in the engine suite
    "ENG-s": ("engine-suite synthetic (all backends)", 200, 256, 2, 6, 7),
}


def make_dataset(name: str) -> Hypergraph:
    if name.endswith((".hif.json", ".hif")):
        if not os.path.exists(name):
            raise FileNotFoundError(f"HIF dataset file not found: {name}")
        return read_hif(name)
    if name == "CHAIN":
        return planted_chain_hypergraph(20, 50, overlap=3, extra_size=2)
    if name == "COLO":
        return colocation_hypergraph(500, 20, 21, p_checkin=0.02, seed=0)
    analog, n, m, lo, hi, seed = BENCH_DATASETS[name]
    return random_hypergraph(n, m, min_size=lo, max_size=hi, seed=seed)
