"""Sharded-vs-closure benchmark sweep over host device counts.

Each device count needs its own process (``XLA_FLAGS=
--xla_force_host_platform_device_count=N`` must precede jax init), so the
parent spawns one worker per N, collects the ``sharded_suite`` rows, and
writes ``BENCH_sharded.json`` at the repo root — the accumulating record
of the perf trajectory (allgather vs ring, 1-4 host devices).

  PYTHONPATH=src python -m benchmarks.bench_sharded            # sweep
  PYTHONPATH=src python -m benchmarks.bench_sharded --worker   # one N

Host-CPU caveat recorded in the JSON: "devices" here are XLA host
platform devices carved out of one CPU, so multi-device timings measure
collective/partitioning *overhead*, not speed-up — the numbers to watch
are allgather vs ring deltas and the single-device parity with
``closure``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys


def worker(dataset: str, n_q: int) -> None:
    from . import paper_tables as pt

    rows = pt.sharded_suite(dataset, n_q=n_q)
    print(json.dumps([(name, float(val), unit) for name, val, unit in rows]))


def sweep(dataset: str, n_q: int, device_counts, out_path: str) -> dict:
    results = []
    for nd in device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={nd}"
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        cmd = [sys.executable, "-m", "benchmarks.bench_sharded", "--worker",
               "--dataset", dataset, "--n-q", str(n_q)]
        out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             cwd=os.path.join(os.path.dirname(__file__), ".."))
        if out.returncode != 0:
            raise RuntimeError(f"worker (devices={nd}) failed:\n{out.stderr}")
        rows = json.loads(out.stdout.strip().splitlines()[-1])
        results.append({"devices": nd, "rows": rows})
        for name, val, unit in rows:
            print(f"{name},{val:.3f},{unit}")
    doc = {
        "dataset": dataset,
        "n_q": n_q,
        "note": ("XLA host-platform devices on one CPU: multi-device rows "
                 "measure collective overhead, not speed-up; compare "
                 "allgather vs ring and 1-device parity with 'closure'"),
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}", file=sys.stderr)
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true",
                    help="measure on this process's devices, print JSON rows")
    ap.add_argument("--dataset", default="ENG-s")
    ap.add_argument("--n-q", type=int, default=128)
    ap.add_argument("--devices", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_sharded.json"))
    args = ap.parse_args()
    if args.worker:
        worker(args.dataset, args.n_q)
    else:
        sweep(args.dataset, args.n_q, args.devices, args.out)


if __name__ == "__main__":
    main()
