"""Serial vs sharded HL-index construction across graph sizes.

The tentpole claim of the sharded builder (repro.core.hlindex.
build_sharded) is tracked as numbers, not prose: on each swept graph the
serial ``build_fast`` and the sharded builder (shared neighbor-index
CSR, per-device component shards, forked workers) run on identical
input, labels are asserted **byte-identical**, sampled answers are
pinned to the independent ``mst-oracle``, and the wall times land in
``BENCH_construction.json`` at the repo root — the accumulating record
the CI smoke job regenerates at tiny sizes.

On a multi-device host (XLA_FLAGS=--xla_force_host_platform_device_
count=N) the neighbor overlaps are computed on the mesh and the worker
count follows the device count, so the sweep doubles as the ≥2-device
scaling record.

  PYTHONPATH=src python -m benchmarks.bench_construction            # sweep
  PYTHONPATH=src python -m benchmarks.bench_construction --quick    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def component_graph(components: int, n_per: int, m_per: int,
                    seed: int = 0):
    """``components`` disjoint random blocks — the multi-component regime
    sharded construction partitions (one block ≈ one line-graph
    component, up to random fragmentation inside a block)."""
    from repro.core import from_edge_lists, random_hypergraph

    edges = []
    offset = 0
    for c in range(components):
        block = random_hypergraph(n_per, m_per, seed=seed * 1000 + c)
        for e in range(block.m):
            edges.append((block.edge(e) + offset).tolist())
        offset += n_per
    return from_edge_lists(edges, n=offset)


def bench_size(components: int, n_per: int, m_per: int, *, mesh, workers,
               n_queries: int, reps: int, seed: int = 0) -> dict:
    from repro.core import MSTOracle, build_fast, build_sharded, mr_query

    h = component_graph(components, n_per, m_per, seed=seed)
    num_shards = max(int(mesh.devices.size), workers, 1)

    # one timing loop per variant (not interleaved) so each row's min
    # sees the same allocator/cache state across its reps
    serial_s, sharded_s, pool_s = [], [], []
    serial_idx = sharded_idx = None
    for _ in range(reps):
        t0 = time.perf_counter()
        serial_idx = build_fast(h)
        serial_s.append(time.perf_counter() - t0)
    for _ in range(reps):
        # the engine's default sharded path (workers unspecified): the
        # auto work gate engages the fork pool only past
        # _POOL_MIN_NEIGHBOR_ENTRIES, so what this row measures is
        # exactly what `build_engine(h, "hl-index", mesh=mesh)` would
        # run — the headline row
        t0 = time.perf_counter()
        sharded_idx = build_sharded(h, mesh=mesh, num_shards=num_shards)
        sharded_s.append(time.perf_counter() - t0)
    for _ in range(reps):
        # the fork-pool variant — pays off once per-shard traversals
        # outweigh the pool's fixed start/pickle cost and the host has
        # cores to spare (recorded either way so the trade-off is
        # visible in the JSON)
        t0 = time.perf_counter()
        pool_idx = build_sharded(h, mesh=mesh, num_shards=num_shards,
                                 workers=workers)
        pool_s.append(time.perf_counter() - t0)

    # byte-identity on every variant's final output
    for other in (sharded_idx, pool_idx):
        assert np.array_equal(serial_idx.rank, other.rank)
        for u in range(h.n):
            assert (serial_idx.labels_rank[u].tobytes()
                    == other.labels_rank[u].tobytes())
            assert (serial_idx.labels_s[u].tobytes()
                    == other.labels_s[u].tobytes())

    # sampled answers pinned to the independent oracle
    oracle = MSTOracle(h)
    rng = np.random.default_rng(seed)
    us = rng.integers(0, h.n, n_queries)
    vs = rng.integers(0, h.n, n_queries)
    for u, v in zip(us, vs):
        want = oracle.mr(int(u), int(v))
        assert mr_query(sharded_idx, int(u), int(v)) == want, (u, v)

    serial_best = min(serial_s)
    sharded_best = min(sharded_s)
    return {
        "components": components,
        "n": int(h.n),
        "m": int(h.m),
        "nnz": int(h.nnz),
        "labels": int(serial_idx.num_labels),
        "shards": int(sharded_idx.stats["shards"]),
        "workers": workers,
        "serial_s": serial_best,
        "sharded_s": sharded_best,
        "sharded_pool_s": min(pool_s),
        "speedup": serial_best / max(sharded_best, 1e-12),
        "pool_speedup": serial_best / max(min(pool_s), 1e-12),
        "answers_checked": int(n_queries),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes for the CI smoke job")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=50)
    ap.add_argument("--workers", type=int, default=None,
                    help="shard worker processes (default: device count)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_construction.json"))
    args = ap.parse_args()

    import jax
    from repro.core.distributed import default_line_graph_mesh

    mesh = default_line_graph_mesh()
    devices = int(jax.device_count())
    cpus = os.cpu_count() or 1
    workers = (args.workers if args.workers is not None
               else max(min(devices, cpus), 2))
    if args.quick:
        sizes = [(4, 40, 30), (4, 80, 60)]
        reps = args.reps or 1
    else:
        sizes = [(4, 60, 50), (8, 150, 500), (8, 300, 900), (8, 300, 1400)]
        reps = args.reps or 3

    results = [bench_size(c, n, m, mesh=mesh, workers=workers,
                          n_queries=args.n_queries, reps=reps)
               for c, n, m in sizes]
    for row in results:
        print(f"construction m={row['m']} n={row['n']} "
              f"({row['components']} blocks, {row['shards']} shards): "
              f"serial {row['serial_s']:.3f}s vs sharded "
              f"{row['sharded_s']:.3f}s -> {row['speedup']:.2f}x "
              f"(pool x{row['workers']}: {row['sharded_pool_s']:.3f}s -> "
              f"{row['pool_speedup']:.2f}x; {row['answers_checked']} "
              f"answers oracle-checked, labels byte-identical)")
    doc = {
        "devices": devices,
        "cpus": cpus,
        "mesh_shape": {k: int(v) for k, v in
                       zip(mesh.axis_names,
                           np.asarray(mesh.devices).shape)},
        "workers": workers,
        "reps": reps,
        "note": ("build_sharded (shared NeighborCSR + per-device "
                 "component shards + reconciled merge) vs serial "
                 "build_fast on identical graphs; labels asserted "
                 "byte-identical and sampled answers asserted equal to "
                 "mst-oracle on every swept size.  `sharded_s` is the "
                 "engine's default path — workers unspecified, so the "
                 "auto gate engages the fork pool only past "
                 "_POOL_MIN_NEIGHBOR_ENTRIES neighbor entries (at the "
                 "swept sizes here it resolves inline); "
                 "`sharded_pool_s` forces forked workers, whose fixed "
                 "start+pickle cost only amortizes once per-shard "
                 "traversals run long enough — on few-core hosts the "
                 "default row is the honest one."),
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")

    largest = results[-1]
    if largest["speedup"] <= 1.0:
        msg = (f"sharded build not faster at the largest size: "
               f"{largest['speedup']:.2f}x")
        if args.quick:
            print(f"WARNING: {msg} (quick mode: sizes too small to "
                  f"amortize the pool)")
        elif devices >= 2:
            raise SystemExit(f"FAIL: {msg} on a {devices}-device mesh")
        else:
            print(f"WARNING: {msg} (single-device host)")


if __name__ == "__main__":
    main()
