#!/usr/bin/env python
"""Docs consistency guard (run by the CI `docs` job).

Two checks, so documentation cannot silently drift from the code:

1. Every relative markdown link in README.md and docs/*.md resolves to
   an existing file or directory.
2. Every backend name in the live engine registry
   (`repro.api.available_backends()`) appears as a row in the backend
   table of docs/ARCHITECTURE.md — registering a backend without
   documenting it fails the build.

  PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```.*?```", re.S)
_TABLE_ROW = re.compile(r"^\|\s*`([^`]+)`", re.M)


def doc_files():
    docs = ROOT / "docs"
    return [ROOT / "README.md"] + (sorted(docs.glob("*.md"))
                                   if docs.is_dir() else [])


def check_links():
    problems = []
    for md in doc_files():
        text = _FENCE.sub("", md.read_text())
        for match in _LINK.finditer(text):
            target = match.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#")[0]
            if path and not (md.parent / path).exists():
                problems.append(
                    f"{md.relative_to(ROOT)}: broken link -> {target}")
    return problems


def check_backend_table():
    from repro.api import available_backends

    arch = ROOT / "docs" / "ARCHITECTURE.md"
    if not arch.is_file():
        return ["docs/ARCHITECTURE.md is missing"]
    documented = set(_TABLE_ROW.findall(arch.read_text()))
    return [f"docs/ARCHITECTURE.md backend table is missing registered "
            f"backend `{name}`"
            for name in available_backends() if name not in documented]


def main() -> int:
    problems = check_links() + check_backend_table()
    for p in problems:
        print(f"FAIL: {p}")
    if problems:
        return 1
    from repro.api import available_backends
    print(f"docs OK: links resolve in {len(doc_files())} files; "
          f"backend table covers {available_backends()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
