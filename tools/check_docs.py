#!/usr/bin/env python
"""Docs consistency guard (run by the CI `docs` job).

Nine checks, so documentation cannot silently drift from the code:

1. Every relative markdown link in README.md and docs/*.md resolves to
   an existing file or directory.
2. Every backend name in the live engine registry
   (`repro.api.available_backends()`) appears as a row in the backend
   table of docs/ARCHITECTURE.md — registering a backend without
   documenting it fails the build.
3. The update-capability table in docs/ARCHITECTURE.md (rows of the
   form ``| `name` | scoped | ... |``) covers every registered backend
   and agrees with the live `repro.api.update_capabilities()` —
   misdeclaring how a backend absorbs hyperedge updates fails the
   build.
4. The serving request-type table in docs/ARCHITECTURE.md (rows of the
   form ``| `MRRequest` | `mr` | ... |``) matches the live
   `repro.serve.reach_service.REQUEST_TYPES` both ways — adding,
   renaming, or removing a request type without documenting it fails
   the build.
5. The construction-mode table in docs/ARCHITECTURE.md (rows of the
   form ``| `serial` | `build_fast` | ... |``) matches the live
   `repro.core.hlindex.CONSTRUCTION_MODES` both ways — documenting a
   builder option that does not exist, or adding one without
   documenting it, fails the build.
6. The on-disk format-version table in docs/ARCHITECTURE.md (rows of
   the form ``| `1` | `aligned-segments-v1` | ... |``) matches the live
   `repro.store.FORMAT_REGISTRY` both ways — shipping a format version
   the docs don't describe, or documenting one the code cannot read,
   fails the build.
7. The kernel-capability table in docs/ARCHITECTURE.md (rows of the
   form ``| `label_join` | `label_join_ref` | VPU | ... |``) matches
   the live `repro.kernels.KERNEL_REGISTRY` both ways — name, oracle,
   and compute unit; shipping a Pallas kernel without a doc row, or
   documenting one the registry does not have, fails the build.
8. The "Multi-tenant serving" section of docs/ARCHITECTURE.md matches
   the live scheduling surface both ways: its priority-class table
   (rows ``| `interactive` | 0 | ... |``) against
   `repro.serve.scheduler.PRIORITY_CLASSES` (names and band numbers),
   and its request-field table (rows ``| `tenant` | `str` |
   `"default"` | ... |``) against `dataclasses.fields(Request)` (names
   and defaults) — adding a priority class or a request metadata field
   without documenting it, or vice versa, fails the build.
9. The workload-capability table in the "Workloads" section of
   docs/ARCHITECTURE.md (header ``| backend | `witness` | ... |``,
   rows ``| `hl-index` | yes | ... |``) matches the live
   `repro.api.workload_capabilities()` both ways — the header must
   list exactly `WORKLOAD_OPS` in order, every registered backend
   needs a row, every row must agree cell-for-cell, and documenting a
   backend the registry does not have fails the build.

  PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```.*?```", re.S)
_TABLE_ROW = re.compile(r"^\|\s*`([^`]+)`", re.M)
_CAPABILITY_ROW = re.compile(
    r"^\|\s*`([^`]+)`\s*\|\s*(scoped|incremental|rebuild|unsupported)\s*\|",
    re.M)
_REQUEST_ROW = re.compile(
    r"^\|\s*`(\w+Request)`\s*\|\s*`(\w+)`\s*\|", re.M)
_CONSTRUCTION_ROW = re.compile(
    r"^\|\s*`(\w+)`\s*\|\s*`(build_\w+)`\s*\|", re.M)
# a digit-only first cell is unique to the format-version table
_FORMAT_ROW = re.compile(r"^\|\s*`(\d+)`\s*\|\s*`([\w.-]+)`\s*\|", re.M)
# a `*_ref` second cell is unique to the kernel-capability table
_KERNEL_ROW = re.compile(
    r"^\|\s*`(\w+)`\s*\|\s*`(\w+_ref)`\s*\|\s*(\w+)\s*\|", re.M)
# the multi-tenant rows are scoped to their section (see _section), so
# these only need to be unique within it: a bare-integer second cell is
# the priority-class table, a backticked third cell the field table
_PRIORITY_ROW = re.compile(r"^\|\s*`(\w+)`\s*\|\s*(\d+)\s*\|", re.M)
_FIELD_ROW = re.compile(
    r"^\|\s*`(\w+)`\s*\|\s*`[^`]+`\s*\|\s*`([^`]+)`\s*\|", re.M)


def _section(text: str, title: str) -> str:
    """The body of one ``## title`` section (empty if absent)."""
    match = re.search(rf"^## {re.escape(title)}$(.*?)(?=^## |\Z)",
                      text, re.M | re.S)
    return match.group(1) if match else ""


def doc_files():
    docs = ROOT / "docs"
    return [ROOT / "README.md"] + (sorted(docs.glob("*.md"))
                                   if docs.is_dir() else [])


def check_links():
    problems = []
    for md in doc_files():
        text = _FENCE.sub("", md.read_text())
        for match in _LINK.finditer(text):
            target = match.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#")[0]
            if path and not (md.parent / path).exists():
                problems.append(
                    f"{md.relative_to(ROOT)}: broken link -> {target}")
    return problems


def check_backend_table():
    from repro.api import available_backends

    arch = ROOT / "docs" / "ARCHITECTURE.md"
    if not arch.is_file():
        return ["docs/ARCHITECTURE.md is missing"]
    # catalogue rows only: a row in the update-capability table (second
    # column is a capability word) must not satisfy this check, or
    # deleting a backend's catalogue row would go unnoticed
    documented = {name for line in arch.read_text().splitlines()
                  if (match := _TABLE_ROW.match(line)) is not None
                  and not _CAPABILITY_ROW.match(line)
                  for name in [match.group(1)]}
    return [f"docs/ARCHITECTURE.md backend table is missing registered "
            f"backend `{name}`"
            for name in available_backends() if name not in documented]


def check_update_capability_table():
    from repro.api import update_capabilities

    arch = ROOT / "docs" / "ARCHITECTURE.md"
    if not arch.is_file():
        return ["docs/ARCHITECTURE.md is missing"]
    documented = dict(_CAPABILITY_ROW.findall(arch.read_text()))
    problems = []
    for name, cap in update_capabilities().items():
        if name not in documented:
            problems.append(
                f"docs/ARCHITECTURE.md update-capability table is missing "
                f"registered backend `{name}` (declared: {cap})")
        elif documented[name] != cap:
            problems.append(
                f"docs/ARCHITECTURE.md declares `{name}` updates as "
                f"'{documented[name]}' but the registry says '{cap}'")
    return problems


def check_request_type_table():
    from repro.serve.reach_service import REQUEST_TYPES

    arch = ROOT / "docs" / "ARCHITECTURE.md"
    if not arch.is_file():
        return ["docs/ARCHITECTURE.md is missing"]
    documented = {kind: cls_name
                  for cls_name, kind in _REQUEST_ROW.findall(arch.read_text())}
    problems = []
    for kind, cls in REQUEST_TYPES.items():
        if kind not in documented:
            problems.append(
                f"docs/ARCHITECTURE.md request-type table is missing the "
                f"`{cls.__name__}` (kind `{kind}`) row")
        elif documented[kind] != cls.__name__:
            problems.append(
                f"docs/ARCHITECTURE.md documents kind `{kind}` as "
                f"`{documented[kind]}` but the live service class is "
                f"`{cls.__name__}`")
    for kind in documented:
        if kind not in REQUEST_TYPES:
            problems.append(
                f"docs/ARCHITECTURE.md documents request kind `{kind}` "
                f"(`{documented[kind]}`) that the live "
                f"repro.serve.reach_service.REQUEST_TYPES does not have")
    return problems


def check_construction_table():
    from repro.core.hlindex import CONSTRUCTION_MODES

    arch = ROOT / "docs" / "ARCHITECTURE.md"
    if not arch.is_file():
        return ["docs/ARCHITECTURE.md is missing"]
    documented = dict(_CONSTRUCTION_ROW.findall(arch.read_text()))
    problems = []
    for mode, fn in CONSTRUCTION_MODES.items():
        if mode not in documented:
            problems.append(
                f"docs/ARCHITECTURE.md construction table is missing the "
                f"`{mode}` (builder `{fn.__name__}`) row")
        elif documented[mode] != fn.__name__:
            problems.append(
                f"docs/ARCHITECTURE.md documents construction mode "
                f"`{mode}` as `{documented[mode]}` but the live builder "
                f"is `{fn.__name__}`")
    for mode in documented:
        if mode not in CONSTRUCTION_MODES:
            problems.append(
                f"docs/ARCHITECTURE.md documents construction mode "
                f"`{mode}` (`{documented[mode]}`) that the live "
                f"repro.core.hlindex.CONSTRUCTION_MODES does not have")
    return problems


def check_format_table():
    from repro.store import FORMAT_REGISTRY

    arch = ROOT / "docs" / "ARCHITECTURE.md"
    if not arch.is_file():
        return ["docs/ARCHITECTURE.md is missing"]
    documented = {int(v): layout
                  for v, layout in _FORMAT_ROW.findall(arch.read_text())}
    problems = []
    for version, layout in FORMAT_REGISTRY.items():
        if version not in documented:
            problems.append(
                f"docs/ARCHITECTURE.md format-version table is missing "
                f"on-disk format `{version}` (layout `{layout}`)")
        elif documented[version] != layout:
            problems.append(
                f"docs/ARCHITECTURE.md documents on-disk format "
                f"`{version}` as `{documented[version]}` but the live "
                f"repro.store.FORMAT_REGISTRY says `{layout}`")
    for version in documented:
        if version not in FORMAT_REGISTRY:
            problems.append(
                f"docs/ARCHITECTURE.md documents on-disk format "
                f"`{version}` (`{documented[version]}`) that the live "
                f"repro.store.FORMAT_REGISTRY cannot read")
    return problems


def check_kernel_table():
    from repro.kernels import KERNEL_REGISTRY

    arch = ROOT / "docs" / "ARCHITECTURE.md"
    if not arch.is_file():
        return ["docs/ARCHITECTURE.md is missing"]
    documented = {name: (oracle, unit)
                  for name, oracle, unit
                  in _KERNEL_ROW.findall(arch.read_text())}
    problems = []
    for name, spec in KERNEL_REGISTRY.items():
        if name not in documented:
            problems.append(
                f"docs/ARCHITECTURE.md kernel-capability table is missing "
                f"registered kernel `{name}` (oracle "
                f"`{spec.reference.__name__}`, unit {spec.unit})")
            continue
        oracle, unit = documented[name]
        if oracle != spec.reference.__name__:
            problems.append(
                f"docs/ARCHITECTURE.md documents kernel `{name}` with "
                f"oracle `{oracle}` but the registry says "
                f"`{spec.reference.__name__}`")
        if unit != spec.unit:
            problems.append(
                f"docs/ARCHITECTURE.md documents kernel `{name}` on unit "
                f"{unit} but the registry says {spec.unit}")
    for name in documented:
        if name not in KERNEL_REGISTRY:
            problems.append(
                f"docs/ARCHITECTURE.md documents kernel `{name}` that the "
                f"live repro.kernels.KERNEL_REGISTRY does not have")
    return problems


def check_multitenant_section():
    import dataclasses

    from repro.serve.reach_service import Request
    from repro.serve.scheduler import PRIORITY_CLASSES

    arch = ROOT / "docs" / "ARCHITECTURE.md"
    if not arch.is_file():
        return ["docs/ARCHITECTURE.md is missing"]
    body = _section(arch.read_text(), "Multi-tenant serving")
    if not body:
        return ["docs/ARCHITECTURE.md has no '## Multi-tenant serving' "
                "section"]
    problems = []

    documented_classes = {name: int(band)
                          for name, band in _PRIORITY_ROW.findall(body)}
    for name, band in PRIORITY_CLASSES.items():
        if name not in documented_classes:
            problems.append(
                f"docs/ARCHITECTURE.md priority-class table is missing "
                f"class `{name}` (band {band})")
        elif documented_classes[name] != band:
            problems.append(
                f"docs/ARCHITECTURE.md documents priority class `{name}` "
                f"as band {documented_classes[name]} but the live "
                f"PRIORITY_CLASSES says {band}")
    for name in documented_classes:
        if name not in PRIORITY_CLASSES:
            problems.append(
                f"docs/ARCHITECTURE.md documents priority class `{name}` "
                f"that the live repro.serve.scheduler.PRIORITY_CLASSES "
                f"does not have")

    # default shown with double quotes in the docs; repr() uses single
    documented_fields = {name: default.replace("'", '"')
                         for name, default in _FIELD_ROW.findall(body)}
    live_fields = {f.name: repr(f.default).replace("'", '"')
                   for f in dataclasses.fields(Request)}
    for name, default in live_fields.items():
        if name not in documented_fields:
            problems.append(
                f"docs/ARCHITECTURE.md request-field table is missing the "
                f"`{name}` (default {default}) row")
        elif documented_fields[name] != default:
            problems.append(
                f"docs/ARCHITECTURE.md documents request field `{name}` "
                f"with default {documented_fields[name]} but the live "
                f"Request dataclass says {default}")
    for name in documented_fields:
        if name not in live_fields:
            problems.append(
                f"docs/ARCHITECTURE.md documents request field `{name}` "
                f"that the live repro.serve.reach_service.Request does "
                f"not have")
    return problems


def check_workload_table():
    from repro.api import WORKLOAD_OPS, workload_capabilities

    arch = ROOT / "docs" / "ARCHITECTURE.md"
    if not arch.is_file():
        return ["docs/ARCHITECTURE.md is missing"]
    body = _section(arch.read_text(), "Workloads")
    if not body:
        return ["docs/ARCHITECTURE.md has no '## Workloads' section"]
    header = re.search(r"^\|\s*backend\s*\|(.+)\|\s*$", body, re.M)
    if header is None:
        return ["docs/ARCHITECTURE.md Workloads section has no "
                "'| backend | ...' capability table header"]
    doc_ops = tuple(re.findall(r"`(\w+)`", header.group(1)))
    if doc_ops != tuple(WORKLOAD_OPS):
        return [f"docs/ARCHITECTURE.md workload-capability table header "
                f"lists ops {list(doc_ops)} but the live WORKLOAD_OPS is "
                f"{list(WORKLOAD_OPS)}"]
    documented = {}
    for line in body.splitlines():
        row = re.match(r"^\|\s*`([\w-]+)`\s*\|(.+)\|\s*$", line)
        if row is None:
            continue
        cells = [c.strip() for c in row.group(2).split("|")]
        if len(cells) == len(doc_ops) and set(cells) <= {"yes", "no"}:
            documented[row.group(1)] = {
                op: cell == "yes" for op, cell in zip(doc_ops, cells)}
    problems = []
    live = workload_capabilities()
    for name, caps in live.items():
        if name not in documented:
            problems.append(
                f"docs/ARCHITECTURE.md workload-capability table is "
                f"missing registered backend `{name}`")
        elif documented[name] != caps:
            problems.append(
                f"docs/ARCHITECTURE.md workload-capability row for "
                f"`{name}` says {documented[name]} but the live registry "
                f"says {caps}")
    for name in documented:
        if name not in live:
            problems.append(
                f"docs/ARCHITECTURE.md workload-capability table "
                f"documents backend `{name}` that the live registry does "
                f"not have")
    return problems


def main() -> int:
    problems = (check_links() + check_backend_table()
                + check_update_capability_table()
                + check_request_type_table()
                + check_construction_table()
                + check_format_table()
                + check_kernel_table()
                + check_multitenant_section()
                + check_workload_table())
    for p in problems:
        print(f"FAIL: {p}")
    if problems:
        return 1
    from repro.api import (available_backends, update_capabilities,
                           workload_capabilities)
    from repro.core.hlindex import CONSTRUCTION_MODES
    from repro.kernels import KERNEL_REGISTRY
    from repro.serve.reach_service import REQUEST_TYPES
    from repro.serve.scheduler import PRIORITY_CLASSES
    from repro.store import FORMAT_REGISTRY
    print(f"docs OK: links resolve in {len(doc_files())} files; "
          f"backend table covers {available_backends()}; update "
          f"capabilities match {update_capabilities()}; request types "
          f"match {sorted(REQUEST_TYPES)}; construction modes match "
          f"{sorted(CONSTRUCTION_MODES)}; on-disk formats match "
          f"{FORMAT_REGISTRY}; kernel table matches "
          f"{sorted(KERNEL_REGISTRY)}; multi-tenant section matches "
          f"{PRIORITY_CLASSES} and the Request metadata fields; workload "
          f"capabilities match for "
          f"{sorted(workload_capabilities())}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
