"""Scoped sharded maintenance: incremental closure blocks + parallel
component splice.  Both ``sharded`` regimes must answer byte-identically
to a fresh rebuild (and the MST oracle) after *every* edit, on 1-, 2-
and 4-device meshes, while reporting true dirty rows so replica serving
patches rows instead of re-landing whole snapshots."""
import numpy as np
import pytest

from repro.api import build_engine, update_capabilities
from repro.core import (MSTOracle, apply_edge_edits, from_edge_lists,
                        planted_chain_hypergraph)
from repro.core.distributed import ShardedEngine


def _assert_matches_fresh(eng, h, *, labels):
    """Every pair answered identically to a from-scratch sharded build
    of the same regime, and both agree with the MST oracle."""
    fresh = build_engine(h, "sharded", build_labels=labels)
    mst = MSTOracle(h)
    if h.n == 0:
        return
    us, vs = np.meshgrid(np.arange(h.n), np.arange(h.n))
    us, vs = us.ravel(), vs.ravel()
    got = np.asarray(eng.mr_batch(us, vs)).astype(np.int64)
    ref = np.asarray(fresh.mr_batch(us, vs)).astype(np.int64)
    np.testing.assert_array_equal(got, ref)
    want = np.array([mst.mr(int(u), int(v)) for u, v in zip(us, vs)],
                    np.int64)
    np.testing.assert_array_equal(got, want)


def test_sharded_capability_is_scoped():
    # the last rebuild-only update path is gone: both regimes are scoped
    assert update_capabilities()["sharded"] == "scoped"
    assert ShardedEngine.update_capability == "scoped"


@pytest.mark.parametrize("labels", [False, True],
                         ids=["closure", "labels"])
def test_deterministic_churn_matches_fresh(labels):
    # hand-written script covering insert-only, delete-only, mixed,
    # component-merging and vertex-growing steps
    h = planted_chain_hypergraph(3, 4, overlap=2, extra_size=2, seed=0)
    eng = build_engine(h, "sharded", build_labels=labels)
    script = [
        ([[0, 1, 2]], []),                     # insert into chain 0
        ([], [0]),                             # delete a chain-0 edge
        ([[0, 5], [2, 3, 4]], [1, 3]),         # mixed batch
        ([[int(h.edge(0)[0]), h.n + 1]], []),  # grow the vertex set
    ]
    for ins, dels in script:
        cur = eng.h
        dels = [d for d in dels if d < cur.m]
        eng.update(inserts=ins, deletes=dels)
        h2, _, _ = apply_edge_edits(cur, ins, dels)
        _assert_matches_fresh(eng, h2, labels=labels)


@pytest.mark.parametrize("labels", [False, True],
                         ids=["closure", "labels"])
def test_update_to_empty_and_back(labels):
    h = from_edge_lists([[0, 1], [1, 2], [3, 4]], n=5)
    eng = build_engine(h, "sharded", build_labels=labels)
    eng.update(deletes=list(range(h.m)))
    assert eng.h.m == 0
    assert int(eng.mr(0, 2)) == 0 and int(eng.mr(1, 1)) == 0
    eng.update(inserts=[[0, 1, 2], [2, 3]])
    h2 = from_edge_lists([[0, 1, 2], [2, 3]], n=5)
    _assert_matches_fresh(eng, h2, labels=labels)
    # and once more past the original edge count (slot-space growth)
    eng.update(inserts=[[3, 4], [0, 4], [1, 3, 4]])
    h3 = from_edge_lists([[0, 1, 2], [2, 3], [3, 4], [0, 4], [1, 3, 4]],
                         n=5)
    _assert_matches_fresh(eng, h3, labels=labels)


@pytest.mark.parametrize("labels", [False, True],
                         ids=["closure", "labels"])
def test_component_local_edit_reports_dirty_rows(labels):
    # true ``refreshed_vertices``: an edit confined to one component
    # must dirty only that component's vertices, not the whole graph
    h = planted_chain_hypergraph(4, 4, overlap=2, extra_size=2, seed=1)
    eng = build_engine(h, "sharded", build_labels=labels)
    basis = eng.snapshot()
    v0 = int(h.edge(0)[0])
    eng.update(inserts=[[v0, v0 + 1, v0 + 2]])
    snap, dirty = eng.snapshot_delta(basis)
    assert dirty is not None, "scoped update degraded to a full reland"
    assert 0 < dirty.size < h.n
    assert eng.last_snapshot_refresh_rows == dirty.size
    # the patched snapshot itself answers like a fresh one (conformance
    # covers the query path; this pins the delta path specifically)
    assert snap.version == eng.version


def test_replica_group_sharded_churn_patches_rows():
    # the regression the issue names: under sharded churn the replica
    # group must fan out row patches, never re-land whole snapshots
    from repro.api import MRRequest, ServiceConfig
    from repro.core.distributed import default_line_graph_mesh
    from repro.serve.replicas import ReplicaGroup

    edges = [[0, 1, 2], [1, 2, 3],            # chain A
             [10, 11, 12], [11, 12, 13]]      # chain B
    for i in range(6):                         # chain C pins the geometry
        edges.append([20 + 2 * i, 21 + 2 * i, 22 + 2 * i, 23 + 2 * i])
    h = from_edge_lists(edges)
    rng = np.random.default_rng(7)
    # paired delete+insert batches recycle freed slots, so neither
    # regime's resident geometry grows: deltas must land as row patches
    script = [([[0, 1, 3]], [0]),              # swap a chain-A edge
              ([[10, 12, 13]], [1]),           # swap a chain-B edge
              ([[0, 1, 2, 3]], [0])]           # and chain A again
    for labels in (False, True):
        eng = build_engine(h, "sharded", build_labels=labels)
        grp = ReplicaGroup(eng, 3, mesh=default_line_graph_mesh(),
                           config=ServiceConfig(max_batch=32), start=False)
        for ins, dels in script:
            cur = grp.engine.h
            mst = MSTOracle(cur)
            reqs = [MRRequest(int(rng.integers(cur.n)),
                              int(rng.integers(cur.n)))
                    for _ in range(40)]
            futs = grp.submit_many(reqs)
            grp.drain()
            for rq, f in zip(reqs, futs):
                assert f.result() == mst.mr(rq.u, rq.v)
            grp.update(inserts=ins, deletes=dels)
        grp.submit(MRRequest(0, 3))
        grp.drain()
        rstats = grp.replica_stats()
        assert all(r["full_relands"] == 1 for r in rstats), (labels, rstats)
        assert all(r["rows_patched"] > 0 for r in rstats), (labels, rstats)


def test_wal_attached_closure_engine_retains_w_star():
    # with a WAL attached (durable serving), snapshot() must not free
    # the resident W* — the next scoped update needs it as its basis
    class _Sink:
        def append(self, version, inserts, deletes):
            pass

        def committed(self, engine):
            pass

    h = planted_chain_hypergraph(3, 3, overlap=2, extra_size=2, seed=2)
    eng = build_engine(h, "sharded")
    eng.attach_wal(_Sink())
    eng.snapshot()
    assert eng._w_star is not None
    basis = eng.snapshot()
    v0 = int(h.edge(0)[0])
    eng.update(inserts=[[v0, v0 + 1]])
    _, dirty = eng.snapshot_delta(basis)
    assert dirty is not None and 0 < dirty.size < h.n
    h2, _, _ = apply_edge_edits(h, [[v0, v0 + 1]], [])
    _assert_matches_fresh(eng, h2, labels=False)


# ---------------------------------------------------------------------------
# multi-device: the same churn scripts on real 2- and 4-device meshes
# ---------------------------------------------------------------------------

_MULTI_DEVICE_CODE = """
import numpy as np
from repro.api import build_engine
from repro.core import MSTOracle, apply_edge_edits, planted_chain_hypergraph

for labels in (False, True):
    h = planted_chain_hypergraph(4, 4, overlap=2, extra_size=2, seed=0)
    eng = build_engine(h, "sharded", build_labels=labels)
    script = [([[0, 1, 2]], []), ([], [0]),
              ([[0, 5], [2, 3, 4]], [1, 3]),
              ([], list(range(6))), ([[0, 1], [1, 2, 3]], [])]
    for ins, dels in script:
        cur = eng.h
        dels = [d for d in dels if d < cur.m]
        eng.update(inserts=ins, deletes=dels)
        h2, _, _ = apply_edge_edits(cur, ins, dels)
        fresh = build_engine(h2, "sharded", build_labels=labels)
        mst = MSTOracle(h2)
        if h2.n:
            us, vs = np.meshgrid(np.arange(h2.n), np.arange(h2.n))
            us, vs = us.ravel(), vs.ravel()
            got = np.asarray(eng.mr_batch(us, vs)).astype(np.int64)
            ref = np.asarray(fresh.mr_batch(us, vs)).astype(np.int64)
            assert np.array_equal(got, ref), labels
            want = np.array([mst.mr(int(u), int(v))
                             for u, v in zip(us, vs)], np.int64)
            assert np.array_equal(got, want), labels
    assert eng.update_capability == "scoped"
print("CHURN", {False: "closure", True: "labels"}[labels], "OK")
"""


@pytest.mark.parametrize("n_devices", [2, 4])
def test_multi_device_scoped_churn(n_devices):
    from util_subproc import run_with_devices
    out = run_with_devices(_MULTI_DEVICE_CODE, n_devices=n_devices)
    assert "OK" in out
