"""Kernel differential-test harness: every Pallas kernel vs its oracle.

Three layers, all in interpret mode (same kernel body the TPU compiles,
Python-evaluated):

1. a **registry coverage** assertion — every entry of
   ``repro.kernels.KERNEL_REGISTRY`` must have a differ here, so a
   kernel can't ship without landing in this harness;
2. a **fixed-seed regression corpus** of adversarial shapes — block
   non-multiples, single-row, empty operands, all-pad label rows,
   ranks at the sentinel bound, and the ``bk % k_chunk != 0``
   tail-truncation counterexample this harness flushed out of
   ``maxmin_matmul`` (the last k-chunk sweep used floor instead of
   ceil division, silently dropping tail columns);
3. **hypothesis fuzzing** over shapes/blocks/dtypes when hypothesis is
   installed (skipped cleanly otherwise — the corpus above still runs).

Every differ asserts exact equality: these kernels are integer/semiring
work, so there is no tolerance to hide behind.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref, KERNEL_REGISTRY, interpret_available
from repro.kernels.label_join import (label_join_pallas, validate_ranks,
                                      MAX_RANK)
from repro.kernels.maxmin_matmul import maxmin_matmul_pallas
from repro.kernels.overlap import overlap_pallas
from repro.kernels.threshold_closure import threshold_step_pallas

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = [
    pytest.mark.kernels,
    pytest.mark.skipif(not interpret_available(),
                       reason="pallas interpret mode unavailable"),
]

_PAD = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# differs — one per registry entry, shared by the corpus and the fuzzers
# ---------------------------------------------------------------------------

def _label_rows(rng, q, l, high):
    """Random padded label rows: ragged true lengths (including all-pad
    rows), ascending int32 ranks, svals in [1, 9)."""
    ranks = np.full((q, l), _PAD, np.int32)
    svals = np.zeros((q, l), np.int32)
    for i in range(q):
        li = int(rng.integers(0, l + 1))
        r = np.unique(rng.integers(0, max(high, 1), li)).astype(np.int64)
        ranks[i, :r.size] = np.minimum(r, MAX_RANK)
        svals[i, :r.size] = rng.integers(1, 9, r.size)
    return jnp.asarray(ranks), jnp.asarray(svals)


def diff_label_join(q, l, bq, bl, seed, high=200):
    rng = np.random.default_rng(seed)
    ru, su = _label_rows(rng, q, l, high)
    rv, sv = _label_rows(rng, q, l, high)
    got = label_join_pallas(ru, su, rv, sv, bq=bq, bl=bl, interpret=True)
    want = ref.label_join_ref(ru, su, rv, sv)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def diff_maxmin_matmul(m, k, n, bm, bn, bk, k_chunk, seed, dtype=jnp.int32):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 12, (m, k))).astype(dtype)
    b = jnp.asarray(rng.integers(0, 12, (k, n))).astype(dtype)
    got = maxmin_matmul_pallas(a, b, bm=bm, bn=bn, bk=bk, k_chunk=k_chunk,
                               interpret=True)
    want = ref.maxmin_matmul_ref(a, b)
    assert got.shape == (m, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def diff_overlap(m, n, bm, bn, bk, seed):
    rng = np.random.default_rng(seed)
    b_inc = jnp.asarray((rng.random((m, n)) < 0.3).astype(np.float32))
    got = overlap_pallas(b_inc, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.overlap_ref(b_inc)
    assert got.shape == (m, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def diff_threshold_step(s, m, bm, bn, bk, seed):
    rng = np.random.default_rng(seed)
    r = jnp.asarray((rng.random((s, m, m)) < 0.2).astype(np.float32))
    got = threshold_step_pallas(r, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.threshold_step_ref(r)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


DIFFERS = {
    "label_join": diff_label_join,
    "maxmin_matmul": diff_maxmin_matmul,
    "overlap": diff_overlap,
    "threshold_step": diff_threshold_step,
}


def test_harness_covers_registry():
    # both directions: a registered kernel with no differ, or a differ
    # for a kernel that no longer exists, fails loudly
    assert set(DIFFERS) == set(KERNEL_REGISTRY)


# ---------------------------------------------------------------------------
# fixed-seed regression corpus — adversarial shapes, kept forever
# ---------------------------------------------------------------------------

LABEL_JOIN_CORPUS = [
    # (q, l, bq, bl, seed) — Q/L non-multiples of the blocks, single
    # query, empty operands, multi-tile L sweeps
    (5, 7, 32, 4, 0),
    (130, 33, 32, 8, 1),
    (1, 1, 128, 256, 2),
    (64, 300, 16, 64, 3),        # L > bq: the L-sub-tiling path
    (31, 129, 8, 32, 4),
    (0, 5, 32, 8, 5),            # Q = 0
    (3, 0, 32, 8, 6),            # L = 0
]

MAXMIN_CORPUS = [
    # (m, k, n, bm, bn, bk, k_chunk, seed)
    (33, 32, 17, 32, 32, 32, 5, 0),   # bk % k_chunk != 0 — the regression
                                      # this harness found: floor instead
                                      # of ceil k-chunk steps dropped
                                      # columns 30-31 of every k block
    (1, 1, 1, 128, 128, 128, 8, 1),   # single element
    (8, 37, 9, 16, 16, 16, 7, 2),     # nothing divides anything
    (0, 4, 4, 32, 32, 32, 8, 3),      # empty m
    (4, 0, 4, 32, 32, 32, 8, 4),      # empty k
    (4, 4, 0, 32, 32, 32, 8, 5),      # empty n
    (64, 64, 64, 32, 32, 32, 1, 6),   # k_chunk = 1
]

OVERLAP_CORPUS = [
    (10, 17, 32, 32, 32, 0), (1, 1, 16, 16, 16, 1),
    (0, 5, 32, 32, 32, 2), (5, 0, 32, 32, 32, 3), (130, 40, 32, 32, 32, 4),
]

THRESHOLD_CORPUS = [
    (1, 16, 32, 32, 32, 0), (3, 33, 16, 16, 16, 1), (0, 8, 16, 16, 16, 2),
    (2, 0, 16, 16, 16, 3),
]


@pytest.mark.parametrize("q,l,bq,bl,seed", LABEL_JOIN_CORPUS)
def test_label_join_corpus(q, l, bq, bl, seed):
    diff_label_join(q, l, bq, bl, seed)


@pytest.mark.parametrize("m,k,n,bm,bn,bk,kc,seed", MAXMIN_CORPUS)
def test_maxmin_matmul_corpus(m, k, n, bm, bn, bk, kc, seed):
    diff_maxmin_matmul(m, k, n, bm, bn, bk, kc, seed)
    diff_maxmin_matmul(m, k, n, bm, bn, bk, kc, seed, dtype=jnp.float32)


@pytest.mark.parametrize("m,n,bm,bn,bk,seed", OVERLAP_CORPUS)
def test_overlap_corpus(m, n, bm, bn, bk, seed):
    diff_overlap(m, n, bm, bn, bk, seed)


@pytest.mark.parametrize("s,m,bm,bn,bk,seed", THRESHOLD_CORPUS)
def test_threshold_step_corpus(s, m, bm, bn, bk, seed):
    diff_threshold_step(s, m, bm, bn, bk, seed)


# -- sentinel bound (satellite of the label-join rewrite) -------------------

def test_label_join_rank_at_sentinel_bound():
    # MAX_RANK itself is a legal real rank and must join; one above it
    # aliases the padded-query-row sentinel and must be rejected
    ru = jnp.asarray([[0, MAX_RANK]], jnp.int32)
    su = jnp.asarray([[3, 5]], jnp.int32)
    rv = jnp.asarray([[MAX_RANK, _PAD]], jnp.int32)
    sv = jnp.asarray([[4, 0]], jnp.int32)
    validate_ranks(ru)
    got = label_join_pallas(ru, su, rv, sv, bq=8, interpret=True)
    assert int(got[0]) == 4
    with pytest.raises(ValueError, match="sentinel"):
        validate_ranks(jnp.asarray([[MAX_RANK + 1]], jnp.int32))
    # MAX_RANK + 2 == INT32_MAX is the padding sentinel itself — legal
    validate_ranks(jnp.asarray([[MAX_RANK + 2]], jnp.int32))


def test_label_join_pad_rows_never_match():
    # a batch padded up to bq adds all-sentinel u rows; they must answer
    # 0 even against an all-pad v row (INT32_MAX vs INT32_MAX-1)
    q, l = 3, 4                      # bq=8 forces 5 padded query rows
    ru = jnp.full((q, l), _PAD, jnp.int32)
    su = jnp.zeros((q, l), jnp.int32)
    got = label_join_pallas(ru, su, ru, su, bq=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(q, np.int32))


# ---------------------------------------------------------------------------
# hypothesis fuzzing — skipped cleanly when hypothesis is not installed
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _SETTINGS = settings(max_examples=30, deadline=None,
                         suppress_health_check=[HealthCheck.too_slow])

    @_SETTINGS
    @given(q=st.integers(0, 40), l=st.integers(0, 40),
           bq=st.sampled_from([8, 32, 128]),
           bl=st.sampled_from([4, 16, 64, 256]),
           seed=st.integers(0, 2**16), high=st.sampled_from([8, 200]))
    def test_label_join_fuzz(q, l, bq, bl, seed, high):
        diff_label_join(q, l, bq, bl, seed, high=high)

    @_SETTINGS
    @given(m=st.integers(0, 48), k=st.integers(0, 48), n=st.integers(0, 48),
           bm=st.sampled_from([8, 16, 32]), bn=st.sampled_from([8, 16, 32]),
           bk=st.sampled_from([8, 16, 32]), kc=st.integers(1, 9),
           seed=st.integers(0, 2**16),
           dtype=st.sampled_from([jnp.int32, jnp.float32]))
    def test_maxmin_matmul_fuzz(m, k, n, bm, bn, bk, kc, seed, dtype):
        diff_maxmin_matmul(m, k, n, bm, bn, bk, kc, seed, dtype=dtype)

    @_SETTINGS
    @given(m=st.integers(0, 40), n=st.integers(0, 40),
           bm=st.sampled_from([8, 16, 32]), bn=st.sampled_from([8, 16, 32]),
           bk=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**16))
    def test_overlap_fuzz(m, n, bm, bn, bk, seed):
        diff_overlap(m, n, bm, bn, bk, seed)

    @_SETTINGS
    @given(s=st.integers(0, 4), m=st.integers(0, 40),
           b=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**16))
    def test_threshold_step_fuzz(s, m, b, seed):
        diff_threshold_step(s, m, b, b, b, seed)
else:
    @pytest.mark.skip(reason="hypothesis not installed; fixed-seed corpus "
                             "above still covers every kernel")
    def test_hypothesis_fuzzing():
        pass
