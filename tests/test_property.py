"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import (from_edge_lists, build_fast, minimize, mr_query,
                        mr_online, mr_oracle_dense, compact, MSTOracle,
                        threshold_closure_mr, maxmin_closure)
import jax.numpy as jnp


@st.composite
def hypergraphs(draw, max_v=16, max_e=12):
    n = draw(st.integers(3, max_v))
    m = draw(st.integers(1, max_e))
    edges = []
    for _ in range(m):
        size = draw(st.integers(1, min(6, n)))
        edge = draw(st.lists(st.integers(0, n - 1), min_size=size,
                             max_size=size, unique=True))
        edges.append(edge)
    return from_edge_lists(edges, n=n)


@settings(max_examples=25, deadline=None)
@given(hypergraphs())
def test_mr_symmetry_and_diagonal(h):
    oracle = mr_oracle_dense(h)
    # symmetry: u ~s~> v  ==  v ~s~> u  (Sec. II)
    assert np.array_equal(oracle, oracle.T)
    # MR(u, u) = max |e| over e ∋ u  (single-hyperedge walk, Corollary 1)
    for u in range(h.n):
        eu = h.edges_of(u)
        want = int(h.edge_sizes[eu].max()) if eu.size else 0
        assert oracle[u, u] == want


@settings(max_examples=20, deadline=None)
@given(hypergraphs())
def test_index_complete_vs_oracle(h):
    oracle = mr_oracle_dense(h)
    idx = minimize(build_fast(h))
    for u in range(h.n):
        for v in range(h.n):
            assert mr_query(idx, u, v) == int(oracle[u, v])


@settings(max_examples=15, deadline=None)
@given(hypergraphs())
def test_online_matches_mst(h):
    mst = MSTOracle(h)
    rng = np.random.default_rng(0)
    for _ in range(10):
        u, v = int(rng.integers(h.n)), int(rng.integers(h.n))
        assert mr_online(h, u, v) == mst.mr(u, v)


@settings(max_examples=15, deadline=None)
@given(hypergraphs())
def test_closure_methods_agree(h):
    w = jnp.asarray(h.line_graph(np.int32))
    a = np.asarray(maxmin_closure(w))
    b = np.asarray(threshold_closure_mr(w)).astype(a.dtype)
    assert np.array_equal(a, b)


@settings(max_examples=15, deadline=None)
@given(hypergraphs())
def test_compaction_preserves_mr(h):
    g, _ = compact(h)
    a = mr_oracle_dense(h)
    b = mr_oracle_dense(g)
    assert np.array_equal(a, b)     # same vertex set; dup edges removable


@settings(max_examples=10, deadline=None)
@given(hypergraphs(), st.integers(0, 15), st.integers(0, 15))
def test_adding_hyperedge_is_monotone(h, ua, ub):
    """Adding a hyperedge can only increase MR values."""
    ua, ub = ua % h.n, ub % h.n
    before = mr_oracle_dense(h)
    edges = [h.edge(e).tolist() for e in range(h.m)] + [[ua, ub]]
    h2 = from_edge_lists(edges, n=h.n)
    after = mr_oracle_dense(h2)
    assert (after >= before).all()


# ---------------------------------------------------------------------------
# engine.update: randomized insert/delete sequences must answer exactly
# like a from-scratch rebuild, on every step, for every updatable backend
# ---------------------------------------------------------------------------

@st.composite
def edit_scripts(draw, steps=3):
    """A list of (inserts, deletes) batches; deletes are drawn as
    fractions so they stay valid whatever the current edge count is."""
    script = []
    for _ in range(draw(st.integers(1, steps))):
        n_ins = draw(st.integers(0, 2))
        inserts = [draw(st.lists(st.integers(0, 19), min_size=2, max_size=4,
                                 unique=True)) for _ in range(n_ins)]
        deletes = draw(st.lists(st.floats(0, 1), min_size=0, max_size=2))
        script.append((inserts, deletes))
    return script


@settings(max_examples=8, deadline=None)
@given(hypergraphs(max_v=14, max_e=8), edit_scripts())
def test_engine_update_equivalent_to_rebuild(h, script):
    from repro.api import build_engine, update_capabilities
    from repro.core import apply_edge_edits

    updatable = [b for b, cap in update_capabilities().items()
                 if cap != "unsupported"]
    engines = {b: build_engine(h, b) for b in updatable}
    rng = np.random.default_rng(0)
    for inserts, delete_fracs in script:
        deletes = sorted({int(f * (h.m - 1)) for f in delete_fracs
                          if h.m > 0})
        for eng in engines.values():
            eng.update(inserts=inserts, deletes=deletes)
        h, _, _ = apply_edge_edits(h, inserts, deletes)
        us = rng.integers(0, h.n, 25)
        vs = rng.integers(0, h.n, 25)
        want = None
        for b, eng in engines.items():
            fresh = build_engine(h, b)
            ref = np.asarray(fresh.mr_batch(us, vs)).astype(np.int64)
            got = np.asarray(eng.mr_batch(us, vs)).astype(np.int64)
            assert np.array_equal(got, ref), b
            if want is None:
                want = ref
            else:                       # all backends agree with each other
                assert np.array_equal(ref, want), b
