"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import (from_edge_lists, build_fast, minimize, mr_query,
                        mr_online, mr_oracle_dense, compact, MSTOracle,
                        threshold_closure_mr, maxmin_closure)
import jax.numpy as jnp


@st.composite
def hypergraphs(draw, max_v=16, max_e=12):
    n = draw(st.integers(3, max_v))
    m = draw(st.integers(1, max_e))
    edges = []
    for _ in range(m):
        size = draw(st.integers(1, min(6, n)))
        edge = draw(st.lists(st.integers(0, n - 1), min_size=size,
                             max_size=size, unique=True))
        edges.append(edge)
    return from_edge_lists(edges, n=n)


@settings(max_examples=25, deadline=None)
@given(hypergraphs())
def test_mr_symmetry_and_diagonal(h):
    oracle = mr_oracle_dense(h)
    # symmetry: u ~s~> v  ==  v ~s~> u  (Sec. II)
    assert np.array_equal(oracle, oracle.T)
    # MR(u, u) = max |e| over e ∋ u  (single-hyperedge walk, Corollary 1)
    for u in range(h.n):
        eu = h.edges_of(u)
        want = int(h.edge_sizes[eu].max()) if eu.size else 0
        assert oracle[u, u] == want


@settings(max_examples=20, deadline=None)
@given(hypergraphs())
def test_index_complete_vs_oracle(h):
    oracle = mr_oracle_dense(h)
    idx = minimize(build_fast(h))
    for u in range(h.n):
        for v in range(h.n):
            assert mr_query(idx, u, v) == int(oracle[u, v])


@settings(max_examples=15, deadline=None)
@given(hypergraphs())
def test_online_matches_mst(h):
    mst = MSTOracle(h)
    rng = np.random.default_rng(0)
    for _ in range(10):
        u, v = int(rng.integers(h.n)), int(rng.integers(h.n))
        assert mr_online(h, u, v) == mst.mr(u, v)


@settings(max_examples=15, deadline=None)
@given(hypergraphs())
def test_closure_methods_agree(h):
    w = jnp.asarray(h.line_graph(np.int32))
    a = np.asarray(maxmin_closure(w))
    b = np.asarray(threshold_closure_mr(w)).astype(a.dtype)
    assert np.array_equal(a, b)


@settings(max_examples=15, deadline=None)
@given(hypergraphs())
def test_compaction_preserves_mr(h):
    g, _ = compact(h)
    a = mr_oracle_dense(h)
    b = mr_oracle_dense(g)
    assert np.array_equal(a, b)     # same vertex set; dup edges removable


@settings(max_examples=10, deadline=None)
@given(hypergraphs(), st.integers(0, 15), st.integers(0, 15))
def test_adding_hyperedge_is_monotone(h, ua, ub):
    """Adding a hyperedge can only increase MR values."""
    ua, ub = ua % h.n, ub % h.n
    before = mr_oracle_dense(h)
    edges = [h.edge(e).tolist() for e in range(h.m)] + [[ua, ub]]
    h2 = from_edge_lists(edges, n=h.n)
    after = mr_oracle_dense(h2)
    assert (after >= before).all()
