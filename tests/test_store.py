"""Persistence subsystem (repro.store): on-disk format round trips,
WAL semantics, checkpoint/replay via IndexStore, and HIF import/export.

The crash-under-fire path (SIGKILL mid-stream) lives in
tests/test_crash_recovery.py; the restored engines' full op-set
conformance vs the mst-oracle lives in tests/test_conformance.py
(rows ``hl-index[restored]`` / ``sharded[restored]``).
"""
import json
import os

import numpy as np
import pytest

from repro.api import build_engine, random_hypergraph, serve
from repro.core.hypergraph import neighbor_csr
from repro.serve.reach_service import ReachabilityService
from repro.store import (FORMAT_REGISTRY, FORMAT_VERSION, CorruptStore,
                         IndexStore, StoreError, StoreUnsupported,
                         WriteAheadLog, load_index, load_segments,
                         read_hif, read_manifest, save_index, scan_wal,
                         write_hif)


def _graph():
    return random_hypergraph(36, 48, seed=5)


def _queries(h, q=64, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, h.n, q), rng.integers(0, h.n, q)


def _memmap_backed(a: np.ndarray) -> bool:
    while a is not None:
        if isinstance(a, np.memmap):
            return True
        a = a.base
    return False


# ---------------------------------------------------------------------------
# format: save/load round trips
# ---------------------------------------------------------------------------

def test_format_registry_names_current_version():
    assert FORMAT_VERSION in FORMAT_REGISTRY


@pytest.mark.parametrize("backend,opts", [
    ("hl-index", {}),
    ("hl-index", {"minimize_labels": False}),
    ("hl-index", {"construction": "sharded", "workers": 2}),
    ("hl-index-basic", {}),
    ("hl-index-basic", {"cover_check": False}),
    ("closure", {}),
])
def test_round_trip_byte_identical(tmp_path, backend, opts):
    h = _graph()
    eng = build_engine(h, backend, **opts)
    p = tmp_path / "x.hlidx"
    save_index(p, eng)
    eng2 = load_index(p)
    assert eng2.name == backend
    assert eng2.version == eng.version == 0
    # graph arrays
    for f in ("e_ptr", "e_idx", "v_ptr", "v_idx"):
        a, b = getattr(eng.h, f), getattr(eng2.h, f)
        assert a.dtype == b.dtype and np.array_equal(a, b)
    if backend == "closure":
        assert np.array_equal(eng.w_star, eng2.w_star)
    else:
        # the tentpole claim: loaded labels byte-identical to built ones
        assert np.array_equal(eng.idx.rank, eng2.idx.rank)
        assert np.array_equal(eng.idx.perm, eng2.idx.perm)
        for u in range(h.n):
            for attr in ("labels_edge", "labels_rank", "labels_s"):
                a = getattr(eng.idx, attr)[u]
                b = getattr(eng2.idx, attr)[u]
                assert a.dtype == b.dtype and np.array_equal(a, b)
        # zero-copy: label arrays are views into the file mmap, so the
        # restart path is page-in + to_mesh, not a rebuild
        assert _memmap_backed(eng2.idx.rank)
        assert _memmap_backed(eng2.idx.labels_s[0])
    us, vs = _queries(h)
    assert np.array_equal(eng.mr_batch(us, vs), eng2.mr_batch(us, vs))


def test_restored_update_path_keeps_builder(tmp_path):
    """A restored engine continues scoped maintenance with the same
    builder/minimizer options it was built with."""
    h = _graph()
    eng = build_engine(h, "hl-index", construction="sharded", workers=2)
    save_index(tmp_path / "x.hlidx", eng)
    eng2 = load_index(tmp_path / "x.hlidx")
    assert eng2.construction == "sharded"
    for e in (eng, eng2):
        e.update(inserts=[[1, 2, 3]], deletes=[0])
    assert eng2.version == 1
    us, vs = _queries(eng.h)
    assert np.array_equal(eng.mr_batch(us, vs), eng2.mr_batch(us, vs))


def test_sharded_round_trip_all_payloads(tmp_path):
    h = _graph()
    us, vs = _queries(h)
    # closure-resident regime
    eng = build_engine(h, "sharded")
    save_index(tmp_path / "c.hlidx", eng)
    m1 = read_manifest(tmp_path / "c.hlidx")
    assert m1["payload"] == "closure"
    r1 = load_index(tmp_path / "c.hlidx")
    assert np.array_equal(eng.mr_batch(us, vs), r1.mr_batch(us, vs))
    # snapshot regime (snapshot() frees the closure)
    eng.snapshot()
    save_index(tmp_path / "s.hlidx", eng)
    assert read_manifest(tmp_path / "s.hlidx")["payload"] == "snapshot"
    r2 = load_index(tmp_path / "s.hlidx")
    assert np.array_equal(eng.mr_batch(us, vs), r2.mr_batch(us, vs))
    # label regime
    eng = build_engine(h, "sharded", build_labels=True)
    save_index(tmp_path / "l.hlidx", eng)
    assert read_manifest(tmp_path / "l.hlidx")["payload"] == "labels"
    r3 = load_index(tmp_path / "l.hlidx")
    assert np.array_equal(eng.mr_batch(us, vs), r3.mr_batch(us, vs))
    r3.update(inserts=[[4, 5, 6]])
    eng.update(inserts=[[4, 5, 6]])
    assert np.array_equal(eng.mr_batch(us, vs), r3.mr_batch(us, vs))


def test_neighbor_csr_block_round_trip(tmp_path):
    h = _graph()
    eng = build_engine(h, "hl-index")
    nbr = neighbor_csr(h)
    save_index(tmp_path / "x.hlidx", eng, neighbors=nbr)
    _, seg = load_segments(tmp_path / "x.hlidx")
    assert np.array_equal(seg["nbr.ptr"], nbr.ptr)
    assert np.array_equal(seg["nbr.idx"], nbr.idx)
    assert np.array_equal(seg["nbr.od"], nbr.od)


@pytest.mark.parametrize("backend", ["online", "frontier", "mst-oracle"])
def test_index_free_backends_unsupported(tmp_path, backend):
    eng = build_engine(_graph(), backend)
    with pytest.raises(StoreUnsupported):
        save_index(tmp_path / "x.hlidx", eng)


# ---------------------------------------------------------------------------
# format: corruption detection
# ---------------------------------------------------------------------------

def _flip_byte(path, offset):
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "x.hlidx"
    save_index(p, build_engine(_graph(), "hl-index"))
    _flip_byte(p, 0)
    with pytest.raises(CorruptStore, match="magic"):
        load_index(p)


def test_unknown_format_version_rejected(tmp_path):
    p = tmp_path / "x.hlidx"
    save_index(p, build_engine(_graph(), "hl-index"))
    _flip_byte(p, 8)                      # the u32 format version field
    with pytest.raises(CorruptStore, match="format version"):
        load_index(p)


def test_truncated_file_fails_manifest_crc(tmp_path):
    p = tmp_path / "x.hlidx"
    save_index(p, build_engine(_graph(), "hl-index"))
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) - 7)
    with pytest.raises(CorruptStore):
        load_index(p)


def test_corrupt_segment_detected_by_checksum(tmp_path):
    p = tmp_path / "x.hlidx"
    manifest = save_index(p, build_engine(_graph(), "hl-index"))
    seg = next(s for s in manifest["segments"] if s["name"] == "labels.s")
    _flip_byte(p, seg["offset"])
    with pytest.raises(CorruptStore, match="labels.s"):
        load_index(p, verify=True)
    load_index(p, verify=False)           # lazy mode defers integrity


def test_expect_backend_mismatch(tmp_path):
    p = tmp_path / "x.hlidx"
    save_index(p, build_engine(_graph(), "closure"))
    with pytest.raises(StoreError, match="closure"):
        load_index(p, expect_backend="hl-index")


# ---------------------------------------------------------------------------
# build_engine(restore=...)
# ---------------------------------------------------------------------------

def test_build_engine_restore_from_file(tmp_path):
    h = _graph()
    eng = build_engine(h, "hl-index")
    p = tmp_path / "x.hlidx"
    save_index(p, eng)
    eng2 = build_engine(restore=p)
    us, vs = _queries(h)
    assert np.array_equal(eng.mr_batch(us, vs), eng2.mr_batch(us, vs))
    # non-auto backend asserts what the checkpoint must hold
    with pytest.raises(StoreError):
        build_engine(backend="sharded", restore=p)


def test_build_engine_argument_validation(tmp_path):
    h = _graph()
    with pytest.raises(ValueError, match="ambiguous"):
        build_engine(h, restore=tmp_path / "x.hlidx")
    with pytest.raises(ValueError, match="hypergraph"):
        build_engine()


# ---------------------------------------------------------------------------
# write-ahead log
# ---------------------------------------------------------------------------

def test_wal_append_scan_round_trip(tmp_path):
    p = tmp_path / "w.log"
    with WriteAheadLog(p) as wal:
        wal.append(1, [[1, 2, 3]], [])
        wal.append(2, [], [0, 4])
        wal.append(3, [[5, 6], [7, 8]], [2])
    records, _, status = scan_wal(p)
    assert status == "ok"
    assert records == [(1, [[1, 2, 3]], []), (2, [], [0, 4]),
                       (3, [[5, 6], [7, 8]], [2])]


def test_wal_monotonic_versions_enforced(tmp_path):
    with WriteAheadLog(tmp_path / "w.log", base_version=5) as wal:
        with pytest.raises(StoreError, match="monotonic"):
            wal.append(5, [], [0])
        with pytest.raises(StoreError, match="monotonic"):
            wal.append(7, [], [0])
        wal.append(6, [], [0])
        assert wal.last_version == 6


@pytest.mark.parametrize("mutilate,expect", [
    (lambda data: data[:-3], "torn-payload"),
    (lambda data: data + b"\x01\x02\x03", "torn-header"),
    (lambda data: data + b"\x00" * 40, "bad-magic"),
])
def test_wal_torn_tail_dropped_not_fatal(tmp_path, mutilate, expect):
    p = tmp_path / "w.log"
    with WriteAheadLog(p) as wal:
        wal.append(1, [[1, 2]], [])
        wal.append(2, [[3, 4]], [])
    data = p.read_bytes()
    p.write_bytes(mutilate(data))
    records, valid, status = scan_wal(p)
    assert status == expect
    assert [r[0] for r in records] == ([1] if expect == "torn-payload"
                                       else [1, 2])
    # reopening truncates the tail for good and resumes the lineage
    with WriteAheadLog(p) as wal:
        assert os.path.getsize(p) == valid
        assert wal.last_version == records[-1][0]
        wal.append(records[-1][0] + 1, [[9]], [])
    assert scan_wal(p)[2] == "ok"


def test_wal_flipped_payload_byte_is_bad_checksum(tmp_path):
    p = tmp_path / "w.log"
    with WriteAheadLog(p) as wal:
        wal.append(1, [[1, 2]], [])
    data = bytearray(p.read_bytes())
    data[-1] ^= 0xFF
    p.write_bytes(bytes(data))
    records, _, status = scan_wal(p)
    assert status == "bad-checksum" and records == []


# ---------------------------------------------------------------------------
# engine WAL hook ordering
# ---------------------------------------------------------------------------

def test_rejected_update_is_never_journaled(tmp_path):
    h = _graph()
    eng = build_engine(h, "hl-index")
    store = IndexStore(tmp_path / "s")
    store.attach(eng)
    wal_path = store.path / "wal-000000000000.log"
    with pytest.raises(IndexError):
        eng.update(deletes=[h.m + 3])     # validated before journaling
    assert eng.version == 0
    assert scan_wal(wal_path)[0] == []
    eng.update(inserts=[[0, 1, 2]])
    assert [r[0] for r in scan_wal(wal_path)[0]] == [1]


def test_unsupported_backend_gates_before_journal(tmp_path):
    from repro.core.engine import UpdateUnsupported
    eng = build_engine(_graph(), "mst-oracle")
    with pytest.raises(UpdateUnsupported):
        eng.update(inserts=[[1, 2]])
    assert eng.version == 0


# ---------------------------------------------------------------------------
# IndexStore: checkpoint / replay / compaction
# ---------------------------------------------------------------------------

def _stream(eng, k, seed=11):
    rng = np.random.default_rng(seed)
    for i in range(k):
        ins = [sorted(int(x) for x in rng.choice(eng.h.n, 3, replace=False))]
        dels = [int(rng.integers(0, eng.h.m))] if i % 3 == 2 else []
        eng.update(inserts=ins, deletes=dels)


def test_store_checkpoint_replay_matches_live(tmp_path):
    h = _graph()
    eng = build_engine(h, "hl-index")
    store = IndexStore(tmp_path / "s")
    store.attach(eng)                     # seeds checkpoint-0
    _stream(eng, 6)
    assert eng.version == 6
    eng2 = IndexStore(tmp_path / "s").restore()
    assert eng2.version == 6
    us, vs = _queries(eng.h)
    assert np.array_equal(eng.mr_batch(us, vs), eng2.mr_batch(us, vs))
    # the restored engine resumes the lineage: next update journals
    eng2.update(inserts=[[0, 1]])
    assert eng2.version == 7


def test_store_compaction_truncates_log(tmp_path):
    h = _graph()
    eng = build_engine(h, "hl-index")
    store = IndexStore(tmp_path / "s", checkpoint_every=3)
    store.attach(eng)
    _stream(eng, 7)
    assert store.checkpoint_version == 6  # compacted at 3 and 6
    files = sorted(os.listdir(store.path))
    assert sum(f.startswith("checkpoint-") for f in files) == 1
    assert sum(f.startswith("wal-") for f in files) == 1
    assert store.records_since_checkpoint == 1
    eng2 = IndexStore(tmp_path / "s").restore()
    assert eng2.version == 7
    us, vs = _queries(eng.h)
    assert np.array_equal(eng.mr_batch(us, vs), eng2.mr_batch(us, vs))


def test_store_lineage_mismatch_rejected(tmp_path):
    h = _graph()
    eng = build_engine(h, "hl-index")
    store = IndexStore(tmp_path / "s")
    store.attach(eng)
    eng.update(inserts=[[0, 1, 2]])
    store.close()
    stranger = build_engine(h, "hl-index")   # version 0, store is at 1
    with pytest.raises(StoreError, match="lineage"):
        IndexStore(tmp_path / "s").attach(stranger)


def test_store_restore_empty_dir_is_error(tmp_path):
    with pytest.raises(StoreError, match="nothing to restore"):
        IndexStore(tmp_path / "empty").restore()


def test_store_restore_detects_lineage_gap(tmp_path):
    h = _graph()
    eng = build_engine(h, "hl-index")
    store = IndexStore(tmp_path / "s")
    store.attach(eng)
    eng.update(inserts=[[0, 1]])
    eng.update(inserts=[[2, 3]])
    store.close()
    # forge a gap: rewrite the log with only record 2
    wal_path = store.path / "wal-000000000000.log"
    records = scan_wal(wal_path)[0]
    wal_path.unlink()
    with WriteAheadLog(wal_path, base_version=1) as w:
        v, ins, dels = records[1]
        w.append(v, ins, dels)
    with pytest.raises(CorruptStore, match="lineage gap"):
        IndexStore(tmp_path / "s").restore()


# ---------------------------------------------------------------------------
# service checkpoint / restore
# ---------------------------------------------------------------------------

def test_service_checkpoint_restore_round_trip(tmp_path):
    h = _graph()
    svc = serve(h, "hl-index", start=False)
    store = IndexStore(tmp_path / "s")
    assert svc.checkpoint(store) == 0
    svc.update(inserts=[[1, 2, 3]])
    svc.update(deletes=[0])
    store.close()
    svc2 = ReachabilityService.restore(tmp_path / "s", start=False)
    assert svc2.engine.version == 2
    us, vs = _queries(svc.engine.h, q=32)
    futs_a = [svc.mr(int(u), int(v)) for u, v in zip(us, vs)]
    futs_b = [svc2.mr(int(u), int(v)) for u, v in zip(us, vs)]
    svc.drain(), svc2.drain()
    assert [f.result() for f in futs_a] == [f.result() for f in futs_b]
    svc.close(), svc2.close()


# ---------------------------------------------------------------------------
# HIF import/export
# ---------------------------------------------------------------------------

def _hif_doc():
    return {
        "network-type": "undirected",
        "metadata": {"name": "fixture"},
        # "iso" never appears in an incidence: isolated vertex
        "nodes": [{"node": "a"}, {"node": "b"}, {"node": "iso"},
                  {"node": "c"}],
        # e1 and e2 have identical member sets (duplicate-member
        # hyperedges — both must survive); "hollow" has no incidences
        "edges": [{"edge": "e1"}, {"edge": "e2"}, {"edge": "e3"},
                  {"edge": "hollow"}],
        "incidences": [
            {"edge": "e1", "node": "a"}, {"edge": "e1", "node": "b"},
            {"edge": "e2", "node": "a"}, {"edge": "e2", "node": "b"},
            {"edge": "e3", "node": "b"}, {"edge": "e3", "node": "c"},
            {"edge": "e3", "node": "b"},   # within-edge duplicate incidence
        ],
    }


def test_hif_import(tmp_path):
    p = tmp_path / "t.hif.json"
    p.write_text(json.dumps(_hif_doc()))
    h = read_hif(p)
    assert h.n == 4                       # incl. the isolated vertex
    assert h.m == 3                       # the memberless edge is dropped
    sets = [set(h.e_idx[h.e_ptr[e]:h.e_ptr[e + 1]].tolist())
            for e in range(h.m)]
    assert sets[0] == sets[1] == {0, 1}   # duplicate-member pair survives
    assert sets[2] == {1, 3}              # within-edge duplicate collapsed


def test_hif_round_trip_identity(tmp_path):
    p = tmp_path / "t.hif.json"
    p.write_text(json.dumps(_hif_doc()))
    h1 = read_hif(p)
    write_hif(tmp_path / "out.hif.json", h1, metadata={"pass": 1})
    h2 = read_hif(tmp_path / "out.hif.json")
    write_hif(tmp_path / "out2.hif.json", h2)
    h3 = read_hif(tmp_path / "out2.hif.json")
    for a, b in ((h1, h2), (h2, h3)):
        assert a.n == b.n and a.m == b.m
        for f in ("e_ptr", "e_idx", "v_ptr", "v_idx"):
            assert np.array_equal(getattr(a, f), getattr(b, f))


def test_hif_rejects_directed_and_garbage(tmp_path):
    p = tmp_path / "d.hif.json"
    p.write_text(json.dumps({"network-type": "directed", "incidences": []}))
    with pytest.raises(ValueError, match="directed"):
        read_hif(p)
    p2 = tmp_path / "g.hif.json"
    p2.write_text(json.dumps({"nodes": []}))
    with pytest.raises(ValueError, match="incidences"):
        read_hif(p2)


def test_hif_through_make_dataset(tmp_path):
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    try:
        from datasets import make_dataset
    finally:
        sys.path.pop(0)
    h = random_hypergraph(20, 25, seed=9)
    p = tmp_path / "ds.hif.json"
    write_hif(p, h)
    h2 = make_dataset(str(p))
    assert h2.n == h.n and h2.m == h.m
    for f in ("e_ptr", "e_idx", "v_ptr", "v_idx"):
        assert np.array_equal(getattr(h, f), getattr(h2, f))
    with pytest.raises(FileNotFoundError):
        make_dataset(str(tmp_path / "missing.hif.json"))
    # an engine built from the imported graph answers like the original
    a = build_engine(h, "hl-index")
    b = build_engine(h2, "hl-index")
    us, vs = _queries(h, q=32)
    assert np.array_equal(a.mr_batch(us, vs), b.mr_batch(us, vs))
