"""Unit tests for the loop-aware HLO accounting (the §Roofline collective
term depends on it)."""
from repro.launch.hlo_analysis import (parse_computations,
                                       loop_aware_collectives)

_HLO = """\
HloModule test

%body.1 (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %ar = f32[4,8]{1,0} all-reduce(%x), channel_id=1, to_apply=%add
  ROOT %t = (s32[], f32[4,8]) tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[4,8])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

%inner.2 (p: f32[2,2]) -> f32[2,2] {
  %ag = f32[2,2]{1,0} all-gather(%y), channel_id=2, dimensions={0}
  ROOT %r = f32[2,2] add(%ag, %ag)
}

ENTRY %main.3 (a: f32[4,8]) -> f32[4,8] {
  %w = (s32[], f32[4,8]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  %f = f32[2,2] fusion(%z), kind=kLoop, calls=%inner.2
  %top = f32[16]{0} reduce-scatter(%q), channel_id=3, dimensions={0}
  ROOT %out = f32[4,8] get-tuple-element(%w), index=1
}
"""


def test_parse_computations():
    comps = parse_computations(_HLO)
    assert set(comps) == {"body.1", "cond.1", "inner.2", "main.3"}
    assert comps["main.3"]["entry"]
    assert comps["body.1"]["coll_bytes"]["all-reduce"] == 4 * 8 * 4


def test_loop_aware_multiplies_trip_counts():
    res = loop_aware_collectives(_HLO)
    # body AR ×5 (known_trip_count), fusion AG ×1, top-level RS ×1
    assert res["bytes"]["all-reduce"] == 5 * 4 * 8 * 4
    assert res["bytes"]["all-gather"] == 2 * 2 * 4
    assert res["bytes"]["reduce-scatter"] == 16 * 4
    assert ("body.1", 5) in res["loops"]


def test_trip_count_fallback_from_condition():
    hlo = _HLO.replace(', backend_config={"known_trip_count":{"n":"5"}}', "")
    res = loop_aware_collectives(hlo)
    assert res["bytes"]["all-reduce"] == 7 * 4 * 8 * 4   # constant(7) in cond
