"""Run a python snippet in a subprocess with a forced host device count."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout
