"""Sharded HL-index construction: byte-identity to the serial builders
(the tentpole contract), the shared neighbor index, the paper's stats
invariants (Lemma 6), and the maintenance splice over shard-built
indexes.  The 1/2/4-device mesh sweep runs in subprocesses (the host
device count must be forced before jax initializes); the 4-device CI
job additionally runs everything here on a real 2×2 mesh.
"""
import functools

import numpy as np
import pytest

from repro.core import (CONSTRUCTION_MODES, MSTOracle, apply_edge_edits,
                        apply_updates, build_basic, build_fast,
                        build_sharded, from_edge_lists, minimize,
                        mr_query, neighbor_csr, paper_figure1,
                        planted_chain_hypergraph, random_hypergraph)
from repro.api import build_engine

GRAPHS = {
    "fig1": paper_figure1,
    "random": lambda: random_hypergraph(30, 45, seed=3),
    "dense": lambda: random_hypergraph(50, 80, seed=7),
    "chain": lambda: planted_chain_hypergraph(4, 8, overlap=2,
                                              extra_size=2, seed=1),
    "isolated": lambda: from_edge_lists([[0, 1, 2], [2, 3], [5, 6, 7],
                                         [6, 7, 8]], n=12),
    "empty": lambda: from_edge_lists([], n=5),
}


def assert_index_identical(a, b, what=""):
    """Byte-for-byte equality of every array field of two HLIndexes."""
    assert np.array_equal(a.rank, b.rank) and a.rank.dtype == b.rank.dtype, what
    assert np.array_equal(a.perm, b.perm), what
    for fa, fb, name in ((a.labels_edge, b.labels_edge, "labels_edge"),
                         (a.labels_rank, b.labels_rank, "labels_rank"),
                         (a.labels_s, b.labels_s, "labels_s"),
                         (a.dual_u, b.dual_u, "dual_u"),
                         (a.dual_s, b.dual_s, "dual_s")):
        assert len(fa) == len(fb), (what, name)
        for i, (x, y) in enumerate(zip(fa, fb)):
            assert x.dtype == y.dtype and x.tobytes() == y.tobytes(), \
                (what, name, i, x, y)


# ---------------------------------------------------------------------------
# the shared neighbor index
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_neighbor_csr_matches_neighbors_od(graph):
    h = GRAPHS[graph]()
    nbr = neighbor_csr(h)
    assert nbr.m == h.m
    for e in range(h.m):
        nb, od = h.neighbors_od(e)
        nb2, od2 = nbr.row(e)
        np.testing.assert_array_equal(nb, nb2)
        np.testing.assert_array_equal(od, od2)


def test_neighbor_csr_induced_requires_closure():
    # the cover-check reconciliation guard: a scope that is not a union
    # of whole line-graph components must be rejected, not merged
    h = planted_chain_hypergraph(2, 4, overlap=2, extra_size=2, seed=0)
    nbr = neighbor_csr(h)
    comp = nbr.components()
    whole = np.nonzero(comp == comp[0])[0]
    sub = nbr.induced(whole)                       # whole component: fine
    assert sub.m == whole.size
    with pytest.raises(ValueError, match="neighbor-closed"):
        nbr.induced(whole[:-1])                    # split component: loud


def test_neighbor_csr_components_deterministic():
    h = from_edge_lists([[0, 1, 2], [2, 3], [5, 6, 7], [6, 7, 8]], n=12)
    comp = neighbor_csr(h).components()
    np.testing.assert_array_equal(comp, [0, 0, 1, 1])


# ---------------------------------------------------------------------------
# byte-identity: the tentpole contract, across shard counts that do not
# divide evenly and through the forked worker pool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("graph", sorted(GRAPHS))
@pytest.mark.parametrize("num_shards", [1, 2, 3, 5])
def test_shard_built_byte_identical_to_build_fast(graph, num_shards):
    h = GRAPHS[graph]()
    serial = build_fast(h)
    sharded = build_sharded(h, num_shards=num_shards)
    assert_index_identical(serial, sharded, (graph, num_shards))
    assert sharded.stats["construction"] == "sharded"


@pytest.mark.parametrize("graph", ["chain", "isolated"])
def test_shard_built_byte_identical_through_worker_pool(graph):
    h = GRAPHS[graph]()
    serial = build_fast(h)
    sharded = build_sharded(h, num_shards=2, workers=2)
    assert_index_identical(serial, sharded, graph)


@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_shard_built_minimized_and_basic_variants(graph):
    h = GRAPHS[graph]()
    # per-shard minimization == global minimization (Algorithm 4's dual
    # sets are component-confined), for both base builders
    assert_index_identical(minimize(build_fast(h)),
                           build_sharded(h, minimizer=minimize,
                                         num_shards=3), (graph, "fast-min"))
    assert_index_identical(minimize(build_basic(h)),
                           build_sharded(h, base=build_basic,
                                         minimizer=minimize, num_shards=2),
                           (graph, "basic-min"))


def test_precomputed_neighbors_identity():
    h = random_hypergraph(30, 45, seed=3)
    nbr = neighbor_csr(h)
    assert_index_identical(build_fast(h), build_fast(h, neighbors=nbr))
    assert_index_identical(build_basic(h), build_basic(h, neighbors=nbr))
    # a shared CSR handed to build_sharded is sliced, never recomputed
    assert_index_identical(build_fast(h),
                           build_sharded(h, num_shards=4, neighbors=nbr))


def test_construction_modes_registry():
    assert set(CONSTRUCTION_MODES) == {"serial", "sharded"}
    assert CONSTRUCTION_MODES["serial"] is build_fast
    assert CONSTRUCTION_MODES["sharded"] is build_sharded
    h = random_hypergraph(10, 8, seed=0)
    with pytest.raises(ValueError, match="unknown construction"):
        build_engine(h, "hl-index", construction="no-such-mode")


def test_engine_construction_modes_byte_identical():
    h = random_hypergraph(30, 45, seed=3)
    serial = build_engine(h, "hl-index", construction="serial")
    sharded = build_engine(h, "hl-index", construction="sharded",
                           num_shards=3)
    assert serial.construction == "serial"
    assert sharded.construction == "sharded"
    assert_index_identical(serial.idx, sharded.idx)
    # same for the unminimized ablation pair
    serial_b = build_engine(h, "hl-index-basic")
    sharded_b = build_engine(h, "hl-index-basic", construction="sharded",
                             num_shards=2)
    assert_index_identical(serial_b.idx, sharded_b.idx)


# ---------------------------------------------------------------------------
# stats regression: the paper's pruning invariants, pinned for both
# builders so a pruning regression fails loudly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("graph", ["fig1", "random", "dense", "chain"])
def test_stats_invariants_serial(graph):
    h = GRAPHS[graph]()
    fast = build_fast(h)
    # Lemma 6: N(e) is computed exactly once per hyperedge, ever
    assert 0 < fast.stats["neighbor_inits"] <= h.m
    # Algorithm 3 never runs an online cover check — MCD replaces it
    assert fast.stats["cover_checks"] == 0
    # the neighbor index never holds more than the full adjacency, and
    # eviction (lines 22-24) only shrinks it
    total_adjacency = int(np.diff(neighbor_csr(h).ptr).sum())
    assert fast.stats["m_total_inserts"] <= total_adjacency
    assert fast.stats["m_final_entries"] <= fast.stats["m_peak_entries"] \
        <= fast.stats["m_total_inserts"]
    basic = build_basic(h)
    # Algorithm 2 runs exactly one cover check per non-stale pop
    assert basic.stats["cover_checks"] == basic.stats["pops"]
    # both produce one label per (root, newly-covered vertex): counts agree
    assert fast.num_labels == basic.num_labels


@pytest.mark.parametrize("graph", ["fig1", "random", "dense", "chain"])
def test_stats_invariants_sharded(graph):
    h = GRAPHS[graph]()
    serial = build_fast(h)
    sharded = build_sharded(h, num_shards=3)
    # per-shard traversal counters sum to exactly the serial values —
    # sharding must not change how much pruned work happens, only where
    for key in ("pops", "pushes", "neighbor_inits", "m_total_inserts",
                "cover_checks", "m_final_entries"):
        assert float(sharded.stats[key]) == float(serial.stats[key]), key
    assert 0 < sharded.stats["neighbor_inits"] <= h.m
    # the sharded peak is per-shard, so it never exceeds the serial peak
    # (which interleaves components in rank order)
    assert sharded.stats["m_peak_entries"] <= serial.stats["m_peak_entries"]
    basic_sharded = build_sharded(h, base=build_basic, num_shards=2)
    basic = build_basic(h)
    assert float(basic_sharded.stats["cover_checks"]) \
        == float(basic.stats["cover_checks"]) == float(basic.stats["pops"])


# ---------------------------------------------------------------------------
# maintenance: the scoped splice composes with shard-built sub-indexes
# ---------------------------------------------------------------------------

def test_splice_accepts_shard_built_indexes():
    h = planted_chain_hypergraph(4, 6, overlap=2, extra_size=2, seed=2)
    idx_serial = build_fast(h)
    idx_sharded = build_sharded(h, num_shards=2)
    ins, dels = [[0, 1, h.n]], [1]
    h_a, idx_a, rep_a = apply_updates(h, idx_serial, ins, dels)
    h_b, idx_b, rep_b = apply_updates(
        h, idx_sharded, ins, dels,
        builder=functools.partial(build_sharded, num_shards=2))
    assert not rep_a.full_rebuild and not rep_b.full_rebuild
    np.testing.assert_array_equal(rep_a.refreshed_vertices,
                                  rep_b.refreshed_vertices)
    assert_index_identical(idx_a, idx_b)
    oracle = MSTOracle(h_a)
    rng = np.random.default_rng(0)
    for _ in range(40):
        u, v = int(rng.integers(h_a.n)), int(rng.integers(h_a.n))
        assert mr_query(idx_b, u, v) == oracle.mr(u, v)


def test_engine_update_sequences_identical_across_constructions():
    rng = np.random.default_rng(5)
    h = planted_chain_hypergraph(3, 5, overlap=2, extra_size=2, seed=3)
    serial = build_engine(h, "hl-index", construction="serial")
    sharded = build_engine(h, "hl-index", construction="sharded",
                           num_shards=2)
    for step in range(4):
        ins = [list(rng.choice(h.n + 1, size=3, replace=False))]
        dels = [int(rng.integers(h.m))] if (step % 2 and h.m > 1) else []
        serial.update(inserts=ins, deletes=dels)
        sharded.update(inserts=ins, deletes=dels)
        h, _, _ = apply_edge_edits(h, ins, dels)
        assert_index_identical(serial.idx, sharded.idx, step)
        us, vs = rng.integers(0, h.n, 20), rng.integers(0, h.n, 20)
        np.testing.assert_array_equal(
            np.asarray(serial.mr_batch(us, vs)),
            np.asarray(sharded.mr_batch(us, vs)))


# ---------------------------------------------------------------------------
# device meshes: 1/2/4-device sweeps in subprocesses (forced host device
# counts), asserting byte-identity of the mesh-computed neighbor index
# and the engine paths that consume it
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_sharded_construction_on_host_mesh(n_devices):
    from util_subproc import run_with_devices
    out = run_with_devices("""
import numpy as np
from repro.core import (MSTOracle, build_fast, build_sharded, minimize,
                        neighbor_csr, random_hypergraph)
from repro.core.distributed import default_line_graph_mesh
from repro.api import build_engine

h = random_hypergraph(40, 30, seed=5)
mesh = default_line_graph_mesh()
assert mesh.devices.size == %(nd)d, mesh

# the mesh-computed neighbor index equals the host one, row for row
host = neighbor_csr(h)
dev = neighbor_csr(h, mesh=mesh)
assert np.array_equal(host.ptr, dev.ptr)
assert np.array_equal(host.idx, dev.idx)
assert np.array_equal(host.od, dev.od)

# shard-built labels are byte-identical to build_fast on this mesh, for
# even and uneven shard counts, with and without the worker pool, and
# (on a multi-device mesh) with the overlap precompute forced onto the
# devices; forcing it without devices to offload to is a loud error
multi = mesh.devices.size > 1
if not multi:
    try:
        build_sharded(h, mesh=mesh, device_overlaps=True)
        raise AssertionError("device_overlaps=True on 1 device must raise")
    except ValueError:
        pass
serial = build_fast(h)
for num_shards, workers, dev in ((1, None, False), (3, None, multi or None),
                                 (3, 2, None), (%(nd)d, 2, multi or False)):
    sh = build_sharded(h, mesh=mesh, num_shards=num_shards,
                       workers=workers, device_overlaps=dev)
    assert np.array_equal(sh.rank, serial.rank)
    for u in range(h.n):
        assert sh.labels_rank[u].tobytes() == serial.labels_rank[u].tobytes()
        assert sh.labels_s[u].tobytes() == serial.labels_s[u].tobytes()
        assert sh.labels_edge[u].tobytes() == serial.labels_edge[u].tobytes()

# a multi-device mesh flips hl-index construction to sharded via auto
eng = build_engine(h, "hl-index", mesh=mesh)
want_mode = "sharded" if mesh.devices.size > 1 else "serial"
assert eng.construction == want_mode, eng.construction

# the sharded backend's label regime answers == mst-oracle on this mesh
oracle = MSTOracle(h)
rng = np.random.default_rng(1)
us, vs = rng.integers(0, h.n, 50), rng.integers(0, h.n, 50)
want = np.array([oracle.mr(int(u), int(v)) for u, v in zip(us, vs)], np.int64)
for eng in (build_engine(h, "hl-index", mesh=mesh),
            build_engine(h, "sharded", mesh=mesh, build_labels=True)):
    got = np.asarray(eng.mr_batch(us, vs)).astype(np.int64)
    assert np.array_equal(got, want)
    eng.update(inserts=[[0, 1, 2]], deletes=[3])

print("OK")
""" % {"nd": n_devices}, n_devices=n_devices)
    assert "OK" in out


def test_label_regime_scalars_validate_vertex_ids():
    # the sharded backend's label regime short-circuits scalars to the
    # host merge-join; it must reject out-of-range ids exactly like the
    # closure regime's batch-validated path (a Python negative index
    # would silently answer from the wrong row)
    h = random_hypergraph(20, 15, seed=4)
    eng = build_engine(h, "sharded", build_labels=True)
    with pytest.raises(IndexError, match="out of range"):
        eng.mr(-1, 3)
    with pytest.raises(IndexError, match="out of range"):
        eng.mr(0, h.n)
    with pytest.raises(IndexError, match="out of range"):
        eng.s_reach(-1, 3, 2)
    assert isinstance(eng.mr(0, 1), int)           # in-range still answers


def test_device_overlaps_forced_without_devices_raises():
    h = random_hypergraph(10, 8, seed=0)
    with pytest.raises(ValueError, match="multi-device mesh"):
        build_sharded(h, device_overlaps=True)


def test_pool_fallback_stat_recorded():
    h = planted_chain_hypergraph(4, 6, overlap=2, extra_size=2, seed=2)
    sh = build_sharded(h, num_shards=2, workers=2)
    assert sh.stats["pool_fallback"] == 0.0        # healthy pool run
    assert build_sharded(h, num_shards=2).stats["pool_fallback"] == 0.0


def test_unit_mesh_neighbor_csr_stays_on_host_path():
    # a unit mesh must not detour through the device matmul — and either
    # way the CSR is identical
    from repro.api import make_mesh
    h = random_hypergraph(25, 20, seed=9)
    mesh = make_mesh((1, 1), ("data", "model"))
    host = neighbor_csr(h)
    via_mesh = neighbor_csr(h, mesh=mesh)
    np.testing.assert_array_equal(host.idx, via_mesh.idx)
    np.testing.assert_array_equal(host.od, via_mesh.od)
    assert_index_identical(build_fast(h),
                           build_sharded(h, mesh=mesh, num_shards=2))


# ---------------------------------------------------------------------------
# hypothesis property: random hypergraphs × uneven shard counts
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def hypergraphs(draw, max_v=16, max_e=12):
        n = draw(st.integers(3, max_v))
        m = draw(st.integers(1, max_e))
        edges = []
        for _ in range(m):
            size = draw(st.integers(1, min(6, n)))
            edge = draw(st.lists(st.integers(0, n - 1), min_size=size,
                                 max_size=size, unique=True))
            edges.append(edge)
        return from_edge_lists(edges, n=n)

    @settings(max_examples=20, deadline=None)
    @given(hypergraphs(), st.integers(1, 7))
    def test_property_shard_built_byte_identical(h, num_shards):
        serial = build_fast(h)
        sharded = build_sharded(h, num_shards=num_shards)
        assert_index_identical(serial, sharded)
        assert_index_identical(
            minimize(build_basic(h)),
            build_sharded(h, base=build_basic, minimizer=minimize,
                          num_shards=num_shards))

    @settings(max_examples=10, deadline=None)
    @given(hypergraphs(max_v=14, max_e=10), st.integers(2, 5))
    def test_property_shard_built_queries_match_oracle(h, num_shards):
        idx = build_sharded(h, minimizer=minimize, num_shards=num_shards)
        oracle = MSTOracle(h)
        for u in range(h.n):
            for v in range(h.n):
                assert mr_query(idx, u, v) == oracle.mr(u, v)
else:                                                 # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_shard_built_byte_identical():
        pass
