"""Multi-tenant serving: weighted-fair scheduling, priority classes,
deadlines, streaming delivery, and read-replica fan-out.

The fairness/starvation tests run the service in synchronous mode and
step it one micro-batch at a time (``drain(max_batches=1)``) so each
batch's *composition* is observable and the asserted bounds are exact,
not timing-dependent.  Replica tests pin every replica byte-identical
to the writer's snapshot at every version, and every answer anywhere is
pinned to the independent MSTOracle.
"""
import dataclasses
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.api import (DeadlineExceeded, MRRequest, PRIORITY_CLASSES,
                       ReachabilityService, ReplicaGroup, Request,
                       ServiceConfig, SReachRequest, TenantSpec,
                       build_engine, random_hypergraph, serve)
from repro.core import MSTOracle
from repro.core.distributed import default_line_graph_mesh
from repro.core.engine import SnapshotUnsupported
from repro.serve.scheduler import WeightedFairScheduler, _Entry


def _entry(req, expiry=None, now=0.0):
    return _Entry(req, Future(), now, expiry)


def _oracle_check(h, reqs, futs):
    oracle = MSTOracle(h)
    for r, f in zip(reqs, futs):
        mr = oracle.mr(r.u, r.v)
        want = mr if r.kind == "mr" else mr >= r.s
        assert f.result(timeout=60) == want


# ---------------------------------------------------------------------------
# typed config / request surface
# ---------------------------------------------------------------------------

def test_tenant_spec_validation():
    spec = TenantSpec("analytics", 3)
    assert spec.weight == 3.0 and isinstance(spec.weight, float)
    with pytest.raises(ValueError, match="non-empty"):
        TenantSpec("")
    with pytest.raises(ValueError, match="weight"):
        TenantSpec("x", 0.0)
    with pytest.raises(ValueError, match="weight"):
        TenantSpec("x", -1.0)
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.weight = 2.0


def test_service_config_validation():
    cfg = ServiceConfig(max_batch="64", min_bucket=4.0)
    assert cfg.max_batch == 64 and cfg.min_bucket == 4
    with pytest.raises(ValueError, match="min_bucket"):
        ServiceConfig(min_bucket=64, max_batch=8)
    with pytest.raises(ValueError, match="replicas"):
        ServiceConfig(replicas=0)
    with pytest.raises(ValueError, match="quantum"):
        ServiceConfig(quantum=0)
    with pytest.raises(ValueError, match="default_weight"):
        ServiceConfig(default_weight=0)
    with pytest.raises(TypeError, match="TenantSpec"):
        ServiceConfig(tenants=("not-a-spec",))
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.max_batch = 128


def test_request_base_defaults_preserve_old_behavior():
    # positional construction means what it always meant
    r = MRRequest(4, 8)
    assert (r.u, r.v) == (4, 8)
    assert r.tenant == "default" and r.priority == "standard"
    assert r.deadline_ms is None
    assert r == MRRequest(4, 8, tenant="default", priority="standard",
                          deadline_ms=None)
    s = SReachRequest(4, 8, 2)
    assert (s.u, s.v, s.s) == (4, 8, 2)
    assert isinstance(r, Request) and isinstance(s, Request)
    with pytest.raises(dataclasses.FrozenInstanceError):
        r.u = 3
    r2 = dataclasses.replace(r, tenant="t", priority="interactive")
    assert (r2.u, r2.v, r2.tenant, r2.priority) == (4, 8, "t", "interactive")
    # the metadata fields live on the base — what docs check 8 pins
    assert {f.name for f in dataclasses.fields(Request)} == \
        {"tenant", "priority", "deadline_ms"}


def test_submit_validates_metadata():
    h = random_hypergraph(20, 25, seed=0)
    svc = serve(h, "hl-index", start=False)
    with pytest.raises(ValueError, match="priority"):
        svc.submit(MRRequest(1, 2, priority="urgent"))
    with pytest.raises(ValueError, match="tenant"):
        svc.submit(MRRequest(1, 2, tenant=""))
    with pytest.raises(ValueError, match="deadline_ms"):
        svc.submit(MRRequest(1, 2, deadline_ms=0))
    with pytest.raises(ValueError, match="deadline_ms"):
        svc.submit(MRRequest(1, 2, deadline_ms=-5.0))
    assert svc.pending() == 0        # nothing invalid was enqueued


# ---------------------------------------------------------------------------
# scheduler unit tests (policy in isolation)
# ---------------------------------------------------------------------------

def test_scheduler_weighted_shares_exact():
    sched = WeightedFairScheduler((TenantSpec("a", 1.0), TenantSpec("b", 3.0)),
                                  quantum=8)
    for i in range(100):
        sched.push(_entry(MRRequest(0, 1, tenant="a")))
        sched.push(_entry(MRRequest(0, 1, tenant="b")))
    selected, expired = sched.take(64, now=0.0)
    assert not expired and len(selected) == 64
    counts = {}
    for e in selected:
        counts[e.request.tenant] = counts.get(e.request.tenant, 0) + 1
    # DRR with quantum 8: per pass a banks 8 credits, b banks 24 — a
    # 64-slot batch is exactly two passes
    assert counts == {"a": 16, "b": 48}
    assert len(sched) == 136
    assert sched.backlog() == {"a": 84, "b": 52}


def test_scheduler_priority_bands_strict():
    sched = WeightedFairScheduler()
    for i in range(50):
        sched.push(_entry(MRRequest(0, 1, tenant="g", priority="batch")))
    for i in range(5):
        sched.push(_entry(MRRequest(0, 1, tenant="s", priority="standard")))
    for i in range(3):
        sched.push(_entry(MRRequest(0, 1, tenant="i", priority="interactive")))
    selected, _ = sched.take(32, now=0.0)
    prios = [e.request.priority for e in selected]
    # strict bands: all interactive, then all standard, then batch fill
    assert prios[:3] == ["interactive"] * 3
    assert prios[3:8] == ["standard"] * 5
    assert prios[8:] == ["batch"] * 24
    # fairness never leaves bucket slots idle under backlog
    assert len(selected) == 32


def test_scheduler_expired_swept_without_consuming_share():
    sched = WeightedFairScheduler()
    for i in range(10):
        sched.push(_entry(MRRequest(0, 1, tenant="a"), expiry=1.0))
    for i in range(10):
        sched.push(_entry(MRRequest(0, 1, tenant="a"), expiry=None))
    selected, expired = sched.take(64, now=2.0)
    assert len(expired) == 10 and len(selected) == 10
    assert all(e.expiry == 1.0 for e in expired)
    assert len(sched) == 0


# ---------------------------------------------------------------------------
# adversarial fairness through the service
# ---------------------------------------------------------------------------

def test_flooding_tenant_cannot_starve_light_tenant():
    h = random_hypergraph(40, 60, seed=1)
    cfg = ServiceConfig(max_batch=64, tenants=(TenantSpec("greedy", 1.0),
                                               TenantSpec("light", 1.0)))
    svc = serve(h, "hl-index", config=cfg, start=False)
    rng = np.random.default_rng(0)
    flood = [MRRequest(int(rng.integers(h.n)), int(rng.integers(h.n)),
                       tenant="greedy") for _ in range(2000)]
    greedy_futs = svc.submit_many(flood)
    light = [MRRequest(int(rng.integers(h.n)), int(rng.integers(h.n)),
                       tenant="light") for _ in range(5)]
    light_futs = svc.submit_many(light)
    # the weighted-fair bound: the light tenant waits at most ONE
    # micro-batch behind a 2000-deep adversarial flood
    svc.drain(max_batches=1)
    assert all(f.done() for f in light_futs)
    _oracle_check(h, light, light_futs)
    svc.drain()
    _oracle_check(h, flood, greedy_futs)
    st = svc.stats()
    assert st.tenant_answered == {"greedy": 2000, "light": 5}
    assert st.expired == 0


def test_weighted_shares_shape_every_batch():
    h = random_hypergraph(40, 60, seed=2)
    cfg = ServiceConfig(max_batch=64, quantum=8,
                        tenants=(TenantSpec("a", 1.0), TenantSpec("b", 3.0)))
    svc = serve(h, "hl-index", config=cfg, start=False)
    rng = np.random.default_rng(1)
    for _ in range(600):
        svc.submit(MRRequest(int(rng.integers(h.n)), int(rng.integers(h.n)),
                             tenant="a"))
        svc.submit(MRRequest(int(rng.integers(h.n)), int(rng.integers(h.n)),
                             tenant="b"))
    prev = {"a": 0, "b": 0}
    # while both tenants stay backlogged, every batch splits 1:3 exactly
    for _ in range(5):
        svc.drain(max_batches=1)
        st = svc.stats()
        got = {t: st.tenant_answered[t] - prev[t] for t in ("a", "b")}
        assert got == {"a": 16, "b": 48}
        prev = dict(st.tenant_answered)
    svc.drain()
    assert svc.stats().tenant_answered == {"a": 600, "b": 600}


def test_priority_inversion_bounded():
    h = random_hypergraph(40, 60, seed=3)
    svc = serve(h, "hl-index", config=ServiceConfig(max_batch=64),
                start=False)
    rng = np.random.default_rng(2)
    flood = [MRRequest(int(rng.integers(h.n)), int(rng.integers(h.n)),
                       tenant="greedy", priority="batch")
             for _ in range(500)]
    svc.submit_many(flood)
    probe = MRRequest(3, 7, tenant="dash", priority="interactive")
    probe_fut = svc.submit(probe)
    svc.drain(max_batches=1)
    # the interactive probe rides the very next batch despite arriving
    # behind 500 batch-class requests
    assert probe_fut.done()
    _oracle_check(h, [probe], [probe_fut])
    svc.drain()


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_deadline_expiry_fails_fast_with_typed_error():
    h = random_hypergraph(30, 40, seed=4)
    svc = serve(h, "hl-index", start=False)
    doomed = MRRequest(1, 2, deadline_ms=1.0)
    doomed_fut = svc.submit(doomed)
    live = MRRequest(3, 4)
    live_fut = svc.submit(live)
    time.sleep(0.02)
    resolved = svc.drain()
    assert resolved == 2             # answered + deadline-failed both count
    with pytest.raises(DeadlineExceeded) as err:
        doomed_fut.result()
    assert err.value.request is doomed
    assert err.value.waited_ms >= 1.0
    _oracle_check(h, [live], [live_fut])
    st = svc.stats()
    assert st.expired == 1 and st.tenant_expired == {"default": 1}
    assert st.tenant_answered == {"default": 1}


def test_generous_deadline_is_met():
    h = random_hypergraph(30, 40, seed=5)
    svc = serve(h, "hl-index", start=False)
    reqs = [MRRequest(i, i + 1, deadline_ms=60_000.0) for i in range(10)]
    futs = svc.submit_many(reqs)
    svc.drain()
    _oracle_check(h, reqs, futs)
    assert svc.stats().expired == 0


# ---------------------------------------------------------------------------
# streaming / callback delivery
# ---------------------------------------------------------------------------

def test_submit_stream_yields_resolved_futures_sync():
    h = random_hypergraph(30, 40, seed=6)
    svc = serve(h, "hl-index", start=False)
    reqs = [MRRequest(i, (i * 3) % h.n) if i % 2 else
            SReachRequest(i, (i * 3) % h.n, 2) for i in range(20)]
    got = list(svc.submit_stream(reqs))
    assert len(got) == 20
    assert all(f.done() for _, f in got)
    by_req = {id(r): f for r, f in got}
    _oracle_check(h, reqs, [by_req[id(r)] for r in reqs])


def test_submit_stream_threaded_completion_order():
    h = random_hypergraph(30, 40, seed=7)
    with serve(h, "hl-index", config=ServiceConfig(max_wait_ms=1.0)) as svc:
        reqs = [MRRequest(i, (i * 7) % h.n) for i in range(30)]
        got = list(svc.submit_stream(reqs))
    assert sorted(id(r) for r, _ in got) == sorted(id(r) for r in reqs)
    by_req = {id(r): f for r, f in got}
    _oracle_check(h, reqs, [by_req[id(r)] for r in reqs])


def test_on_result_callback_hook():
    h = random_hypergraph(30, 40, seed=8)
    svc = serve(h, "hl-index", start=False)
    seen = []
    reqs = [MRRequest(i, i + 2) for i in range(8)]
    futs = [svc.submit(r, on_result=lambda rq, f: seen.append((rq, f)))
            for r in reqs]
    svc.drain()
    assert len(seen) == 8
    assert {id(r) for r, _ in seen} == {id(r) for r in reqs}
    assert all(f.done() for _, f in seen)
    _oracle_check(h, reqs, futs)
    # the hook fires on failure paths too (deadline expiry)
    failed = []
    svc.submit(MRRequest(0, 1, deadline_ms=1.0),
               on_result=lambda rq, f: failed.append(f))
    time.sleep(0.01)
    svc.drain()
    assert len(failed) == 1 and isinstance(failed[0].exception(),
                                           DeadlineExceeded)


# ---------------------------------------------------------------------------
# replica fan-out
# ---------------------------------------------------------------------------

def _assert_replicas_match_writer(grp):
    host = grp.engine.snapshot()     # cached/current: no re-derivation
    for r in grp.replicas:
        assert r.snap is not None and r.snap.version == host.version
        np.testing.assert_array_equal(np.asarray(r.snap.ranks),
                                      np.asarray(host.ranks))
        np.testing.assert_array_equal(np.asarray(r.snap.svals),
                                      np.asarray(host.svals))
        np.testing.assert_array_equal(np.asarray(r.snap.lengths),
                                      np.asarray(host.lengths))


def test_replica_group_churn_stays_byte_identical():
    # the multi-chain graph from the serving regression tests: chains A
    # and B absorb scoped edits while the long chain C pins the padded
    # geometry, so updates fan out as row patches (not full re-lands)
    from repro.core import from_edge_lists
    edges = [[0, 1, 2], [1, 2, 3],            # chain A
             [10, 11, 12], [11, 12, 13]]      # chain B
    for i in range(10):                        # chain C dominates lmax
        edges.append([20 + 2 * i, 21 + 2 * i, 22 + 2 * i, 23 + 2 * i])
    h = from_edge_lists(edges)
    eng = build_engine(h, "hl-index")
    grp = ReplicaGroup(eng, 3, mesh=default_line_graph_mesh(),
                       config=ServiceConfig(max_batch=32), start=False)
    rng = np.random.default_rng(3)
    edits = [[[0, 1, 2, 3]], [[10, 11, 12, 13]], [[0, 2, 3]], [[11, 13]]]
    for ins in edits:
        cur = grp.engine.h
        reqs = [MRRequest(int(rng.integers(cur.n)), int(rng.integers(cur.n)))
                for _ in range(80)]
        futs = grp.submit_many(reqs)
        grp.drain()
        _oracle_check(cur, reqs, futs)
        _assert_replicas_match_writer(grp)
        grp.update(inserts=ins)      # single writer; fan-out at next batch
    # post-churn: all replicas answer the updated graph correctly
    cur = grp.engine.h
    reqs = [MRRequest(int(rng.integers(cur.n)), int(rng.integers(cur.n)))
            for _ in range(80)]
    futs = grp.submit_many(reqs)
    grp.drain()
    _oracle_check(cur, reqs, futs)
    _assert_replicas_match_writer(grp)
    rstats = grp.replica_stats()
    # every replica served batches (round-robin) ...
    assert all(r["batches"] >= 1 for r in rstats)
    # ... was landed in full exactly once, and patched row-wise since
    assert all(r["full_relands"] == 1 for r in rstats)
    assert all(r["rows_patched"] > 0 for r in rstats)
    assert grp.stats().mesh_rows_patched == sum(r["rows_patched"]
                                                for r in rstats)


def test_replica_group_kernel_serving_matches_oracle():
    h = random_hypergraph(40, 60, seed=10)
    eng = build_engine(h, "hl-index")
    grp = ReplicaGroup(eng, 2, config=ServiceConfig(use_kernels=True,
                                                    max_batch=32),
                       start=False)
    rng = np.random.default_rng(4)
    reqs = [SReachRequest(int(rng.integers(h.n)), int(rng.integers(h.n)),
                          int(rng.integers(1, 4))) for _ in range(64)]
    futs = grp.submit_many(reqs)
    grp.drain()
    _oracle_check(h, reqs, futs)
    assert grp.stats().kernel_batches >= 1


def test_replica_group_refuses_snapshotless_backend():
    h = random_hypergraph(25, 35, seed=11)
    eng = build_engine(h, "online")
    with pytest.raises(SnapshotUnsupported, match="replica"):
        ReplicaGroup(eng, 2, start=False)


def test_plain_service_refuses_replicated_config():
    h = random_hypergraph(25, 35, seed=12)
    eng = build_engine(h, "hl-index")
    with pytest.raises(ValueError, match="ReplicaGroup"):
        ReachabilityService(eng, config=ServiceConfig(replicas=2),
                            start=False)


def test_serve_routes_replicated_config_to_group():
    h = random_hypergraph(30, 45, seed=13)
    svc = serve(h, "hl-index", config=ServiceConfig(replicas=2), start=False)
    assert isinstance(svc, ReplicaGroup) and len(svc.replicas) == 2
    reqs = [MRRequest(i % h.n, (i * 5) % h.n) for i in range(40)]
    futs = svc.submit_many(reqs)
    svc.drain()
    _oracle_check(h, reqs, futs)


# ---------------------------------------------------------------------------
# API redesign: deprecation shim + re-exports
# ---------------------------------------------------------------------------

def test_serve_legacy_kwargs_warn_and_still_work():
    h = random_hypergraph(25, 35, seed=14)
    with pytest.warns(DeprecationWarning, match="ServiceConfig"):
        svc = serve(h, "hl-index", start=False, max_batch=32, min_bucket=4)
    assert svc.max_batch == 32 and svc.min_bucket == 4
    f = svc.mr(1, 2)
    svc.drain()
    assert f.result() == MSTOracle(h).mr(1, 2)
    # legacy kwargs override the matching config field
    with pytest.warns(DeprecationWarning):
        svc2 = serve(h, "hl-index", start=False,
                     config=ServiceConfig(max_batch=128), max_batch=16)
    assert svc2.max_batch == 16


def test_config_path_does_not_warn():
    import warnings
    h = random_hypergraph(25, 35, seed=15)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        svc = serve(h, "hl-index", start=False,
                    config=ServiceConfig(max_batch=32))
    assert svc.max_batch == 32


def test_api_reexports_cover_multitenant_surface():
    import repro.api as api
    for name in ("Request", "MRRequest", "SReachRequest", "ServiceConfig",
                 "TenantSpec", "PRIORITY_CLASSES", "DeadlineExceeded",
                 "ReplicaGroup", "ReachabilityService", "serve"):
        assert name in api.__all__ and getattr(api, name) is not None
    import repro.serve as srv
    assert srv.WeightedFairScheduler is WeightedFairScheduler
    assert srv.PRIORITY_CLASSES == {"interactive": 0, "standard": 1,
                                    "batch": 2}
    assert PRIORITY_CLASSES["interactive"] < PRIORITY_CLASSES["standard"] \
        < PRIORITY_CLASSES["batch"]
