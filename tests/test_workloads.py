"""Workload subsystem tests: witness machinery, the standalone
structures (DistanceOracle, set/topk helpers), the serving round trip
for every registered request kind, and the hypothesis property that
every extracted witness is a valid s-walk realizing exactly the
reported MR.

Backend × op conformance cells live in tests/test_conformance.py; this
module covers what the matrix can't — the subsystem's own invariants.
"""
import dataclasses

import numpy as np
import pytest

from repro.api import (MRSetRequest, SDistanceRequest, SReachKRequest,
                       TopSRequest, WitnessRequest, WorkloadUnsupported,
                       build_engine, from_edge_lists, random_hypergraph,
                       serve, verify_witness)
from repro.core import (MSTOracle, brute_force_mr_from_set,
                        brute_force_mr_set, brute_force_s_distance,
                        brute_force_s_reach_k, brute_force_top_s,
                        brute_force_witness)
from repro.serve.reach_service import REQUEST_TYPES
from repro.workloads import (DistanceOracle, Witness, WORKLOAD_OPS,
                             bounded_s_distance, cross_pairs,
                             extract_witness, hop_bounded_s_reach,
                             normalize_vertex_set, select_top_s, walk_wod,
                             workload_capabilities)


# ---------------------------------------------------------------------------
# walk primitives
# ---------------------------------------------------------------------------

def test_walk_wod_and_verify():
    h = from_edge_lists([[0, 1, 2], [1, 2, 3], [3, 4], [5, 6, 7]], n=8)
    assert walk_wod(h, ()) == 0
    assert walk_wod(h, (0,)) == 3            # singleton walk: |e|
    assert walk_wod(h, (0, 1)) == 2          # overlap {1, 2}
    assert walk_wod(h, (0, 1, 2)) == 1       # min(2, 1)
    assert walk_wod(h, (0, 3)) == 0          # disjoint edges
    with pytest.raises(IndexError):
        walk_wod(h, (0, 99))
    assert verify_witness(h, Witness(0, 3, 2, (0, 1)))
    assert verify_witness(h, Witness(0, 5, 0, ()))       # unreachable pair
    assert not verify_witness(h, Witness(0, 3, 2, ()))   # s>0 needs a walk
    assert not verify_witness(h, Witness(0, 3, 3, (0, 1)))   # wod != s
    assert not verify_witness(h, Witness(5, 3, 2, (0, 1)))   # u not in first
    assert not verify_witness(h, Witness(0, 5, 2, (0, 1)))   # v not in last


def test_extract_witness_matches_brute_force():
    h = random_hypergraph(25, 40, seed=11)
    oracle = MSTOracle(h)
    rng = np.random.default_rng(2)
    for u, v in rng.integers(0, h.n, (25, 2)):
        u, v = int(u), int(v)
        k = oracle.mr(u, v)
        bk, bwalk = brute_force_witness(h, u, v)
        assert bk == k                       # brute force agrees with oracle
        assert walk_wod(h, bwalk) == k if k else bwalk == ()
        if k == 0:
            continue
        walk = extract_witness(h, u, v, k)
        w = Witness(u, v, k, walk)
        assert verify_witness(h, w)
    # asking for a strength above the true MR is loud, not a bad walk
    with pytest.raises(ValueError):
        u, v = 0, 1
        extract_witness(h, u, v, oracle.mr(u, v) + 5)


# ---------------------------------------------------------------------------
# standalone structures
# ---------------------------------------------------------------------------

def test_hop_bounded_matches_brute_force():
    h = random_hypergraph(25, 40, seed=4)
    rng = np.random.default_rng(5)
    for u, v in rng.integers(0, h.n, (15, 2)):
        for s in (1, 2, 3):
            d = bounded_s_distance(h, int(u), int(v), s)
            assert d == brute_force_s_distance(h, int(u), int(v), s)
            for k in (1, 2, h.m):
                assert hop_bounded_s_reach(h, int(u), int(v), s, k) == \
                    brute_force_s_reach_k(h, int(u), int(v), s, k)
    # the hop budget truncates: distance-d pairs unreachable under d-1
    assert bounded_s_distance(h, 0, 0, 1, max_hyperedges=0) in (0, 1)


def test_distance_oracle_certified_bounds():
    h = random_hypergraph(30, 45, seed=3)
    for s in (1, 2, 3):
        do = DistanceOracle(h, s)
        assert do.num_landmarks >= 1 or h.m == 0
        assert do.nbytes() > 0
        rng = np.random.default_rng(s)
        for u, v in rng.integers(0, h.n, (30, 2)):
            bound = do.distance(int(u), int(v))
            exact = brute_force_s_distance(h, int(u), int(v), s)
            assert (bound == 0) == (exact == 0)      # never wrong on reach
            assert bound >= exact                    # certified upper bound
    with pytest.raises(ValueError):
        DistanceOracle(h, 0)


def test_distance_oracle_extra_landmarks_tighten():
    h = random_hypergraph(40, 70, seed=8)
    lean = DistanceOracle(h, 1, extra_landmarks=0)
    rich = DistanceOracle(h, 1, extra_landmarks=8)
    assert rich.num_landmarks >= lean.num_landmarks
    rng = np.random.default_rng(0)
    pairs = rng.integers(0, h.n, (40, 2))
    for u, v in pairs:
        assert rich.distance(int(u), int(v)) <= lean.distance(int(u), int(v))


def test_set_helpers():
    us = normalize_vertex_set([3, 1, 3, 2], 10, "us")
    np.testing.assert_array_equal(us, [1, 2, 3])
    with pytest.raises(ValueError):
        normalize_vertex_set([], 10, "us")
    with pytest.raises(ValueError):
        normalize_vertex_set([1.5], 10, "us")
    with pytest.raises(IndexError):
        normalize_vertex_set([10], 10, "us")
    a, b = cross_pairs(np.array([0, 1]), np.array([5, 6, 7]))
    np.testing.assert_array_equal(a, [0, 0, 0, 1, 1, 1])
    np.testing.assert_array_equal(b, [5, 6, 7, 5, 6, 7])


def test_select_top_s():
    row = np.array([0, 5, 3, 5, 0, 2], np.int64)
    verts, vals = select_top_s(row, u=1, k=3)
    np.testing.assert_array_equal(verts, [3, 2, 5])   # 1 (self) excluded
    np.testing.assert_array_equal(vals, [5, 3, 2])
    verts, vals = select_top_s(row, u=0, k=100)       # k past the nonzeros
    np.testing.assert_array_equal(verts, [1, 3, 2, 5])
    np.testing.assert_array_equal(vals, [5, 5, 3, 2])


# ---------------------------------------------------------------------------
# engine-level invariants the matrix doesn't pin
# ---------------------------------------------------------------------------

def test_workload_capabilities_registry_shape():
    caps = workload_capabilities()
    assert all(tuple(row) == WORKLOAD_OPS for row in caps.values())
    assert all(caps["hl-index"].values())
    assert not any(caps["mst-oracle"].values())


def test_distance_oracle_cache_invalidated_by_update():
    h = random_hypergraph(20, 25, seed=6)
    eng = build_engine(h, "hl-index")
    do1 = eng.distance_oracle(2)
    assert eng.distance_oracle(2) is do1             # cached per (s, extras)
    eng.update(inserts=[[0, 1, 2, 3]])
    assert eng.distance_oracle(2) is not do1         # update invalidates
    for u in range(5):
        bound = eng.s_distance(0, u, 2)
        exact = brute_force_s_distance(eng.h, 0, u, 2)
        assert (bound == 0) == (exact == 0) and bound >= exact


def test_workloads_after_update_match_brute_force():
    h = random_hypergraph(20, 25, seed=9)
    eng = build_engine(h, "hl-index")
    eng.update(inserts=[[0, 5, 9, 11]], deletes=[1])
    h2 = eng.h
    oracle = MSTOracle(h2)
    for u, v in ((0, 9), (5, 11), (2, 17)):
        w = eng.mr_witness(u, v)
        assert w.s == oracle.mr(u, v) and verify_witness(h2, w)
        assert eng.s_reach_k(u, v, 1, 2) == brute_force_s_reach_k(
            h2, u, v, 1, 2)
    verts, vals = eng.top_s(0, 4)
    bv, bs = brute_force_top_s(h2, 0, 4)
    np.testing.assert_array_equal(verts, bv)
    np.testing.assert_array_equal(vals, bs)
    assert eng.mr_set([0, 5], [9, 11]) == brute_force_mr_set(
        h2, [0, 5], [9, 11])
    targets = np.arange(h2.n)
    np.testing.assert_array_equal(
        eng.mr_from_set([0, 5], targets),
        brute_force_mr_from_set(h2, [0, 5], targets))


# ---------------------------------------------------------------------------
# serving round trip: every registered request kind through submit()
# ---------------------------------------------------------------------------

# one well-formed instance per registered kind (u/v/s/k in range for the
# 30-vertex fixture below); a new REQUEST_TYPES entry without a row here
# fails test_request_registry_covered
_SAMPLE_FIELDS = {
    "mr": dict(u=0, v=1),
    "s_reach": dict(u=0, v=1, s=2),
    "witness": dict(u=0, v=1),
    "s_reach_k": dict(u=0, v=1, s=2, k=3),
    "mr_set": dict(us=(0, 1), vs=(2, 3)),
    "top_s": dict(u=0, k=3),
    "s_distance": dict(u=0, v=1, s=2),
}


def test_request_registry_covered():
    assert set(_SAMPLE_FIELDS) == set(REQUEST_TYPES)


@pytest.fixture(scope="module")
def svc():
    h = random_hypergraph(30, 45, seed=3)
    service = serve(h, "hl-index", start=False)
    yield service
    service.close()


@pytest.mark.parametrize("kind", sorted(_SAMPLE_FIELDS))
def test_request_metadata_roundtrip(svc, kind):
    """Every public request type takes the shared tenant/priority/
    deadline metadata through the same admission validation: good
    metadata resolves, each bad field raises — for every kind."""
    cls = REQUEST_TYPES[kind]
    fields = _SAMPLE_FIELDS[kind]
    fut = svc.submit(cls(**fields, tenant="t9", priority="interactive",
                         deadline_ms=10_000.0))
    svc.drain()
    assert fut.done() and fut.exception() is None
    req = cls(**fields)
    assert (req.tenant, req.priority, req.deadline_ms) == \
        ("default", "standard", None)        # defaults intact per kind
    with pytest.raises(ValueError):
        svc.submit(cls(**fields, tenant=""))
    with pytest.raises(ValueError):
        svc.submit(cls(**fields, priority="warp-speed"))
    with pytest.raises(ValueError):
        svc.submit(cls(**fields, deadline_ms=0))


def test_service_workload_answers_match_brute_force(svc):
    h = svc.engine.h
    oracle = MSTOracle(h)
    f_w = svc.witness(3, 17)
    f_k = svc.s_reach_k(3, 17, 2, 2)
    f_set = svc.mr_set([0, 1, 2], [10, 11, 12])
    f_top = svc.top_s(5, 4)
    f_d = svc.s_distance(3, 17, 2)
    svc.drain()
    w = f_w.result(timeout=0)
    assert w.s == oracle.mr(3, 17) and verify_witness(h, w)
    assert f_k.result(timeout=0) == brute_force_s_reach_k(h, 3, 17, 2, 2)
    assert f_set.result(timeout=0) == brute_force_mr_set(
        h, [0, 1, 2], [10, 11, 12])
    bv, bs = brute_force_top_s(h, 5, 4)
    assert list(f_top.result(timeout=0)) == list(zip(bv.tolist(),
                                                     bs.tolist()))
    bound, exact = f_d.result(timeout=0), brute_force_s_distance(h, 3, 17, 2)
    assert (bound == 0) == (exact == 0) and bound >= exact
    stats = svc.stats().as_dict()
    assert all(stats["workload_answered"].get(k, 0) >= 1
               for k in ("witness", "s_reach_k", "mr_set", "top_s",
                         "s_distance"))


def test_service_refuses_unsupported_workloads_at_admission():
    h = random_hypergraph(20, 25, seed=1)
    with serve(h, "online", start=False) as svc_o:
        with pytest.raises(WorkloadUnsupported):
            svc_o.witness(0, 1)
        with pytest.raises(WorkloadUnsupported):
            svc_o.top_s(0, 3)
        fut = svc_o.s_reach_k(0, 1, 1, 3)    # traversal ops still served
        svc_o.drain()
        assert isinstance(fut.result(timeout=0), bool)
        assert svc_o.stats().expired == 0


def test_request_types_frozen_and_hashable():
    for kind, fields in _SAMPLE_FIELDS.items():
        req = REQUEST_TYPES[kind](**fields)
        assert hash(req) == hash(REQUEST_TYPES[kind](**fields))
        with pytest.raises(dataclasses.FrozenInstanceError):
            req.tenant = "x"
    # mr_set coerces list inputs to tuples so the instance stays hashable
    req = MRSetRequest([3, 1], [2])
    assert req.us == (3, 1) and req.vs == (2,)
    assert hash(req) == hash(MRSetRequest((3, 1), (2,)))


def test_workload_requests_importable_from_api():
    import repro.api as api
    for name in ("WitnessRequest", "SReachKRequest", "MRSetRequest",
                 "TopSRequest", "SDistanceRequest", "Witness",
                 "verify_witness", "DistanceOracle", "WorkloadUnsupported",
                 "WORKLOAD_OPS", "workload_capabilities"):
        assert name in api.__all__ and hasattr(api, name)
    assert {WitnessRequest, SReachKRequest, MRSetRequest, TopSRequest,
            SDistanceRequest} <= set(REQUEST_TYPES.values())


# ---------------------------------------------------------------------------
# property: witnesses are valid s-walks realizing exactly the MR
# ---------------------------------------------------------------------------

# guarded import (not a module-level importorskip: that would skip the
# whole file, and the non-property tests above must run regardless)
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                          # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @st.composite
    def small_hypergraphs(draw):
        n = draw(st.integers(4, 12))
        m = draw(st.integers(1, 10))
        edges = [sorted(draw(st.sets(st.integers(0, n - 1), min_size=1,
                                     max_size=min(n, 5))))
                 for _ in range(m)]
        return from_edge_lists(edges, n=n)

    @settings(max_examples=40, deadline=None)
    @given(h=small_hypergraphs(), data=st.data())
    def test_property_witness_walks_are_valid(h, data):
        u = data.draw(st.integers(0, h.n - 1), label="u")
        v = data.draw(st.integers(0, h.n - 1), label="v")
        oracle = MSTOracle(h)
        k = oracle.mr(u, v)
        if k == 0:
            return
        walk = extract_witness(h, u, v, k)
        # a genuine s-walk: endpoints covered, every consecutive overlap
        # >= k, and its min overlap is *exactly* the reported MR
        assert walk[0] in h.edges_of(u) and walk[-1] in h.edges_of(v)
        assert walk_wod(h, walk) == k
        assert verify_witness(h, Witness(u, v, k, walk))
else:                                        # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_witness_walks_are_valid():
        pass
