"""End-to-end behaviour tests for the paper's system: the full pipeline
(build -> minimize -> batched serve) on a nontrivial graph, the epidemic
case-study workflow (Exp-5), and the data-pipeline integration."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (colocation_hypergraph, random_hypergraph, compact,
                        build_fast, minimize, PaddedIndex, mr_query,
                        mr_oracle_dense, mr_matrix, vertex_mr_from_edge_mr,
                        threshold_closure_mr, distinct_thresholds)


def test_end_to_end_pipeline():
    """graph -> compaction -> fast construction -> minimal index ->
    batched device queries == oracle."""
    h0 = random_hypergraph(60, 90, min_size=2, max_size=7, seed=42)
    h, _ = compact(h0)
    idx = minimize(build_fast(h))
    oracle = mr_oracle_dense(h)
    pidx = PaddedIndex(idx)
    rng = np.random.default_rng(0)
    us, vs = rng.integers(0, h.n, 500), rng.integers(0, h.n, 500)
    got = np.asarray(pidx.mr(us, vs))
    want = np.array([oracle[u, v] for u, v in zip(us, vs)])
    np.testing.assert_array_equal(got, want)
    # index is no larger than the full one and much smaller than O(n*m)
    assert idx.num_labels <= build_fast(h).num_labels <= h.n * h.m


def test_epidemic_case_study_workflow():
    """Exp-5 analog: co-location hypergraph; risk = MR to the index case."""
    h = colocation_hypergraph(n_people=80, n_places=6, n_days=12,
                              p_checkin=0.05, seed=7)
    if h.m == 0:
        pytest.skip("degenerate random draw")
    idx = minimize(build_fast(h))
    oracle = mr_oracle_dense(h)
    patient_zero = int(np.argmax(h.vertex_degrees))
    pidx = PaddedIndex(idx)
    everyone = np.arange(h.n)
    risk = np.asarray(pidx.mr(np.full(h.n, patient_zero), everyone))
    want = oracle[patient_zero]
    np.testing.assert_array_equal(risk, want)
    # risk to self is the max co-location group size
    assert risk[patient_zero] == int(
        h.edge_sizes[h.edges_of(patient_zero)].max())


def test_semiring_vertex_queries_match_index():
    h = random_hypergraph(30, 45, seed=17)
    w_star = mr_matrix(h)
    idx = build_fast(h)
    rng = np.random.default_rng(3)
    us, vs = rng.integers(0, h.n, 50), rng.integers(0, h.n, 50)
    got = vertex_mr_from_edge_mr(h, w_star, us, vs)
    want = np.array([mr_query(idx, int(u), int(v)) for u, v in zip(us, vs)])
    np.testing.assert_array_equal(got, want)


def test_bucketized_thresholds_lower_bound():
    """Coarse threshold ladders give exact-or-lower MR (the approximate
    mode for huge delta; DESIGN.md section 2)."""
    h = random_hypergraph(25, 40, seed=23)
    w = jnp.asarray(h.line_graph(np.int32))
    exact = np.asarray(threshold_closure_mr(w))
    thr = distinct_thresholds(np.asarray(w))
    coarse = np.asarray(threshold_closure_mr(w, thr[::2]))
    assert (coarse <= exact).all()
    # and exact where the value is in the coarse ladder
    mask = np.isin(exact, thr[::2])
    np.testing.assert_array_equal(coarse[mask], exact[mask])
