"""Request-based serving: ReachabilityService admission micro-batching,
version-keyed snapshot reuse across updates, dirty-row re-derivation,
and the shared batch-input validation contract.

Answers are always pinned against the independent MSTOracle; the
partial snapshot refresh is additionally pinned *byte-identical* to a
from-scratch derivation — caching may never change an answer, or a bit.
"""
import dataclasses
import time

import numpy as np
import pytest

from repro.api import (MRRequest, ReachabilityService, ServiceConfig,
                       SReachRequest, available_backends, build_engine,
                       serve, update_capabilities)
from repro.core import (MSTOracle, apply_edge_edits,
                        planted_chain_hypergraph, random_hypergraph)
from repro.core.engine import SnapshotUnsupported, validate_batch
from repro.core.query import DeviceSnapshot
from repro.serve.reach_service import (REQUEST_TYPES, ServiceStats,
                                       _bucket_size)

BACKENDS = available_backends()
CAPS = update_capabilities()


def _mixed_requests(h, rng, count):
    reqs, answer = [], []
    oracle = MSTOracle(h)
    for _ in range(count):
        u, v = int(rng.integers(h.n)), int(rng.integers(h.n))
        mr = oracle.mr(u, v)
        if rng.random() < 0.5:
            reqs.append(MRRequest(u, v))
            answer.append(mr)
        else:
            s = int(rng.integers(1, 5))
            reqs.append(SReachRequest(u, v, s))
            answer.append(mr >= s)
    return reqs, answer


# ---------------------------------------------------------------------------
# service lifecycle (the per-backend service-vs-oracle equivalence check
# moved into the conformance matrix: tests/test_conformance.py)
# ---------------------------------------------------------------------------

def test_service_background_thread():
    h = random_hypergraph(25, 35, seed=11)
    rng = np.random.default_rng(0)
    reqs, want = _mixed_requests(h, rng, 120)
    with serve(h, "hl-index", config=ServiceConfig(max_wait_ms=1.0)) as svc:
        futs = svc.submit_many(reqs)
        got = [f.result(timeout=60) for f in futs]
    assert got == want
    st = svc.stats()
    assert st.submitted == st.answered == 120
    assert st.batches >= 1


def test_close_answers_everything_submitted():
    h = random_hypergraph(20, 30, seed=5)
    svc = serve(h, "hl-index", config=ServiceConfig(max_wait_ms=5.0))
    futs = [svc.mr(0, i % h.n) for i in range(50)]
    svc.close()
    assert all(f.done() for f in futs)
    # post-close submissions still answer through the synchronous drain
    f = svc.mr(1, 2)
    svc.drain()
    assert f.done()


# ---------------------------------------------------------------------------
# admission bucketing: power-of-two padded shapes, bounded program count
# ---------------------------------------------------------------------------

def test_bucket_size_policy():
    assert _bucket_size(1, 8, 4096) == 8
    assert _bucket_size(8, 8, 4096) == 8
    assert _bucket_size(9, 8, 4096) == 16
    assert _bucket_size(1000, 8, 4096) == 1024
    assert _bucket_size(4097, 8, 4096) == 4097   # never truncates a batch
    assert _bucket_size(3000, 8, 2048) == 3000


def test_bucketing_bounds_dispatch_shapes():
    h = random_hypergraph(30, 45, seed=3)
    svc = serve(h, "hl-index", start=False,
                config=ServiceConfig(min_bucket=8, max_batch=64))
    rng = np.random.default_rng(1)
    oracle = MSTOracle(h)
    futs = []
    # ragged arrival pattern: many distinct queue depths
    for q in (1, 3, 5, 9, 17, 33, 64, 64, 7):
        futs += [svc.mr(int(rng.integers(h.n)), int(rng.integers(h.n)))
                 for _ in range(q)]
        svc.drain()
    st = svc.stats()
    for bucket in st.bucket_histogram:
        assert bucket & (bucket - 1) == 0 and bucket >= 8   # pow2, >= min
    # 9 ragged batches but at most log2(64/8)+1 = 4 distinct shapes
    assert len(st.bucket_histogram) <= 4
    assert st.padded_queries > 0
    # padding never leaks into answers
    for f in futs:
        assert isinstance(f.result(timeout=0), int)
    us = [int(rng.integers(h.n)) for _ in range(10)]
    vs = [int(rng.integers(h.n)) for _ in range(10)]
    fs = [svc.mr(u, v) for u, v in zip(us, vs)]
    svc.drain()
    for u, v, f in zip(us, vs, fs):
        assert f.result(timeout=0) == oracle.mr(u, v)


# ---------------------------------------------------------------------------
# snapshot lifecycle under churn: version-keyed swap between micro-batches,
# dirty-row re-derivation, mesh-resident row patching
# ---------------------------------------------------------------------------

def test_service_update_churn_matches_oracle():
    rng = np.random.default_rng(9)
    h = random_hypergraph(20, 16, seed=8)
    svc = serve(h, "hl-index", start=False)
    for _ in range(4):
        ins, dels = [], []
        if h.m > 2 and rng.random() < 0.6:
            dels = [int(rng.integers(h.m))]
        if rng.random() < 0.8:
            ins = [rng.choice(h.n + 1, size=3, replace=False)]
        svc.update(inserts=ins, deletes=dels)
        h, _, _ = apply_edge_edits(h, ins, dels)
        reqs, want = _mixed_requests(h, rng, 40)
        futs = svc.submit_many(reqs)
        svc.drain()
        assert [f.result(timeout=0) for f in futs] == want
    assert svc.stats().snapshot_refreshes >= 1


@pytest.mark.parametrize("backend", ["hl-index", "sharded"])
def test_kernel_serving_byte_identical_under_churn(backend):
    # kernel row of the serving matrix: twin services over the same
    # engine backend, one host merge-join and one Pallas label-join,
    # fed identical request streams across an update() sequence — the
    # kernel path must stay byte-identical (values *and* types), not
    # just oracle-correct
    rng = np.random.default_rng(11)
    h = random_hypergraph(20, 16, seed=8)
    host = serve(h, backend, start=False)
    kern = serve(h, backend, start=False,
                 config=ServiceConfig(use_kernels=True))
    for _ in range(3):
        ins, dels = [], []
        if h.m > 2 and rng.random() < 0.6:
            dels = [int(rng.integers(h.m))]
        if rng.random() < 0.8:
            ins = [rng.choice(h.n + 1, size=3, replace=False)]
        host.update(inserts=ins, deletes=dels)
        kern.update(inserts=ins, deletes=dels)
        h, _, _ = apply_edge_edits(h, ins, dels)
        reqs, want = _mixed_requests(h, rng, 40)
        hf = host.submit_many(reqs)
        kf = kern.submit_many([dataclasses.replace(r) for r in reqs])
        host.drain()
        kern.drain()
        hres = [f.result(timeout=0) for f in hf]
        kres = [f.result(timeout=0) for f in kf]
        assert hres == want
        assert kres == hres
        assert [type(r) for r in kres] == [type(r) for r in hres]
    assert kern.stats().kernel_batches > 0
    assert host.stats().kernel_batches == 0


def test_kernel_serving_mesh_reland_byte_identical():
    # snapshot re-lands: a mesh-resident service re-lands the snapshot
    # after each scoped update, and the kernel view must be rebuilt over
    # the re-landed copy (not answer from the stale one) — twin services
    # again, byte-identical at every step
    from repro.core.distributed import default_line_graph_mesh
    mesh = default_line_graph_mesh()
    h = planted_chain_hypergraph(4, 8, overlap=2, extra_size=2, seed=1)
    host = serve(h, "hl-index", mesh=mesh, start=False)
    kern = serve(h, "hl-index", mesh=mesh, start=False,
                 config=ServiceConfig(use_kernels=True))
    rng = np.random.default_rng(13)
    for step in range(3):
        v0 = int(h.edge(0)[0])
        ins = [[v0, v0 + 1, h.n + step]]
        host.update(inserts=ins)
        kern.update(inserts=ins)
        h, _, _ = apply_edge_edits(h, ins, [])
        reqs, want = _mixed_requests(h, rng, 30)
        hf = host.submit_many(reqs)
        kf = kern.submit_many([dataclasses.replace(r) for r in reqs])
        host.drain()
        kern.drain()
        hres = [f.result(timeout=0) for f in hf]
        kres = [f.result(timeout=0) for f in kf]
        assert hres == want
        assert kres == hres
    assert kern.stats().kernel_batches >= 3


def test_scoped_update_rederives_only_touched_rows():
    # the acceptance criterion: after a scoped update the snapshot
    # refresh touches < n rows (here: one chain component out of four)
    h = planted_chain_hypergraph(4, 8, overlap=2, extra_size=2, seed=1)
    svc = serve(h, "hl-index", start=False)
    f = svc.mr(0, 1)
    svc.drain()
    f.result(timeout=0)
    v0 = int(h.edge(0)[0])
    svc.update(inserts=[[v0, v0 + 1]])
    h2, _, _ = apply_edge_edits(h, [[v0, v0 + 1]], [])
    oracle = MSTOracle(h2)
    rng = np.random.default_rng(2)
    us, vs = rng.integers(0, h2.n, 40), rng.integers(0, h2.n, 40)
    futs = [svc.mr(int(u), int(v)) for u, v in zip(us, vs)]
    svc.drain()
    for u, v, fut in zip(us, vs, futs):
        assert fut.result(timeout=0) == oracle.mr(int(u), int(v))
    eng = svc.engine
    assert 0 < eng.last_snapshot_refresh_rows < h2.n
    st = svc.stats()
    assert st.rows_rederived < st.rows_full


def test_partial_rederivation_byte_identical_under_churn():
    # satellite: interleaved inserts and deletes; after every scoped
    # update the patched snapshot must equal a from-scratch derivation
    # bit for bit, and version must track the engine
    h = planted_chain_hypergraph(3, 6, overlap=2, extra_size=2, seed=4)
    eng = build_engine(h, "hl-index")
    eng.snapshot()
    rng = np.random.default_rng(5)
    partial_seen = 0
    for step in range(5):
        if step % 2 == 0:
            v0 = int(rng.integers(h.n))
            ins, dels = [[v0, min(v0 + 1, h.n - 1), h.n]], []
        else:
            ins, dels = [], [int(rng.integers(h.m))]
        eng.update(inserts=ins, deletes=dels)
        h, _, _ = apply_edge_edits(h, ins, dels)
        snap = eng.snapshot()
        assert snap.version == eng.version == step + 1
        if 0 < eng.last_snapshot_refresh_rows < h.n:
            partial_seen += 1
        fresh = DeviceSnapshot.from_hlindex(eng.idx, "hl-index",
                                            version=eng.version)
        np.testing.assert_array_equal(np.asarray(snap.ranks),
                                      np.asarray(fresh.ranks))
        np.testing.assert_array_equal(np.asarray(snap.svals),
                                      np.asarray(fresh.svals))
        np.testing.assert_array_equal(np.asarray(snap.lengths),
                                      np.asarray(fresh.lengths))
    assert partial_seen > 0        # the scoped path actually exercised


def test_version_propagates_through_to_mesh_under_churn():
    # satellite: DeviceSnapshot.version survives to_mesh across multiple
    # interleaved update() calls, so mesh-resident staleness stays
    # detectable at every step
    from repro.core.distributed import default_line_graph_mesh
    mesh = default_line_graph_mesh()
    h = planted_chain_hypergraph(3, 6, overlap=2, extra_size=2, seed=6)
    eng = build_engine(h, "hl-index")
    sharded = eng.snapshot().to_mesh(mesh)
    assert sharded.version == 0
    for step in range(3):
        v0 = int(h.edge(0)[0])
        eng.update(inserts=[[v0, v0 + 1, h.n + step]])
        h, _, _ = apply_edge_edits(h, [[v0, v0 + 1, h.n + step]], [])
        assert sharded.version != eng.version      # old copy: stale
        dirty = eng.dirty_rows()
        fresh = eng.snapshot()
        new_sharded = fresh.to_mesh(
            mesh, base=sharded if dirty is not None else None,
            dirty_rows=dirty)
        assert new_sharded.version == eng.version == step + 1
        full = fresh.to_mesh(mesh)
        np.testing.assert_array_equal(np.asarray(new_sharded.ranks),
                                      np.asarray(full.ranks))
        np.testing.assert_array_equal(np.asarray(new_sharded.svals),
                                      np.asarray(full.svals))
        np.testing.assert_array_equal(np.asarray(new_sharded.lengths),
                                      np.asarray(full.lengths))
        oracle = MSTOracle(h)
        rng = np.random.default_rng(step)
        us, vs = rng.integers(0, h.n, 20), rng.integers(0, h.n, 20)
        want = np.array([oracle.mr(int(u), int(v))
                         for u, v in zip(us, vs)], np.int64)
        np.testing.assert_array_equal(
            np.asarray(new_sharded.mr(us, vs)).astype(np.int64), want)
        sharded = new_sharded


def test_mesh_resident_service_row_patches():
    from repro.core.distributed import default_line_graph_mesh
    mesh = default_line_graph_mesh()
    h = planted_chain_hypergraph(4, 8, overlap=2, extra_size=2, seed=1)
    svc = serve(h, "hl-index", mesh=mesh, start=False)
    f = svc.mr(0, 1)
    svc.drain()
    f.result(timeout=0)
    v0 = int(h.edge(0)[0])
    svc.update(inserts=[[v0, v0 + 1]])
    h2, _, _ = apply_edge_edits(h, [[v0, v0 + 1]], [])
    oracle = MSTOracle(h2)
    rng = np.random.default_rng(3)
    us, vs = rng.integers(0, h2.n, 30), rng.integers(0, h2.n, 30)
    futs = [svc.mr(int(u), int(v)) for u, v in zip(us, vs)]
    svc.drain()
    for u, v, fut in zip(us, vs, futs):
        assert fut.result(timeout=0) == oracle.mr(int(u), int(v))
    st = svc.stats()
    assert 0 < st.mesh_rows_patched < h2.n


def test_mesh_refresh_with_shared_engine_stays_correct():
    # regression: a direct engine.snapshot() call between the service's
    # refreshes resets the engine's dirty set, so the delta no longer
    # describes the service's landed copy — the service must detect that
    # (snapshot_cache identity) and re-land in full rather than patch a
    # partial delta over a stale mesh base.  The graph is built so the
    # padded geometry stays constant across the updates (an untouched
    # long chain C pins lmax), which is exactly the case where a naive
    # patch would silently serve stale rows (reproduced: 4 wrong answers
    # without the snapshot_cache identity guard).
    from repro.core import from_edge_lists
    from repro.core.distributed import default_line_graph_mesh
    mesh = default_line_graph_mesh()
    edges = [[0, 1, 2], [1, 2, 3],            # chain A
             [10, 11, 12], [11, 12, 13]]      # chain B
    for i in range(10):                        # chain C dominates lmax
        edges.append([20 + 2 * i, 21 + 2 * i, 22 + 2 * i, 23 + 2 * i])
    h = from_edge_lists(edges)
    eng = build_engine(h, "hl-index")
    svc = serve(eng, mesh=mesh, start=False)
    f = svc.mr(0, 1)
    svc.drain()
    f.result(timeout=0)                        # mesh copy landed at v0
    ins1, ins2 = [[0, 1, 2, 3]], [[10, 11, 12, 13]]   # change MR in A, B
    svc.update(inserts=ins1)                   # dirty = chain-A rows
    eng.snapshot()                             # external consumer: resets
    svc.update(inserts=ins2)                   # dirty = chain-B rows only
    h2, _, _ = apply_edge_edits(h, ins1, [])
    h3, _, _ = apply_edge_edits(h2, ins2, [])
    oracle = MSTOracle(h3)
    us = list(range(h3.n))
    vs = [3] * h3.n
    futs = [svc.mr(u, v) for u, v in zip(us, vs)]
    svc.drain()
    for u, v, fut in zip(us, vs, futs):
        assert fut.result(timeout=0) == oracle.mr(u, v), (u, v)


def test_admission_window_coalesces_trickle_arrivals():
    # the coalescing wait must survive per-submit notifies: requests
    # trickling in during the window end up in one batch, not many
    h = random_hypergraph(15, 20, seed=0)
    svc = serve(h, "hl-index",
                config=ServiceConfig(max_wait_ms=400.0, max_batch=64))
    try:
        futs = []
        for _ in range(10):
            futs.append(svc.mr(0, 1))
            time.sleep(0.02)          # well inside the 400 ms window
        for f in futs:
            f.result(timeout=30)
    finally:
        svc.close()
    st = svc.stats()
    assert st.batches <= 3, st.batches   # not one dispatch per arrival


def test_dirty_rows_contract():
    h = planted_chain_hypergraph(4, 8, overlap=2, extra_size=2, seed=1)
    eng = build_engine(h, "hl-index")
    assert eng.dirty_rows().size == 0
    eng.snapshot()
    v0 = int(h.edge(0)[0])
    eng.update(inserts=[[v0, v0 + 1]])
    dirty = eng.dirty_rows()
    assert dirty is not None and 0 < dirty.size < eng.h.n
    eng.snapshot()
    assert eng.dirty_rows().size == 0             # reset after re-derive
    # rebuild-capability backends report all-dirty (None)
    ce = build_engine(h, "closure")
    ce.snapshot()
    ce.update(inserts=[[0, 1]])
    assert ce.dirty_rows() is None
    ce.snapshot()
    assert ce.dirty_rows().size == 0


# ---------------------------------------------------------------------------
# satellite: centralized batch-input validation — identical errors everywhere
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_batch_validation_uniform_across_backends(backend):
    h = random_hypergraph(12, 14, seed=0)
    eng = build_engine(h, backend)
    with pytest.raises(ValueError, match="length mismatch"):
        eng.mr_batch([0, 1], [2])
    with pytest.raises(ValueError, match="integer dtype"):
        eng.mr_batch([0.5, 1.5], [2, 3])
    with pytest.raises(IndexError, match="out of range"):
        eng.mr_batch([0, 1], [2, h.n])
    with pytest.raises(IndexError, match="out of range"):
        eng.s_reach_batch([-1], [2], 2)
    with pytest.raises(ValueError, match="1-D"):
        eng.mr_batch(np.zeros((2, 2), np.int64), np.zeros((2, 2), np.int64))
    # empty batches are legal everywhere
    assert len(eng.mr_batch([], [])) == 0


def test_validate_batch_helper():
    us, vs = validate_batch([1, 2], np.array([3, 4], np.int32), 5)
    assert us.dtype == vs.dtype == np.int64
    with pytest.raises(IndexError):
        validate_batch([0], [5], 5)
    validate_batch([], [], 0)                      # empty always fine


def test_submit_validation():
    h = random_hypergraph(10, 12, seed=0)
    svc = serve(h, "hl-index", start=False)
    with pytest.raises(IndexError, match="out of range"):
        svc.submit(MRRequest(0, h.n))
    with pytest.raises(ValueError, match="s >= 1"):
        svc.submit(SReachRequest(0, 1, 0))
    with pytest.raises(ValueError, match="integer dtype"):
        svc.submit(MRRequest(0.5, 1))
    with pytest.raises(ValueError, match="integer dtype"):
        svc.submit(SReachRequest(0, 1, 1.5))       # same contract for s
    with pytest.raises(TypeError, match="requests"):
        svc.submit((0, 1))
    assert svc.pending() == 0                      # nothing half-admitted


def test_rebuild_update_drops_stale_snapshot():
    # rebuild backends can never patch (all rows dirty), so update()
    # must release the old snapshot immediately instead of holding it
    # resident through the recompute (they are the memory-bound regime)
    h = random_hypergraph(16, 12, seed=9)
    for backend in ("closure", "sharded"):
        eng = build_engine(h, backend)
        eng.snapshot()
        eng.update(inserts=[[0, 3, 7]])
        assert eng.snapshot_cache() is None
        assert eng.snapshot().version == 1         # and re-derives fine


def test_mesh_service_on_sharded_backend_reuses_resident_snapshot():
    # the sharded backend's snapshot is already mesh-sharded; the
    # service must serve it directly, not gather-and-re-land a duplicate
    from repro.core.distributed import default_line_graph_mesh
    mesh = default_line_graph_mesh()
    h = random_hypergraph(30, 20, seed=6)
    svc = serve(h, "sharded", mesh=mesh, start=False)
    f = svc.mr(0, 1)
    svc.drain()
    f.result(timeout=0)
    assert svc._snap is svc.engine.snapshot_cache()
    oracle = MSTOracle(h)
    rng = np.random.default_rng(1)
    us, vs = rng.integers(0, h.n, 30), rng.integers(0, h.n, 30)
    futs = [svc.mr(int(u), int(v)) for u, v in zip(us, vs)]
    svc.drain()
    for u, v, fut in zip(us, vs, futs):
        assert fut.result(timeout=0) == oracle.mr(int(u), int(v))


# ---------------------------------------------------------------------------
# facade + request-type registry
# ---------------------------------------------------------------------------

def test_serve_facade():
    h = random_hypergraph(15, 20, seed=2)
    svc = serve(h, "hl-index", start=False,
                config=ServiceConfig(max_batch=32, min_bucket=4))
    assert svc.max_batch == 32 and svc.min_bucket == 4
    assert svc.engine.name == "hl-index"
    eng = build_engine(h, "online")
    svc2 = serve(eng, start=False)
    assert svc2.engine is eng
    with pytest.raises(ValueError, match="already-built"):
        serve(eng, start=False, minimize_labels=False)
    # explicit backend / batch_hint with a built engine would be
    # silently ignored — must raise instead
    with pytest.raises(ValueError, match="already-built"):
        serve(eng, "closure", start=False)
    with pytest.raises(ValueError, match="already-built"):
        serve(eng, start=False, batch_hint=10_000)
    with pytest.raises(ValueError, match="min_bucket"):
        ReachabilityService(eng, min_bucket=64, max_batch=8, start=False)


def test_request_types_registry():
    assert set(REQUEST_TYPES) == {"mr", "s_reach", "witness", "s_reach_k",
                                  "mr_set", "top_s", "s_distance"}
    for kind, cls in REQUEST_TYPES.items():
        assert cls.kind == kind
    # frozen dataclasses: requests are immutable (safe across threads)
    req = MRRequest(1, 2)
    with pytest.raises(dataclasses.FrozenInstanceError):
        req.u = 3


def test_service_on_snapshotless_backend_never_snapshots():
    h = random_hypergraph(15, 20, seed=2)
    svc = serve(h, "online", start=False)
    futs = [svc.mr(0, i % h.n) for i in range(10)]
    futs.append(svc.s_reach(0, 1, 2))
    svc.drain()
    for f in futs:
        f.result(timeout=0)
    assert svc.stats().snapshot_refreshes == 0
    with pytest.raises(SnapshotUnsupported):
        svc.engine.snapshot()


def test_service_stats_shape():
    st = ServiceStats()
    d = st.as_dict()
    assert set(d) >= {"submitted", "answered", "batches", "padded_queries",
                      "bucket_histogram", "snapshot_refreshes",
                      "rows_rederived", "rows_full", "mesh_rows_patched",
                      "updates"}
