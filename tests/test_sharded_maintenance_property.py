"""Hypothesis edit-script property: scoped sharded maintenance must be
byte-identical to a fresh rebuild after every step, in both regimes.
Gated like tests/test_property.py — skipped wholesale without
hypothesis."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.api import build_engine
from repro.core import MSTOracle, apply_edge_edits, from_edge_lists


def _assert_matches_fresh(eng, h, *, labels):
    fresh = build_engine(h, "sharded", build_labels=labels)
    mst = MSTOracle(h)
    if h.n == 0:
        return
    us, vs = np.meshgrid(np.arange(h.n), np.arange(h.n))
    us, vs = us.ravel(), vs.ravel()
    got = np.asarray(eng.mr_batch(us, vs)).astype(np.int64)
    ref = np.asarray(fresh.mr_batch(us, vs)).astype(np.int64)
    np.testing.assert_array_equal(got, ref)
    want = np.array([mst.mr(int(u), int(v)) for u, v in zip(us, vs)],
                    np.int64)
    np.testing.assert_array_equal(got, want)


@st.composite
def _hypergraphs(draw, max_v=12, max_e=8):
    n = draw(st.integers(3, max_v))
    m = draw(st.integers(1, max_e))
    edges = []
    for _ in range(m):
        size = draw(st.integers(1, min(5, n)))
        edges.append(draw(st.lists(st.integers(0, n - 1), min_size=size,
                                   max_size=size, unique=True)))
    return from_edge_lists(edges, n=n)


@st.composite
def _edit_scripts(draw, steps=3):
    script = []
    for _ in range(draw(st.integers(1, steps))):
        n_ins = draw(st.integers(0, 2))
        inserts = [draw(st.lists(st.integers(0, 13), min_size=2,
                                 max_size=4, unique=True))
                   for _ in range(n_ins)]
        deletes = draw(st.lists(st.floats(0, 1), min_size=0, max_size=2))
        script.append((inserts, deletes))
    return script


@pytest.mark.parametrize("labels", [False, True],
                         ids=["closure", "labels"])
@settings(max_examples=5, deadline=None)
@given(_hypergraphs(), _edit_scripts())
def test_scoped_equals_fresh_rebuild_every_step(labels, h, script):
    eng = build_engine(h, "sharded", build_labels=labels)
    for inserts, delete_fracs in script:
        deletes = sorted({int(f * (h.m - 1)) for f in delete_fracs
                          if h.m > 0})
        eng.update(inserts=inserts, deletes=deletes)
        h, _, _ = apply_edge_edits(h, inserts, deletes)
        _assert_matches_fresh(eng, h, labels=labels)
