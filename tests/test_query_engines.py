"""Query engines: Algorithm 5 scalar vs batched JAX vs Pallas label-join."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (random_hypergraph, build_fast, minimize, mr_query,
                        PaddedIndex, mr_oracle_dense)
from repro.kernels import label_join
from repro.kernels import ref as kref


@pytest.fixture(scope="module")
def setup():
    h = random_hypergraph(40, 60, seed=9)
    idx = minimize(build_fast(h))
    oracle = mr_oracle_dense(h)
    return h, idx, oracle


def test_batched_engine_matches_scalar(setup):
    h, idx, oracle = setup
    pidx = PaddedIndex(idx)
    rng = np.random.default_rng(0)
    us = rng.integers(0, h.n, 200)
    vs = rng.integers(0, h.n, 200)
    got = np.asarray(pidx.mr(us, vs))
    want = np.array([oracle[u, v] for u, v in zip(us, vs)])
    np.testing.assert_array_equal(got, want)


def test_batched_s_reach(setup):
    h, idx, oracle = setup
    pidx = PaddedIndex(idx)
    rng = np.random.default_rng(1)
    us = rng.integers(0, h.n, 100)
    vs = rng.integers(0, h.n, 100)
    for s in (1, 2, 3):
        got = np.asarray(pidx.s_reach(us, vs, s))
        want = np.array([oracle[u, v] >= s for u, v in zip(us, vs)])
        np.testing.assert_array_equal(got, want)


def test_pallas_label_join_matches_batched(setup):
    h, idx, oracle = setup
    ranks, svals, _ = idx.as_padded()
    rng = np.random.default_rng(2)
    us = rng.integers(0, h.n, 64)
    vs = rng.integers(0, h.n, 64)
    got = np.asarray(label_join(jnp.asarray(ranks[us]), jnp.asarray(svals[us]),
                                jnp.asarray(ranks[vs]), jnp.asarray(svals[vs]),
                                bq=32))
    want = np.array([oracle[u, v] for u, v in zip(us, vs)])
    np.testing.assert_array_equal(got, want)


def test_empty_labels_queries():
    # a vertex in no hyperedge must answer 0 against everyone
    from repro.core import from_edge_lists, build_fast, mr_query
    h = from_edge_lists([[0, 1], [1, 2]], n=5)     # vertices 3, 4 isolated
    idx = build_fast(h)
    assert mr_query(idx, 3, 0) == 0
    assert mr_query(idx, 3, 4) == 0
    pidx = PaddedIndex(idx)
    assert int(pidx.mr(np.array([3]), np.array([0]))[0]) == 0
