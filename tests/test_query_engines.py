"""Query kernels: the Pallas label-join vs the merge-join reference.

The padded-engine-vs-oracle equivalence checks that used to live here
are conformance matrix cells now (tests/test_conformance.py: the
``snapshot`` operation and the PaddedIndex back-compat test); this file
keeps the kernel-specific coverage.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (random_hypergraph, build_fast, minimize,
                        PaddedIndex, mr_oracle_dense)
from repro.kernels import label_join


@pytest.fixture(scope="module")
def setup():
    h = random_hypergraph(40, 60, seed=9)
    idx = minimize(build_fast(h))
    oracle = mr_oracle_dense(h)
    return h, idx, oracle


def test_pallas_label_join_matches_batched(setup):
    h, idx, oracle = setup
    ranks, svals, _ = idx.as_padded()
    rng = np.random.default_rng(2)
    us = rng.integers(0, h.n, 64)
    vs = rng.integers(0, h.n, 64)
    got = np.asarray(label_join(jnp.asarray(ranks[us]), jnp.asarray(svals[us]),
                                jnp.asarray(ranks[vs]), jnp.asarray(svals[vs]),
                                bq=32))
    want = np.array([oracle[u, v] for u, v in zip(us, vs)])
    np.testing.assert_array_equal(got, want)


def test_empty_labels_queries():
    # a vertex in no hyperedge must answer 0 against everyone
    from repro.core import from_edge_lists, build_fast, mr_query
    h = from_edge_lists([[0, 1], [1, 2]], n=5)     # vertices 3, 4 isolated
    idx = build_fast(h)
    assert mr_query(idx, 3, 0) == 0
    assert mr_query(idx, 3, 4) == 0
    pidx = PaddedIndex(idx)
    assert int(pidx.mr(np.array([3]), np.array([0]))[0]) == 0
