"""Crash recovery under fire (ISSUE 6 acceptance): SIGKILL a serving
process mid-update-stream, restart from checkpoint + WAL, and resume
serving byte-identical answers — no full rebuild.

The child process serves a deterministic update stream (batches are
computed by the parent and passed as JSON, so the uninterrupted oracle
replays exactly the same edits).  The parent SIGKILLs it after a few
acknowledged updates, restores from the store directory, and compares
against a fresh oracle that replays the durable prefix.  A torn final
WAL record — the state a kill mid-append legitimately leaves — must be
detected by checksum and dropped, never crash the replay.
"""
import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

from repro.api import build_engine, random_hypergraph
from repro.serve.reach_service import ReachabilityService
from repro.store import IndexStore, scan_wal

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N, M, SEED = 36, 48, 5
KILL_AFTER = 5          # acknowledged updates before the SIGKILL lands

_CHILD = """
import json, sys
from repro.api import random_hypergraph, serve
from repro.store import IndexStore

store_dir = sys.argv[1]
batches = json.loads(sys.argv[2])
h = random_hypergraph({n}, {m}, seed={seed})
svc = serve(h, "hl-index", start=False)
store = IndexStore(store_dir)
svc.checkpoint(store)
print("READY", flush=True)
for k, (ins, dels) in enumerate(batches):
    svc.update(inserts=ins, deletes=dels)
    print("APPLIED", k + 1, flush=True)
sys.exit(3)   # the stream must be long enough that we never get here
""".format(n=N, m=M, seed=SEED)


def _make_batches(count, seed=11):
    """Deterministic update stream; batch k becomes engine version k+1.
    Deletes track the evolving edge count so every batch is valid
    whenever it is (re)applied in sequence."""
    rng = np.random.default_rng(seed)
    m = M
    batches = []
    for k in range(count):
        ins = [sorted(int(x) for x in rng.choice(N, 3, replace=False))]
        dels = [int(rng.integers(0, m))] if k % 3 == 2 else []
        m += len(ins) - len(dels)
        batches.append((ins, dels))
    return batches


def _oracle(batches, upto):
    """The uninterrupted reference: fresh build + the first ``upto``
    batches applied live."""
    eng = build_engine(random_hypergraph(N, M, seed=SEED), "hl-index")
    for ins, dels in batches[:upto]:
        eng.update(inserts=ins, deletes=dels)
    return eng


def _queries(n, q=64, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, n, q), rng.integers(0, n, q)


@pytest.fixture(scope="module")
def killed_store(tmp_path_factory):
    """Run the serving child and SIGKILL it mid-stream; returns the
    store directory and the batch list it was streaming."""
    store_dir = str(tmp_path_factory.mktemp("crash") / "store")
    batches = _make_batches(400)     # far more than ever get applied
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-c", _CHILD, store_dir, json.dumps(batches)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
    try:
        applied = 0
        for line in proc.stdout:
            if line.startswith("APPLIED"):
                applied = int(line.split()[1])
                if applied >= KILL_AFTER:
                    proc.kill()          # SIGKILL: no atexit, no flush
                    break
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=60)
    assert proc.returncode == -signal.SIGKILL, (
        f"child exited {proc.returncode} (stream too short?): "
        f"{proc.stderr.read()}")
    assert applied >= KILL_AFTER
    return store_dir, batches, applied


def test_restart_matches_uninterrupted_oracle(killed_store):
    store_dir, batches, applied = killed_store
    # attach=False: the resumed updates below are an in-memory
    # comparison against the oracle, not a continuation of the journal
    # (other tests re-read this store)
    eng = build_engine(restore=store_dir, attach=False)
    # every acknowledged update was fsynced before it applied, so the
    # durable lineage is at least the acknowledged prefix; at most one
    # journaled-but-unacknowledged record may follow it
    assert applied <= eng.version <= applied + 1
    oracle = _oracle(batches, eng.version)
    us, vs = _queries(eng.h.n)
    assert np.array_equal(eng.mr_batch(us, vs), oracle.mr_batch(us, vs))
    # resume the stream on both: byte-identical answers continue
    for ins, dels in batches[eng.version:eng.version + 3]:
        eng.update(inserts=ins, deletes=dels)
        oracle.update(inserts=ins, deletes=dels)
    us, vs = _queries(eng.h.n, seed=1)
    assert np.array_equal(eng.mr_batch(us, vs), oracle.mr_batch(us, vs))


def test_restart_through_service_layer(killed_store):
    store_dir, batches, _ = killed_store
    svc = ReachabilityService.restore(store_dir, start=False)
    oracle = _oracle(batches, svc.engine.version)
    us, vs = _queries(svc.engine.h.n)
    futs = [svc.mr(int(u), int(v)) for u, v in zip(us, vs)]
    svc.drain()
    assert [f.result() for f in futs] == \
        [int(x) for x in oracle.mr_batch(us, vs)]
    svc.close()


def test_torn_final_record_dropped_not_fatal(killed_store):
    store_dir, batches, _ = killed_store
    wal_path = next(p for p in sorted(os.listdir(store_dir))
                    if p.startswith("wal-"))
    wal_path = os.path.join(store_dir, wal_path)
    records, valid, _ = scan_wal(wal_path)
    assert records, "kill landed before any update was journaled?"
    # tear the final record the way a crash mid-append does
    with open(wal_path, "r+b") as f:
        f.truncate(valid - 3)
    recs2, _, status = scan_wal(wal_path)
    assert status != "ok" and len(recs2) == len(records) - 1
    eng = build_engine(restore=store_dir)     # drops the tail, no error
    assert eng.version == len(recs2)
    oracle = _oracle(batches, eng.version)
    us, vs = _queries(eng.h.n)
    assert np.array_equal(eng.mr_batch(us, vs), oracle.mr_batch(us, vs))


def test_empty_wal_restore_is_pure_load(killed_store):
    """With no journaled suffix the restart is exactly checkpoint
    page-in: the restored labels are views into the file mmap — the
    'no full rebuild' claim in its purest form."""
    store_dir, batches, _ = killed_store
    wal_path = next(p for p in sorted(os.listdir(store_dir))
                    if p.startswith("wal-"))
    with open(os.path.join(store_dir, wal_path), "r+b") as f:
        f.truncate(0)
    eng = IndexStore(store_dir).restore(attach=False)
    assert eng.version == 0

    def memmap_backed(a):
        while a is not None:
            if isinstance(a, np.memmap):
                return True
            a = a.base
        return False

    assert memmap_backed(eng.idx.rank)
    assert all(memmap_backed(eng.idx.labels_s[u]) for u in range(eng.h.n))
    oracle = _oracle(batches, 0)
    us, vs = _queries(eng.h.n)
    assert np.array_equal(eng.mr_batch(us, vs), oracle.mr_batch(us, vs))
