"""HL-index maintenance (paper Sec. V-D): scoped construction, spliced
labels, answers always identical to a full rebuild."""
import numpy as np
import pytest

from repro.core import (random_hypergraph, build_fast, minimize, mr_query,
                        mr_oracle_dense, insert_hyperedge, delete_hyperedge,
                        apply_updates, apply_edge_edits, from_edge_lists,
                        neighbor_csr, planted_chain_hypergraph)


def _assert_matches_oracle(idx, h):
    oracle = mr_oracle_dense(h)
    for u in range(h.n):
        for v in range(h.n):
            assert mr_query(idx, u, v) == int(oracle[u, v]), (u, v)


@pytest.mark.parametrize("seed", range(3))
def test_insert_matches_rebuild(seed):
    rng = np.random.default_rng(seed)
    h = random_hypergraph(20, 16, seed=seed)
    idx = build_fast(h)
    h2, idx2 = insert_hyperedge(h, idx, rng.choice(20, size=4, replace=False))
    _assert_matches_oracle(idx2, h2)


@pytest.mark.parametrize("seed", range(3))
def test_delete_matches_rebuild(seed):
    rng = np.random.default_rng(seed + 10)
    h = random_hypergraph(20, 16, seed=seed + 10)
    idx = build_fast(h)
    h2, idx2 = delete_hyperedge(h, idx, int(rng.integers(h.m)))
    _assert_matches_oracle(idx2, h2)


def test_insert_scope_is_component_local():
    # two disjoint chains: inserting into chain 0 must not touch chain 1's
    # hubs (scoped rebuild smaller than the graph)
    h = planted_chain_hypergraph(2, 10, overlap=2, extra_size=2, seed=0)
    idx = build_fast(h)
    v0 = int(h.edge(0)[0])
    h2, idx2 = insert_hyperedge(h, idx, [v0, v0 + 1])
    assert idx2.stats["maintenance_scope"] < h2.m
    oracle = mr_oracle_dense(h2)
    rng = np.random.default_rng(0)
    for _ in range(60):
        u, v = int(rng.integers(h2.n)), int(rng.integers(h2.n))
        assert mr_query(idx2, u, v) == int(oracle[u, v])


def test_construction_is_scoped():
    # the *construction* input is the extracted sub-hypergraph, not the
    # full graph — the PR's tentpole claim, asserted on the stats the
    # splice records (and benchmarked in benchmarks/bench_maintenance.py)
    h = planted_chain_hypergraph(4, 8, overlap=2, extra_size=2, seed=1)
    idx = build_fast(h)
    v0 = int(h.edge(0)[0])
    h2, idx2 = insert_hyperedge(h, idx, [v0, v0 + 1])
    assert 0 < idx2.stats["maintenance_subgraph_m"] < h2.m
    assert idx2.stats["maintenance_subgraph_m"] == \
        idx2.stats["maintenance_scope"]
    _assert_matches_oracle(idx2, h2)


def test_untouched_label_arrays_are_shared():
    # splice keeps out-of-scope vertices' label arrays byte-for-byte —
    # literally the same objects (insert-only edits don't even remap ids)
    h = planted_chain_hypergraph(2, 6, overlap=2, extra_size=2, seed=2)
    idx = build_fast(h)
    v0 = int(h.edge(0)[0])
    h2, idx2 = insert_hyperedge(h, idx, [v0, v0 + 1])
    chain1_edges = set(range(6, h.m))          # chain 1 = second component
    shared = 0
    for u in range(h.n):
        eu = set(int(e) for e in h.edges_of(u))
        if eu and eu <= chain1_edges:          # vertex wholly in chain 1
            assert idx2.labels_edge[u] is idx.labels_edge[u]
            assert idx2.labels_rank[u] is idx.labels_rank[u]
            assert idx2.labels_s[u] is idx.labels_s[u]
            shared += 1
    assert shared > 0


@pytest.mark.parametrize("use_minimizer", [False, True])
def test_batched_update_sequences_match_rebuild(use_minimizer):
    # randomized insert/delete batches; every step must answer exactly
    # like an index built from scratch on the edited graph
    rng = np.random.default_rng(42 + use_minimizer)
    h = random_hypergraph(16, 12, seed=7)
    idx = build_fast(h)
    minimizer = minimize if use_minimizer else None
    if use_minimizer:
        idx = minimize(idx)
    for step in range(6):
        ins, dels = [], []
        if h.m > 2 and rng.random() < 0.5:
            dels = list(rng.choice(h.m, size=int(rng.integers(1, 3)),
                                   replace=False))
        if rng.random() < 0.8:
            size = int(rng.integers(2, 5))
            ins.append(rng.choice(h.n + 2, size=min(size, h.n),
                                  replace=False))
        h, idx, report = apply_updates(h, idx, inserts=ins, deletes=dels,
                                       minimizer=minimizer)
        assert report.full_rebuild or report.scope <= h.m
        _assert_matches_oracle(idx, h)


def test_delete_isolated_hyperedge_clears_labels():
    h = from_edge_lists([[0, 1], [5, 6], [2, 3]], n=8)
    idx = build_fast(h)
    h2, idx2 = delete_hyperedge(h, idx, 1)     # isolated: no neighbors
    assert idx2.stats["maintenance_scope"] == 0
    assert idx2.labels_s[5].size == 0 and idx2.labels_s[6].size == 0
    _assert_matches_oracle(idx2, h2)


def test_delete_everything():
    h = from_edge_lists([[0, 1], [1, 2]], n=3)
    idx = build_fast(h)
    h2, idx2, _ = apply_updates(h, idx, deletes=[0, 1])
    assert h2.m == 0
    assert all(a.size == 0 for a in idx2.labels_s)
    assert mr_query(idx2, 0, 2) == 0


def test_insert_grows_vertex_set():
    h = from_edge_lists([[0, 1, 2]], n=3)
    idx = build_fast(h)
    h2, idx2 = insert_hyperedge(h, idx, [2, 7, 9])
    assert h2.n == 10
    _assert_matches_oracle(idx2, h2)


def test_insert_merging_components_invalidates_both():
    # a bridge hyperedge merges two chains: both become in-scope
    h = planted_chain_hypergraph(2, 5, overlap=2, extra_size=2, seed=3)
    idx = build_fast(h)
    u0 = int(h.edge(0)[0])                     # a chain-0 vertex
    u1 = int(h.edge(5)[0])                     # a chain-1 vertex
    h2, idx2 = insert_hyperedge(h, idx, [u0, u1])
    assert idx2.stats["maintenance_scope"] == h2.m   # everything merged
    _assert_matches_oracle(idx2, h2)


@pytest.mark.parametrize("seed", range(3))
def test_neighbor_csr_patch_matches_fresh(seed):
    # 1-hop CSR patching (satellite of the scoped-sharded PR): reusing
    # untouched rows through an edit must be byte-identical to paying
    # the full O(sum d^2) pair pass on the edited graph
    rng = np.random.default_rng(100 + seed)
    h = random_hypergraph(18, 14, seed=seed)
    csr = neighbor_csr(h)
    for _ in range(4):
        ins, dels = [], []
        if h.m > 2 and rng.random() < 0.6:
            dels = list(rng.choice(h.m, size=int(rng.integers(1, 3)),
                                   replace=False))
        if rng.random() < 0.8:
            size = int(rng.integers(2, 5))
            ins.append(rng.choice(h.n, size=min(size, h.n),
                                  replace=False))
        h2, old_to_new, touched = apply_edge_edits(h, inserts=ins,
                                                   deletes=dels)
        patched = csr.updated(h2, old_to_new, touched)
        fresh = neighbor_csr(h2)
        np.testing.assert_array_equal(patched.ptr, fresh.ptr)
        np.testing.assert_array_equal(patched.idx, fresh.idx)
        np.testing.assert_array_equal(patched.od, fresh.od)
        h, csr = h2, patched


def test_neighbor_csr_patch_to_empty_and_back():
    h = from_edge_lists([[0, 1], [1, 2]], n=3)
    csr = neighbor_csr(h)
    h2, old_to_new, touched = apply_edge_edits(h, deletes=[0, 1])
    csr2 = csr.updated(h2, old_to_new, touched)
    fresh2 = neighbor_csr(h2)
    np.testing.assert_array_equal(csr2.ptr, fresh2.ptr)
    h3, old_to_new, touched = apply_edge_edits(h2, inserts=[[0, 1, 2]])
    csr3 = csr2.updated(h3, old_to_new, touched)
    fresh3 = neighbor_csr(h3)
    np.testing.assert_array_equal(csr3.ptr, fresh3.ptr)
    np.testing.assert_array_equal(csr3.idx, fresh3.idx)
    np.testing.assert_array_equal(csr3.od, fresh3.od)


def test_apply_updates_threads_neighbor_csr():
    # apply_updates(neighbors=) must hand back a patched CSR equal to a
    # fresh one, and answers stay identical to the no-CSR path
    h = random_hypergraph(16, 12, seed=5)
    idx = build_fast(h)
    nbr = neighbor_csr(h)
    rng = np.random.default_rng(5)
    for _ in range(3):
        ins = [rng.choice(h.n, size=3, replace=False)]
        dels = [int(rng.integers(h.m))] if h.m > 1 else []
        h, idx, report = apply_updates(h, idx, inserts=ins, deletes=dels,
                                       neighbors=nbr)
        assert report.neighbors is not None
        nbr = report.neighbors
        fresh = neighbor_csr(h)
        np.testing.assert_array_equal(nbr.ptr, fresh.ptr)
        np.testing.assert_array_equal(nbr.idx, fresh.idx)
        _assert_matches_oracle(idx, h)
