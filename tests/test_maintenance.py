"""HL-index maintenance (paper Sec. V-D): insert/delete == full rebuild."""
import numpy as np
import pytest

from repro.core import (random_hypergraph, build_fast, mr_query,
                        mr_oracle_dense, insert_hyperedge, delete_hyperedge,
                        planted_chain_hypergraph)


@pytest.mark.parametrize("seed", range(3))
def test_insert_matches_rebuild(seed):
    rng = np.random.default_rng(seed)
    h = random_hypergraph(20, 16, seed=seed)
    idx = build_fast(h)
    h2, idx2 = insert_hyperedge(h, idx, rng.choice(20, size=4, replace=False))
    oracle = mr_oracle_dense(h2)
    for u in range(h2.n):
        for v in range(h2.n):
            assert mr_query(idx2, u, v) == int(oracle[u, v])


@pytest.mark.parametrize("seed", range(3))
def test_delete_matches_rebuild(seed):
    rng = np.random.default_rng(seed + 10)
    h = random_hypergraph(20, 16, seed=seed + 10)
    idx = build_fast(h)
    h2, idx2 = delete_hyperedge(h, idx, int(rng.integers(h.m)))
    oracle = mr_oracle_dense(h2)
    for u in range(h2.n):
        for v in range(h2.n):
            assert mr_query(idx2, u, v) == int(oracle[u, v])


def test_insert_scope_is_component_local():
    # two disjoint chains: inserting into chain 0 must not touch chain 1's
    # hubs (scoped rebuild smaller than the graph)
    h = planted_chain_hypergraph(2, 10, overlap=2, extra_size=2, seed=0)
    idx = build_fast(h)
    v0 = int(h.edge(0)[0])
    h2, idx2 = insert_hyperedge(h, idx, [v0, v0 + 1])
    assert idx2.stats["maintenance_scope"] < h2.m
    oracle = mr_oracle_dense(h2)
    rng = np.random.default_rng(0)
    for _ in range(60):
        u, v = int(rng.integers(h2.n)), int(rng.integers(h2.n))
        assert mr_query(idx2, u, v) == int(oracle[u, v])
