"""Sparse frontier engine vs the dense oracle."""
import numpy as np
import pytest

from repro.core import (random_hypergraph, planted_chain_hypergraph,
                        mr_oracle_dense)
from repro.core.frontier import (SparseLineGraph, frontier_batched_s_reach,
                                 frontier_batched_mr)


@pytest.mark.parametrize("seed", range(3))
def test_sreach_matches_oracle(seed):
    h = random_hypergraph(25, 35, seed=seed)
    oracle = mr_oracle_dense(h)
    g = SparseLineGraph(h)
    rng = np.random.default_rng(seed)
    us, vs = rng.integers(0, h.n, 30), rng.integers(0, h.n, 30)
    for s in (1, 2, 4):
        got = frontier_batched_s_reach(g, us, vs, s, rounds=h.m)
        want = np.array([oracle[u, v] >= s for u, v in zip(us, vs)])
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("seed", range(3))
def test_mr_bisection_matches_oracle(seed):
    h = random_hypergraph(25, 35, seed=100 + seed)
    oracle = mr_oracle_dense(h)
    g = SparseLineGraph(h)
    rng = np.random.default_rng(seed)
    us, vs = rng.integers(0, h.n, 30), rng.integers(0, h.n, 30)
    got = frontier_batched_mr(g, us, vs, rounds=h.m)
    want = np.array([oracle[u, v] for u, v in zip(us, vs)])
    np.testing.assert_array_equal(got, want)


def test_chain_diameter_rounds():
    """Linear-diameter propagation: a 12-long chain needs ~12 rounds."""
    h = planted_chain_hypergraph(1, 12, overlap=2, extra_size=2, seed=0)
    g = SparseLineGraph(h)
    u = np.array([int(h.edge(0)[0])])
    v = np.array([int(h.edge(11)[-1])])
    assert not frontier_batched_s_reach(g, u, v, 2, rounds=3)[0]
    assert frontier_batched_s_reach(g, u, v, 2, rounds=12)[0]
