"""Multi-device tests (subprocess with forced host device count):
sharded closures, compressed all-reduce, small-mesh dry-run proxies."""
import pytest

from util_subproc import run_with_devices


def test_sharded_closures_match_dense():
    out = run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import random_hypergraph, mr_matrix, distinct_thresholds
from repro.core.distributed import (sharded_maxmin_closure,
                                    sharded_threshold_closure_mr)
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 2), ("data", "model"))
h = random_hypergraph(30, 26, seed=3)
w = h.line_graph(np.int32).astype(np.float32)
oracle = mr_matrix(h).astype(np.float32)
for sched in ("allgather", "ring"):
    got = np.asarray(sharded_maxmin_closure(w, mesh, schedule=sched))
    assert np.array_equal(got, oracle), sched
mesh3 = make_test_mesh((1, 2, 2), ("pod", "data", "model"))
thr = distinct_thresholds(w)
got = np.asarray(sharded_threshold_closure_mr(w, thr, mesh3))
assert np.array_equal(got, oracle)
print("OK")
""")
    assert "OK" in out


def test_compressed_allreduce():
    out = run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.distributed_lm import compressed_allreduce
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((4,), ("data",))
rng = np.random.default_rng(0)
tree = {"a": jnp.asarray(rng.normal(size=(4, 33)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(4, 8, 9)).astype(np.float32))}
out = compressed_allreduce(tree, mesh, "data", block=16)
for k in tree:
    want = np.mean(np.asarray(tree[k]), axis=0)
    got = np.asarray(out[k])
    # int8 quantization error bound: blockwise absmax / 127 per element
    err = np.abs(got - want).max()
    assert err < np.abs(np.asarray(tree[k])).max() / 127 + 1e-6, (k, err)
print("OK")
""")
    assert "OK" in out


def test_small_mesh_dryrun_all_kinds():
    """Proxy for the 512-device dry-run: tiny configs, 2x2 mesh, all three
    step kinds lower + compile with the same code path."""
    out = run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
import dataclasses
from jax.sharding import PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.launch.mesh import make_test_mesh
from repro.distributed_lm.sharding import (input_structs, shard_params,
                                           cache_structs, named, batch_axes)
from repro.train.optimizer import AdamConfig, adam_init, opt_state_specs
from repro.train.train_step import make_train_step
from repro.serve.serve_step import make_serve_step, make_prefill_step

mesh = make_test_mesh((2, 2), ("data", "model"))
for arch in ("qwen3_1_7b", "falcon_mamba_7b", "recurrentgemma_2b",
             "whisper_large_v3", "qwen2_moe_a2_7b"):
    cfg = dataclasses.replace(get_smoke_config(arch), microbatch=2,
                              num_patches=4)
    model = build_model(cfg)
    with mesh:
        params = shard_params(model, mesh)
        opt_cfg = AdamConfig(use_8bit=cfg.opt_8bit)
        opt_shapes = jax.eval_shape(lambda p: adam_init(p, opt_cfg), params)
        ospecs = opt_state_specs(model.param_specs(), params, opt_cfg,
                                 data_size=2, zero1=True)
        opt = jax.tree.map(
            lambda sds, spec: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                                   sharding=named(mesh, spec)),
            opt_shapes, ospecs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        batch = input_structs(cfg, mesh, 4, 16)
        c1 = jax.jit(make_train_step(model, cfg, opt_cfg)).lower(
            params, opt, batch).compile()
        c2 = jax.jit(make_prefill_step(model, cfg)).lower(params, batch).compile()
        cache = cache_structs(model, cfg, mesh, 4, 16, False)
        toks = jax.ShapeDtypeStruct((4, 1), jnp.int32,
                                    sharding=named(mesh, P(batch_axes(mesh))))
        pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=named(mesh, P()))
        c3 = jax.jit(make_serve_step(model, cfg)).lower(
            params, cache, toks, pos).compile()
        assert c1.cost_analysis() is not None
    print(arch, "OK")
print("ALLOK")
""", timeout=560)
    assert "ALLOK" in out
