"""Unit tests: hypergraph structure, compaction, importance order."""
import numpy as np
import pytest

from repro.core import (paper_figure1, from_edge_lists, compact,
                        random_hypergraph)


def test_figure1_structure():
    h = paper_figure1()
    assert h.n == 12 and h.m == 7
    assert h.edge_size(1) == 6          # |e2| = 6
    assert h.edge_size(3) == 4          # |e4| = 4
    assert h.overlap(1, 4) == 2         # e2 ∩ e5 = {v5, v6}  (Example 2)
    assert h.overlap(4, 2) == 1         # e5 ∩ e3 = {v10}
    assert h.overlap(6, 3) == 2         # e7 ∩ e4 = {v3, v4}  (Example 5)
    assert set(h.edges_of(0).tolist()) == {0, 6}    # E(v1) = {e1, e7}
    assert h.delta == 6 and h.d_max == 3


def test_importance_order_figure1():
    h = paper_figure1()
    rank = h.importance_order()
    # w: e2=34 > e4=23 > e7=22 > e3=e5=e6=12 (id ties) > e1=5
    order = np.argsort(rank)
    assert order.tolist() == [1, 3, 6, 2, 4, 5, 0]


def test_dual_csr_consistency():
    h = random_hypergraph(30, 50, seed=1)
    for e in range(h.m):
        for v in h.edge(e):
            assert e in h.edges_of(int(v))
    for v in range(h.n):
        for e in h.edges_of(v):
            assert v in h.edge(int(e))


def test_compaction_removes_duplicates():
    h = from_edge_lists([[0, 1, 2], [2, 3], [0, 1, 2], [3, 4], [2, 3]])
    g, rep = compact(h)
    assert g.m == 3
    assert rep[2] == 0 and rep[4] == 1


def test_neighbors_od_matches_dense():
    h = random_hypergraph(25, 40, seed=2)
    w = h.line_graph()
    for e in range(h.m):
        nb, od = h.neighbors_od(e)
        dense_nb = np.nonzero(w[e])[0]
        dense_nb = dense_nb[dense_nb != e]
        assert np.array_equal(nb, dense_nb)
        assert np.array_equal(od, w[e, dense_nb])


def test_from_edge_lists_dedups_and_sorts():
    h = from_edge_lists([[3, 1, 3, 2], []])
    assert h.m == 1
    assert h.edge(0).tolist() == [1, 2, 3]
