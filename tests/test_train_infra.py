"""Training infrastructure: loss goes down, checkpoint/restart, preemption,
8-bit optimizer, dedup data stage, straggler accounting."""
import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.train import (AdamConfig, adam_init, adam_update, make_train_step,
                         SyntheticStream, SupervisorConfig, TrainSupervisor,
                         checkpoint as ckpt, quantize_blockwise,
                         dequantize_blockwise, dedup_corpus, zero1_specs)
from jax.sharding import PartitionSpec as P


def _setup(arch="qwen3_1_7b", lr=3e-3, steps=40, use_8bit=False, micro=2):
    cfg = dataclasses.replace(get_smoke_config(arch), microbatch=micro,
                              opt_8bit=use_8bit)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamConfig(lr=lr, use_8bit=use_8bit, total_steps=steps,
                         warmup_steps=4)
    opt = adam_init(params, opt_cfg)
    step = jax.jit(make_train_step(model, cfg, opt_cfg))
    return cfg, model, params, opt, step


def test_loss_decreases():
    cfg, model, params, opt, step = _setup(steps=30)
    data = SyntheticStream(cfg, batch=4, seq=32, seed=0)
    losses = []
    it = iter(data)
    for _ in range(30):
        batch = jax.tree.map(jnp.asarray, next(it))
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_8bit_optimizer_trains():
    cfg, model, params, opt, step = _setup(use_8bit=True, steps=25, lr=2e-3)
    data = iter(SyntheticStream(cfg, batch=4, seq=32, seed=1))
    losses = []
    for _ in range(25):
        batch = jax.tree.map(jnp.asarray, next(data))
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_quantize_roundtrip():
    rng = np.random.default_rng(0)
    for shape in [(10,), (33, 7), (4, 5, 6)]:
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        codes, scale = quantize_blockwise(x, block=16)
        back = dequantize_blockwise(codes, scale, shape)
        err = np.abs(np.asarray(back) - np.asarray(x)).max()
        scale_max = float(np.asarray(scale).max())
        assert err <= scale_max * 0.51 + 1e-7


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    cfg, model, params, opt, step = _setup()
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, {"params": params, "opt": opt})
    ckpt.save(d, 7, {"params": params, "opt": opt})
    assert ckpt.latest_step(d) == 7
    like = {"params": jax.tree.map(np.asarray, params),
            "opt": jax.tree.map(np.asarray, opt)}
    s, tree, meta = ckpt.restore(d, like)
    assert s == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 tree["params"], params)
    # keep-k pruning
    for stp in (8, 9, 10, 11):
        ckpt.save(d, stp, {"params": params, "opt": opt}, keep=2)
    assert ckpt.all_steps(d) == [10, 11]


def test_restart_resumes_identically(tmp_path):
    """Train 10 steps with a checkpoint at 5; kill; resume from 5 and verify
    the restarted trajectory matches the uninterrupted one."""
    d = str(tmp_path / "ck")

    def make(seed_stream=0):
        cfg, model, params, opt, step = _setup(steps=10)
        data = map(lambda b: jax.tree.map(jnp.asarray, b),
                   iter(SyntheticStream(cfg, batch=4, seq=32, seed=7)))
        return cfg, params, opt, step, data

    cfg, params, opt, step, data = make()
    sup = TrainSupervisor(SupervisorConfig(ckpt_dir=d, ckpt_every=5,
                                           max_steps=10,
                                           handle_sigterm=False),
                          step, data, async_ckpt=False)
    _, p_full, _, log_full = sup.run(params, opt)

    # "crashed" run: restore at 5, replay the same stream from batch 5
    cfg, params2, opt2, step2, data2 = make()
    for _ in range(5):
        next(data2)                      # stream position after step 5
    sup2 = TrainSupervisor(SupervisorConfig(ckpt_dir=d, ckpt_every=100,
                                            max_steps=10,
                                            handle_sigterm=False),
                           step2, data2, async_ckpt=False)
    start, p_r, o_r = sup2.resume_or_init(params2, opt2)
    assert start == 10 or start == 5
    if start == 10:       # the run above saved at 10 too (max_steps hit)
        _, tree, _ = ckpt.restore(d, {"params": jax.tree.map(np.asarray, params2),
                                      "opt": jax.tree.map(np.asarray, opt2)},
                                  step=5)
        p_r, o_r = tree["params"], tree["opt"]
    p_r = jax.tree.map(jnp.asarray, p_r)
    o_r = jax.tree.map(jnp.asarray, o_r)
    _, p_resumed, _, log2 = sup2.run(p_r, o_r, start_step=5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        p_full, p_resumed)


def test_preemption_saves_and_exits(tmp_path):
    d = str(tmp_path / "ck")
    cfg, model, params, opt, step = _setup(steps=50)
    data = map(lambda b: jax.tree.map(jnp.asarray, b),
               iter(SyntheticStream(cfg, batch=4, seq=32, seed=3)))
    sup = TrainSupervisor(SupervisorConfig(ckpt_dir=d, ckpt_every=1000,
                                           max_steps=50,
                                           handle_sigterm=False),
                          step, data, async_ckpt=False)

    # preempt after 3 steps by wrapping the step fn
    calls = {"n": 0}
    orig = sup.train_step

    def wrapped(*a):
        calls["n"] += 1
        if calls["n"] == 3:
            sup.preempted = True
        return orig(*a)

    sup.train_step = wrapped
    stop_step, *_ = sup.run(params, opt)
    assert stop_step == 3
    assert ckpt.latest_step(d) == 3      # graceful save on preemption


def test_zero1_specs():
    assert zero1_specs(P("model", None), (64, 128), 4) == P("model", "data")
    assert zero1_specs(P(None, "model"), (64, 128), 4) == P("data", "model")
    # non-divisible dims stay unsharded
    assert zero1_specs(P(None,), (7,), 4) == P(None)


def test_dedup_corpus():
    rng = np.random.default_rng(0)
    base = rng.integers(0, 100, 64)
    near_dup = base.copy()
    near_dup[:3] = rng.integers(0, 100, 3)
    distinct = rng.integers(100, 200, 64)
    docs = [base, near_dup, distinct, base.copy()]
    kept, comp = dedup_corpus(docs, s=10, k=4)
    assert comp[0] == comp[1] == comp[3]     # near-dups cluster
    assert comp[2] != comp[0]
    assert len(kept) == 2
