"""Algorithm 1/2/3/4/5 correctness vs the semiring oracle + paper goldens."""
import numpy as np
import pytest

from repro.core import (paper_figure1, random_hypergraph,
                        planted_chain_hypergraph, mr_online,
                        precompute_neighbors, build_basic, build_fast,
                        minimize, exact_minimize, mr_query, s_reach_query,
                        mr_oracle_dense, vtv_query, build_ete,
                        ThresholdComponentIndex, MSTOracle)


@pytest.fixture(scope="module")
def fig1():
    h = paper_figure1()
    return h, mr_oracle_dense(h)


def test_paper_examples(fig1):
    h, oracle = fig1
    assert mr_online(h, 4, 8) == 2          # Example 1: MR(v5, v9) = 2
    assert mr_online(h, 0, 9) >= 2          # Example 3: v1 ~2~> v10
    assert mr_online(h, 0, 11) == 2         # Example 4: MR(v1, v12) = 2
    idx = build_fast(h)
    assert mr_query(idx, 5, 8) == 2         # Example 7: MR(v6, v9) = 2


def test_table2_labels(fig1):
    """Golden: Table II labels (e-ids 1-based).  v10's (e2, ·) entry is 2,
    not the paper's printed 1 — provably a typo: Example 3 (MR(v1,v10)=2)
    is only answerable through hub e2 with min(2, s_v10) = 2."""
    h, _ = fig1
    idx = build_fast(h)
    want = {
        0: {2: 2, 1: 2, 7: 3}, 1: {2: 1, 1: 2}, 2: {2: 6, 4: 4, 7: 3},
        3: {2: 6, 4: 4, 7: 3}, 4: {2: 6, 5: 3}, 5: {2: 6, 5: 3},
        6: {2: 6, 6: 3}, 7: {2: 6, 6: 3}, 8: {2: 2, 3: 3, 6: 3},
        9: {2: 2, 5: 3, 3: 3}, 10: {2: 2, 4: 4}, 11: {2: 2, 4: 4, 3: 3},
    }
    for u in range(h.n):
        got = {int(e) + 1: int(s) for e, s in
               zip(idx.labels_edge[u], idx.labels_s[u])}
        assert got == want[u], f"v{u+1}: {got} != {want[u]}"


def test_vtv_overestimates_example5(fig1):
    h, oracle = fig1
    assert oracle[0, 11] == 2
    assert vtv_query(oracle, 0, 11) >= 3    # the false-positive pitfall


@pytest.mark.parametrize("seed", range(6))
def test_all_methods_match_oracle(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 35))
    m = int(rng.integers(8, 45))
    h = random_hypergraph(n, m, seed=seed)
    oracle = mr_oracle_dense(h)
    nc = precompute_neighbors(h)
    idx_b = build_basic(h)
    idx_f = build_fast(h)
    idx_m = minimize(idx_f)
    idx_e = exact_minimize(idx_f)
    ete = build_ete(h)
    tci = ThresholdComponentIndex(h)
    mst = MSTOracle(h)
    pairs = rng.integers(0, h.n, (30, 2))
    for u, v in pairs:
        u, v = int(u), int(v)
        o = int(oracle[u, v])
        assert mr_online(h, u, v, nc) == o
        assert mr_query(idx_b, u, v) == o
        assert mr_query(idx_f, u, v) == o
        assert mr_query(idx_m, u, v) == o
        assert mr_query(idx_e, u, v) == o
        assert ete.mr(u, v) == o
        assert tci.mr(u, v) == o
        assert mst.mr(u, v) == o


def test_planted_chain():
    h = planted_chain_hypergraph(2, 10, overlap=3, extra_size=2, seed=0)
    idx = minimize(build_fast(h))
    first_edge_first_chain = h.edge(0)
    last_edge_first_chain = h.edge(9)
    u = int(first_edge_first_chain[0])
    v = int(last_edge_first_chain[-1])
    assert mr_query(idx, u, v) == 3
    # across chains: unreachable
    other = int(h.edge(10)[0])
    assert mr_query(idx, u, other) == 0


def test_s_reachability_queries():
    h = paper_figure1()
    idx = build_fast(h)
    assert s_reach_query(idx, 4, 8, 2)          # v5 ~2~> v9
    assert not s_reach_query(idx, 4, 8, 3)      # no 3-walk (Example 1)
    assert s_reach_query(idx, 0, 9, 2)          # Example 3


def test_minimality_necessity():
    """Every label kept by exact_minimize is necessary: removing it breaks
    some query.  Algorithm 4 (minimize) stays complete and is measured
    against the exact pass (its removal order may differ)."""
    h = random_hypergraph(20, 30, seed=11)
    oracle = mr_oracle_dense(h)
    idx = exact_minimize(build_fast(h))
    # completeness
    for u in range(h.n):
        for v in range(h.n):
            assert mr_query(idx, u, v) == int(oracle[u, v])
    # necessity: drop each label, expect at least one query to change
    for u in range(h.n):
        for j in range(idx.labels_edge[u].size):
            e = int(idx.labels_edge[u][j])
            keep = np.arange(idx.labels_edge[u].size) != j
            saved = (idx.labels_edge[u], idx.labels_rank[u], idx.labels_s[u])
            idx.labels_edge[u] = idx.labels_edge[u][keep]
            idx.labels_rank[u] = idx.labels_rank[u][keep]
            idx.labels_s[u] = idx.labels_s[u][keep]
            broke = any(mr_query(idx, u, v) != int(oracle[u, v])
                        for v in range(h.n))
            idx.labels_edge[u], idx.labels_rank[u], idx.labels_s[u] = saved
            assert broke, f"label ({u}, e{e}) was removable"


def test_minimize_is_complete_and_not_larger():
    for seed in range(4):
        h = random_hypergraph(18, 28, seed=100 + seed)
        oracle = mr_oracle_dense(h)
        full = build_fast(h)
        mn = minimize(full)
        assert mn.num_labels <= full.num_labels
        for u in range(h.n):
            for v in range(h.n):
                assert mr_query(mn, u, v) == int(oracle[u, v])
