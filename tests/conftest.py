"""Test config.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; multi-device tests spawn subprocesses with their own flag
(see tests/util_subproc.py)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
