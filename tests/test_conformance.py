"""Cross-backend conformance matrix.

One parameterized matrix replaces the ad-hoc per-file equivalence checks
that used to live in test_engine.py / test_query_engines.py /
test_serving.py: every registered backend (plus the non-default build
configurations of the sharded-construction paths) × every engine
operation — ``mr``, ``s_reach``, ``mr_batch``, ``s_reach_batch``,
``snapshot``, ``update`` — is validated against the independent
``mst-oracle`` reference on every graph in the suite.

Capability flags are **asserted, never silently skipped**: a backend
with no snapshot form must raise ``SnapshotUnsupported`` (and one with
no update path ``UpdateUnsupported``) exactly where the pinned tables
below say so.  Registry drift — a new backend, or a capability change —
fails the matrix until the expectations here are updated consciously.
"""
import os
import tempfile

import numpy as np
import pytest

from repro.api import (ServiceConfig, available_backends, build_engine,
                       serve, update_capabilities, workload_capabilities,
                       random_hypergraph, planted_chain_hypergraph,
                       from_edge_lists)
from repro.store import load_index, save_index
from repro.core import (MSTOracle, PaddedIndex, apply_edge_edits, build_fast,
                        minimize, brute_force_mr_from_set, brute_force_mr_set,
                        brute_force_s_distance, brute_force_s_reach_k,
                        brute_force_top_s)
from repro.core.engine import (SnapshotUnsupported, UpdateUnsupported,
                               WorkloadUnsupported, WORKLOAD_OPS)
from repro.serve.reach_service import MRRequest, SReachRequest
from repro.workloads import verify_witness

BACKENDS = available_backends()

# ---------------------------------------------------------------------------
# pinned capability expectations — the registry must match these exactly
# ---------------------------------------------------------------------------

EXPECTED_SNAPSHOT = {
    "hl-index": True, "hl-index-basic": True, "ete": True,
    "closure": True, "sharded": True,
    "online": False, "frontier": False, "threshold": False,
    "mst-oracle": False,
}
EXPECTED_UPDATE = {
    "hl-index": "scoped", "hl-index-basic": "scoped",
    "online": "incremental", "frontier": "incremental",
    "closure": "rebuild", "sharded": "scoped",
    "ete": "unsupported", "threshold": "unsupported",
    "mst-oracle": "unsupported",
}
# workload capability: label ops (witness / mr_set / top_s) need a
# snapshot-capable label or closure form; traversal ops (s_reach_k /
# s_distance) need a live maintained graph.  The static Section IV/VII
# baselines serve neither.
_ALL_OPS = {op: True for op in WORKLOAD_OPS}
_NO_OPS = {op: False for op in WORKLOAD_OPS}
_LABEL_ONLY = dict(_NO_OPS, witness=True, mr_set=True, top_s=True)
_TRAVERSAL_ONLY = dict(_NO_OPS, s_reach_k=True, s_distance=True)
EXPECTED_WORKLOADS = {
    "hl-index": _ALL_OPS, "hl-index-basic": _ALL_OPS,
    "closure": _ALL_OPS, "sharded": _ALL_OPS,
    "ete": _LABEL_ONLY,
    "online": _TRAVERSAL_ONLY, "frontier": _TRAVERSAL_ONLY,
    "threshold": _NO_OPS, "mst-oracle": _NO_OPS,
}

# matrix rows: every registered backend under default options, plus the
# non-default construction paths (sharded label construction; the
# sharded backend's label regime) and the persistence round trip
# (``_restore``: build → save_index → load_index, then the full op set —
# a restored engine meets exactly the same conformance bar as a built
# one) — same bar for all
CONFIGS = {name: (name, {}) for name in BACKENDS}
CONFIGS["hl-index[sharded-build]"] = (
    "hl-index", dict(construction="sharded", num_shards=3))
CONFIGS["sharded[labels]"] = ("sharded", dict(build_labels=True))
CONFIGS["hl-index[restored]"] = ("hl-index", dict(_restore=True))
CONFIGS["sharded[restored]"] = ("sharded", dict(_restore=True))
# kernel rows: the same op set answered through the Pallas device path —
# label_join for batched queries (KernelSnapshot) and maxmin_matmul for
# the sharded closure contraction — pinned to the identical oracle
CONFIGS["hl-index[kernels]"] = ("hl-index", dict(use_kernels=True))
CONFIGS["sharded[kernels]"] = ("sharded", dict(use_kernels=True))
CONFIG_NAMES = sorted(CONFIGS)

# TemporaryDirectory handles for the restored rows: the loaded engines
# hold zero-copy views into the checkpoint mmap, so the files must
# outlive every test that queries them
_RESTORE_DIRS = []


def _build(h, config):
    """Build one engine for a matrix row; ``_restore`` rows round-trip
    it through a persisted checkpoint first."""
    backend, opts = CONFIGS[config]
    opts = dict(opts)
    restore = opts.pop("_restore", False)
    eng = build_engine(h, backend, **opts)
    if restore:
        td = tempfile.TemporaryDirectory()
        _RESTORE_DIRS.append(td)
        path = os.path.join(td.name, "ckpt.hlidx")
        save_index(path, eng)
        eng = load_index(path)
        assert eng.name == backend
    return eng

GRAPHS = {
    "random": lambda: random_hypergraph(30, 45, seed=3),
    "chain": lambda: planted_chain_hypergraph(2, 6, overlap=2,
                                              extra_size=2, seed=0),
    "isolated": lambda: from_edge_lists([[0, 1, 2], [2, 3], [5, 6, 7],
                                         [6, 7, 8]], n=12),
}


def test_matrix_covers_registry_exactly():
    # the pinned tables and the live registry must agree both ways — a
    # backend registered without a row here (or vice versa) is loud
    assert set(EXPECTED_SNAPSHOT) == set(BACKENDS)
    assert set(EXPECTED_UPDATE) == set(BACKENDS)
    assert set(EXPECTED_WORKLOADS) == set(BACKENDS)
    assert update_capabilities() == EXPECTED_UPDATE
    assert workload_capabilities() == EXPECTED_WORKLOADS
    assert "vtv" not in BACKENDS          # unsound for MR (paper Example 5)


@pytest.fixture(scope="module", params=sorted(GRAPHS))
def case(request):
    h = GRAPHS[request.param]()
    rng = np.random.default_rng(7)
    us = rng.integers(0, h.n, 60)
    vs = rng.integers(0, h.n, 60)
    oracle = MSTOracle(h)
    want = np.array([oracle.mr(int(u), int(v)) for u, v in zip(us, vs)],
                    np.int64)
    return request.param, h, us, vs, want


_ENGINES = {}


def _engine(graph_name, h, config):
    """One engine per (graph, config), shared by the read-only ops."""
    key = (graph_name, config)
    if key not in _ENGINES:
        _ENGINES[key] = _build(h, config)
    return _ENGINES[key]


# ---------------------------------------------------------------------------
# the matrix: config × operation, answers pinned to mst-oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_op_mr(case, config):
    name, h, us, vs, want = case
    eng = _engine(name, h, config)
    assert eng.name == CONFIGS[config][0]
    for u, v, w in zip(us[:20], vs[:20], want[:20]):
        assert eng.mr(int(u), int(v)) == int(w)
    # scalar paths reject out-of-range ids like the batch paths — a
    # Python negative index must never silently answer from another row
    with pytest.raises(IndexError):
        eng.mr(-1, 0)
    with pytest.raises(IndexError):
        eng.mr(0, h.n)


@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_op_s_reach(case, config):
    name, h, us, vs, want = case
    eng = _engine(name, h, config)
    for s in (1, 2, 3):
        for u, v, w in zip(us[:10], vs[:10], want[:10]):
            assert eng.s_reach(int(u), int(v), s) == (int(w) >= s)


@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_op_mr_batch(case, config):
    name, h, us, vs, want = case
    eng = _engine(name, h, config)
    got = np.asarray(eng.mr_batch(us, vs)).astype(np.int64)
    np.testing.assert_array_equal(got, want)
    assert len(eng.mr_batch([], [])) == 0     # empty batches legal


@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_op_s_reach_batch(case, config):
    name, h, us, vs, want = case
    eng = _engine(name, h, config)
    for s in (1, 2, 3):
        got = np.asarray(eng.s_reach_batch(us, vs, s))
        np.testing.assert_array_equal(got, want >= s)


@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_op_snapshot(case, config):
    name, h, us, vs, want = case
    eng = _engine(name, h, config)
    backend = CONFIGS[config][0]
    if not EXPECTED_SNAPSHOT[backend]:
        # capability asserted, not skipped: the declared-unsupported
        # backends must raise, and must keep raising (not silently grow
        # a half-working snapshot path)
        with pytest.raises(SnapshotUnsupported):
            eng.snapshot()
        return
    snap = eng.snapshot()
    got = np.asarray(snap.mr(us, vs)).astype(np.int64)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(np.asarray(snap.s_reach(us, vs, 2)),
                                  want >= 2)
    assert snap.backend == backend
    assert snap.version == eng.version
    assert snap.nbytes() > 0 or h.m == 0
    assert eng.snapshot() is snap             # cached while un-updated


@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_op_update(case, config):
    name, h, us, vs, want = case
    backend, _ = CONFIGS[config]
    eng = _build(h, config)                   # fresh: update mutates
    assert eng.version == 0
    if EXPECTED_UPDATE[backend] == "unsupported":
        with pytest.raises(UpdateUnsupported):
            eng.update(inserts=[[0, 1]])
        assert eng.version == 0               # refused == untouched
        return
    ins, dels = [[0, 1, h.n - 1]], ([2] if h.m > 2 else [])
    eng.update(inserts=ins, deletes=dels)
    assert eng.version == 1
    h2, _, _ = apply_edge_edits(h, ins, dels)
    oracle = MSTOracle(h2)
    rng = np.random.default_rng(1)
    us2 = rng.integers(0, h2.n, 40)
    vs2 = rng.integers(0, h2.n, 40)
    want2 = np.array([oracle.mr(int(u), int(v)) for u, v in zip(us2, vs2)],
                     np.int64)
    got = np.asarray(eng.mr_batch(us2, vs2)).astype(np.int64)
    np.testing.assert_array_equal(got, want2)
    for u, v, w in zip(us2[:8], vs2[:8], want2[:8]):
        assert eng.mr(int(u), int(v)) == int(w)
        assert eng.s_reach(int(u), int(v), 2) == (int(w) >= 2)


# ---------------------------------------------------------------------------
# workload ops ride the same matrix: one row per op × config, answers
# pinned to the brute-force references; unsupported cells must raise
# WorkloadUnsupported (asserted, never skipped)
# ---------------------------------------------------------------------------

def _workload_supported(config, op):
    return EXPECTED_WORKLOADS[CONFIGS[config][0]][op]


@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_op_witness(case, config):
    name, h, us, vs, want = case
    eng = _engine(name, h, config)
    if not _workload_supported(config, "witness"):
        with pytest.raises(WorkloadUnsupported):
            eng.mr_witness(int(us[0]), int(vs[0]))
        return
    for u, v, w in zip(us[:10], vs[:10], want[:10]):
        wit = eng.mr_witness(int(u), int(v))
        assert wit.u == int(u) and wit.v == int(v)
        assert wit.s == int(w)                # witness strength == MR
        assert verify_witness(h, wit)         # walk is a valid s-walk
    with pytest.raises(IndexError):
        eng.mr_witness(-1, 0)


@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_op_s_reach_k(case, config):
    name, h, us, vs, want = case
    eng = _engine(name, h, config)
    if not _workload_supported(config, "s_reach_k"):
        with pytest.raises(WorkloadUnsupported):
            eng.s_reach_k(int(us[0]), int(vs[0]), 1, 1)
        return
    for s in (1, 2):
        for k in (1, 2, h.m):
            for u, v in zip(us[:8], vs[:8]):
                assert eng.s_reach_k(int(u), int(v), s, k) == \
                    brute_force_s_reach_k(h, int(u), int(v), s, k)
    with pytest.raises(ValueError):
        eng.s_reach_k(int(us[0]), int(vs[0]), 0, 1)
    with pytest.raises(ValueError):
        eng.s_reach_k(int(us[0]), int(vs[0]), 1, 0)


@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_op_mr_set(case, config):
    name, h, us, vs, want = case
    eng = _engine(name, h, config)
    if not _workload_supported(config, "mr_set"):
        with pytest.raises(WorkloadUnsupported):
            eng.mr_set(us[:3], vs[:3])
        return
    for a, b in ((6, 6), (1, 12), (12, 1)):
        U, V = us[:a], vs[:b]
        assert eng.mr_set(U, V) == brute_force_mr_set(h, U, V)
    targets = np.arange(h.n)
    got = np.asarray(eng.mr_from_set(us[:5], targets)).astype(np.int64)
    np.testing.assert_array_equal(
        got, brute_force_mr_from_set(h, us[:5], targets))
    with pytest.raises(ValueError):
        eng.mr_set(np.array([], np.int64), vs[:3])


@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_op_top_s(case, config):
    name, h, us, vs, want = case
    eng = _engine(name, h, config)
    if not _workload_supported(config, "top_s"):
        with pytest.raises(WorkloadUnsupported):
            eng.top_s(int(us[0]), 3)
        return
    for u in {int(x) for x in us[:6]}:
        for k in (1, 4, h.n):
            verts, vals = eng.top_s(u, k)
            bv, bs = brute_force_top_s(h, u, k)
            np.testing.assert_array_equal(np.asarray(verts), bv)
            np.testing.assert_array_equal(np.asarray(vals), bs)
    with pytest.raises(ValueError):
        eng.top_s(int(us[0]), 0)


@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_op_s_distance(case, config):
    name, h, us, vs, want = case
    eng = _engine(name, h, config)
    if not _workload_supported(config, "s_distance"):
        with pytest.raises(WorkloadUnsupported):
            eng.s_distance(int(us[0]), int(vs[0]), 1)
        return
    for s in (1, 2):
        for u, v in zip(us[:12], vs[:12]):
            bound = eng.s_distance(int(u), int(v), s)
            exact = brute_force_s_distance(h, int(u), int(v), s)
            # certified: reachability is never wrong, bounds are walks
            assert (bound == 0) == (exact == 0), (u, v, s)
            assert bound >= exact
    with pytest.raises(ValueError):
        eng.s_distance(int(us[0]), int(vs[0]), 0)


# ---------------------------------------------------------------------------
# serving layer rides the same matrix: service answers == oracle on every
# backend (moved here from test_serving.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("config", CONFIG_NAMES)
def test_service_matches_oracle(config):
    backend, opts = CONFIGS[config]
    h = random_hypergraph(30, 45, seed=3)
    if opts.get("_restore"):
        svc = serve(_build(h, config), start=False)
    else:
        opts = dict(opts)
        svc_cfg = ServiceConfig(use_kernels=opts.pop("use_kernels", None))
        svc = serve(h, backend, start=False, config=svc_cfg, **opts)
    oracle = MSTOracle(h)
    rng = np.random.default_rng(7)
    reqs, want = [], []
    for _ in range(80):
        u, v = int(rng.integers(h.n)), int(rng.integers(h.n))
        mr = oracle.mr(u, v)
        if rng.random() < 0.5:
            reqs.append(MRRequest(u, v))
            want.append(mr)
        else:
            s = int(rng.integers(1, 5))
            reqs.append(SReachRequest(u, v, s))
            want.append(mr >= s)
    futs = svc.submit_many(reqs)
    assert svc.pending() == 80
    svc.drain()
    assert svc.pending() == 0
    for req, fut, w in zip(reqs, futs, want):
        got = fut.result(timeout=0)
        assert got == w, (req, got, w)
        assert isinstance(got, int if req.kind == "mr" else bool)


# ---------------------------------------------------------------------------
# back-compat padded form (moved here from test_query_engines.py): the
# PaddedIndex constructor serves the same answers as the engine snapshot
# ---------------------------------------------------------------------------

def test_padded_index_backcompat_matches_oracle():
    h = random_hypergraph(40, 60, seed=9)
    idx = minimize(build_fast(h))
    pidx = PaddedIndex(idx)
    oracle = MSTOracle(h)
    rng = np.random.default_rng(0)
    us = rng.integers(0, h.n, 200)
    vs = rng.integers(0, h.n, 200)
    want = np.array([oracle.mr(int(u), int(v)) for u, v in zip(us, vs)],
                    np.int64)
    np.testing.assert_array_equal(np.asarray(pidx.mr(us, vs)).astype(np.int64),
                                  want)
    for s in (1, 2, 3):
        np.testing.assert_array_equal(np.asarray(pidx.s_reach(us, vs, s)),
                                      want >= s)
    snap = build_engine(h, "hl-index").snapshot()
    np.testing.assert_array_equal(np.asarray(pidx.mr(us, vs)),
                                  np.asarray(snap.mr(us, vs)))
