"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.maxmin_matmul import maxmin_matmul_pallas
from repro.kernels.overlap import overlap_pallas
from repro.kernels.threshold_closure import threshold_step_pallas
from repro.kernels.label_join import label_join_pallas
from repro.kernels import maxmin_closure_kernel, threshold_mr_kernel
from repro.core import (paper_figure1, random_hypergraph, mr_matrix,
                        distinct_thresholds)


@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (7, 13, 5), (64, 33, 96),
                                   (1, 100, 1), (128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_maxmin_matmul_sweep(m, k, n, dtype):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = jnp.asarray(rng.integers(0, 12, (m, k))).astype(dtype)
    b = jnp.asarray(rng.integers(0, 12, (k, n))).astype(dtype)
    got = maxmin_matmul_pallas(a, b, bm=32, bn=32, bk=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.maxmin_matmul_ref(a, b)))


@pytest.mark.parametrize("m,n", [(10, 17), (64, 64), (130, 40)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_overlap_sweep(m, n, dtype):
    rng = np.random.default_rng(m + n)
    b_inc = jnp.asarray((rng.random((m, n)) < 0.3).astype(np.float32)).astype(dtype)
    got = overlap_pallas(b_inc, bm=32, bn=32, bk=32, interpret=True)
    want = ref.overlap_ref(b_inc.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.01)


@pytest.mark.parametrize("s,m", [(1, 16), (3, 40), (5, 70)])
def test_threshold_step_sweep(s, m):
    rng = np.random.default_rng(s * 100 + m)
    r = jnp.asarray((rng.random((s, m, m)) < 0.15).astype(np.float32))
    got = threshold_step_pallas(r, bm=32, bn=32, bk=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.threshold_step_ref(r)))


@pytest.mark.parametrize("q,l", [(5, 8), (64, 16), (130, 32)])
def test_label_join_sweep(q, l):
    rng = np.random.default_rng(q + l)
    ru = np.sort(rng.integers(0, 60, (q, l)), axis=1).astype(np.int32)
    rv = np.sort(rng.integers(0, 60, (q, l)), axis=1).astype(np.int32)
    su = rng.integers(1, 9, (q, l)).astype(np.int32)
    sv = rng.integers(1, 9, (q, l)).astype(np.int32)
    got = label_join_pallas(jnp.asarray(ru), jnp.asarray(su), jnp.asarray(rv),
                            jnp.asarray(sv), bq=32, interpret=True)
    want = ref.label_join_ref(*map(jnp.asarray, (ru, su, rv, sv)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_closures_match_oracle():
    h = random_hypergraph(20, 30, seed=5)
    w = jnp.asarray(h.line_graph(np.int32).astype(np.float32))
    oracle = mr_matrix(h).astype(np.float32)
    got_mm = maxmin_closure_kernel(w, bm=16, bn=16, bk=16)
    np.testing.assert_array_equal(np.asarray(got_mm), oracle)
    thr = distinct_thresholds(np.asarray(w))
    got_tc = threshold_mr_kernel(w, thr, bm=16, bn=16, bk=16)
    np.testing.assert_array_equal(np.asarray(got_tc), oracle)
