"""Unified ReachabilityEngine API: the auto planner, the engine.update
sequencing contracts, snapshot invalidation, the vectorized as_padded
export, the sharded backend's mesh handling, and the deprecated-alias
shims.

The per-backend × per-operation oracle equivalence matrix (every
registered backend, capability flags asserted) lives in
tests/test_conformance.py — this file keeps only the behaviors that are
not a (backend, operation) matrix cell.
"""
import numpy as np
import pytest

from repro.api import (build_engine, available_backends, plan_backend,
                       update_capabilities, random_hypergraph,
                       from_edge_lists)
from repro.core import MSTOracle, apply_edge_edits, build_fast, minimize
from repro.core.engine import SnapshotUnsupported

BACKENDS = available_backends()


def test_auto_planner_picks_registered_backend():
    h = random_hypergraph(30, 45, seed=3)
    for hint in (None, 8, 10_000):
        name = plan_backend(h, hint)
        assert name in BACKENDS
        eng = build_engine(h, "auto", batch_hint=hint)
        assert eng.name in BACKENDS
    with pytest.raises(ValueError, match="unknown backend"):
        build_engine(h, "no-such-backend")


def test_auto_engine_matches_oracle():
    h = random_hypergraph(25, 35, seed=11)
    oracle = MSTOracle(h)
    rng = np.random.default_rng(0)
    us, vs = rng.integers(0, h.n, 40), rng.integers(0, h.n, 40)
    want = np.array([oracle.mr(int(u), int(v)) for u, v in zip(us, vs)])
    eng = build_engine(h, "auto", batch_hint=len(us))
    np.testing.assert_array_equal(
        np.asarray(eng.mr_batch(us, vs)).astype(np.int64), want)


# ---------------------------------------------------------------------------
# engine.update: multi-step sequencing vs fresh rebuilds, snapshot
# invalidation (the single-step capability contract is a conformance cell)
# ---------------------------------------------------------------------------

CAPS = update_capabilities()
UPDATABLE = [b for b in BACKENDS if CAPS[b] != "unsupported"]


def _oracle_answers(h, us, vs):
    oracle = MSTOracle(h)
    return np.array([oracle.mr(int(u), int(v)) for u, v in zip(us, vs)],
                    np.int64)


@pytest.mark.parametrize("backend", UPDATABLE)
def test_update_sequence_matches_fresh_rebuild(backend):
    rng = np.random.default_rng(11)
    h = random_hypergraph(18, 12, seed=8)
    eng = build_engine(h, backend)
    for step in range(4):
        ins, dels = [], []
        if h.m > 2 and rng.random() < 0.5:
            dels = list(rng.choice(h.m, size=int(rng.integers(1, 3)),
                                   replace=False))
        if rng.random() < 0.8:
            ins.append(rng.choice(h.n + 2, size=3, replace=False))
        eng.update(inserts=ins, deletes=dels)
        h, _, _ = apply_edge_edits(h, ins, dels)
        assert eng.version == step + 1
        fresh = build_engine(h, backend)
        us = rng.integers(0, h.n, 30)
        vs = rng.integers(0, h.n, 30)
        want = np.asarray(fresh.mr_batch(us, vs)).astype(np.int64)
        got = np.asarray(eng.mr_batch(us, vs)).astype(np.int64)
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(want, _oracle_answers(h, us, vs))


@pytest.mark.parametrize("backend", UPDATABLE)
def test_update_invalidates_snapshot(backend):
    h = random_hypergraph(16, 12, seed=9)
    eng = build_engine(h, backend)
    try:
        snap0 = eng.snapshot()
    except SnapshotUnsupported:
        pytest.skip(f"{backend} has no padded device form")
    assert snap0.version == 0
    assert eng.snapshot() is snap0            # cached while un-updated
    eng.update(inserts=[[0, 3, 7]])
    snap1 = eng.snapshot()
    assert snap1 is not snap0                 # stale snapshot dropped
    assert snap1.version == eng.version == 1
    assert snap0.version != eng.version       # staleness is detectable
    h2, _, _ = apply_edge_edits(h, [[0, 3, 7]], [])
    rng = np.random.default_rng(1)
    us, vs = rng.integers(0, h2.n, 30), rng.integers(0, h2.n, 30)
    np.testing.assert_array_equal(
        np.asarray(snap1.mr(us, vs)).astype(np.int64),
        _oracle_answers(h2, us, vs))


@pytest.mark.parametrize("backend", UPDATABLE)
def test_update_to_empty_graph_and_back(backend):
    h = from_edge_lists([[0, 1, 2], [2, 3]], n=5)
    eng = build_engine(h, backend)
    eng.update(deletes=[0, 1])                # graph emptied
    assert eng.mr(0, 3) == 0
    np.testing.assert_array_equal(eng.mr_batch([0, 1], [2, 3]),
                                  np.zeros(2, np.int64))
    eng.update(inserts=[[0, 3], [3, 4]])      # and repopulated
    want = _oracle_answers(from_edge_lists([[0, 3], [3, 4]], n=5),
                           [0, 0, 1], [3, 4, 2])
    np.testing.assert_array_equal(
        np.asarray(eng.mr_batch([0, 0, 1], [3, 4, 2])).astype(np.int64),
        want)


def test_post_update_snapshot_on_device_mesh():
    # runs on a mesh over every visible device: a real 2x2 mesh in the
    # CI multi-device job (XLA_FLAGS=--xla_force_host_platform_device_
    # count=4), a degenerate 1x1 mesh elsewhere — same assertions
    from repro.core.distributed import default_line_graph_mesh
    h = random_hypergraph(26, 20, seed=6)
    mesh = default_line_graph_mesh()
    eng = build_engine(h, "sharded", mesh=mesh)
    snap0 = eng.snapshot()
    eng.update(inserts=[[0, 1, 2]], deletes=[3])
    snap1 = eng.snapshot()
    assert snap1 is not snap0 and snap1.version == 1
    h2, _, _ = apply_edge_edits(h, [[0, 1, 2]], [3])
    rng = np.random.default_rng(2)
    us, vs = rng.integers(0, h2.n, 40), rng.integers(0, h2.n, 40)
    want = _oracle_answers(h2, us, vs)
    np.testing.assert_array_equal(
        np.asarray(eng.mr_batch(us, vs)).astype(np.int64), want)
    # to_mesh keeps answers and the version, so resharded copies of the
    # fresh snapshot stay comparable against the engine
    hl = build_engine(h2, "hl-index")
    hl.update(inserts=[[4, 5]])
    sh = hl.snapshot().to_mesh(mesh)
    assert sh.version == hl.version == 1
    h3, _, _ = apply_edge_edits(h2, [[4, 5]], [])
    np.testing.assert_array_equal(
        np.asarray(sh.mr(us, vs)).astype(np.int64),
        _oracle_answers(h3, us, vs))


# ---------------------------------------------------------------------------
# satellite: vectorized as_padded must match the per-row reference scatter
# ---------------------------------------------------------------------------

def _as_padded_reference(idx, pad_to=None):
    n = idx.h.n
    lengths = np.array([a.size for a in idx.labels_s], np.int32)
    lmax = int(pad_to if pad_to is not None else (lengths.max() if n else 0))
    ranks = np.full((n, lmax), np.iinfo(np.int32).max, np.int32)
    svals = np.zeros((n, lmax), np.int32)
    for u in range(n):
        k = int(lengths[u])
        ranks[u, :k] = idx.labels_rank[u][:k]
        svals[u, :k] = idx.labels_s[u][:k]
    return ranks, svals, lengths


@pytest.mark.parametrize("pad_to", [None, 40])
def test_as_padded_vectorized_identity(pad_to):
    h = random_hypergraph(35, 50, seed=5)
    for idx in (build_fast(h), minimize(build_fast(h))):
        got = idx.as_padded(pad_to)
        want = _as_padded_reference(idx, pad_to)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)


def test_as_padded_empty_labels():
    h = from_edge_lists([[0, 1]], n=4)    # vertices 2, 3 label-free
    idx = build_fast(h)
    got = idx.as_padded()
    want = _as_padded_reference(idx)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


# ---------------------------------------------------------------------------
# satellite: deprecated aliases still resolve (loudly)
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# sharded backend: multi-device meshes (subprocess — the host device count
# must be forced before jax init), unit-axis degradation, mesh-aware planner
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_sharded_backend_on_host_mesh(n_devices):
    from util_subproc import run_with_devices
    out = run_with_devices("""
import numpy as np
from repro.api import build_engine, plan_backend, random_hypergraph
from repro.core import MSTOracle
from repro.core.distributed import default_line_graph_mesh

h = random_hypergraph(40, 30, seed=5)
oracle = MSTOracle(h)
rng = np.random.default_rng(1)
us, vs = rng.integers(0, h.n, 64), rng.integers(0, h.n, 64)
want = np.array([oracle.mr(int(u), int(v)) for u, v in zip(us, vs)], np.int64)

mesh = default_line_graph_mesh()
assert mesh.devices.size == %(nd)d, mesh
for sched in ("allgather", "ring"):
    eng = build_engine(h, "sharded", mesh=mesh, schedule=sched)
    assert eng.name == "sharded"
    got = np.asarray(eng.mr_batch(us, vs)).astype(np.int64)
    assert np.array_equal(got, want), sched
    for s in (1, 2, 3):
        assert np.array_equal(np.asarray(eng.s_reach_batch(us, vs, s)),
                              want >= s), sched
    for u, v, w in zip(us[:8], vs[:8], want[:8]):
        assert eng.mr(int(u), int(v)) == int(w)
        assert eng.s_reach(int(u), int(v), 2) == (int(w) >= 2)
    # the snapshot is built once and survives across query batches
    assert eng.snapshot() is eng.snapshot()
    assert eng.nbytes() > 0

# mesh-aware planner: sharded iff the mesh is multi-device AND the
# closure exceeds the single-device budget
assert plan_backend(h) != "sharded"
picked = plan_backend(h, mesh=mesh, device_budget_bytes=0)
assert (picked == "sharded") == (mesh.devices.size > 1), picked
assert plan_backend(h, 64, mesh=mesh, device_budget_bytes=1 << 40) == "closure"
if mesh.devices.size > 1:
    eng = build_engine(h, "auto", mesh=mesh, device_budget_bytes=0)
    assert eng.name == "sharded"
    assert np.array_equal(np.asarray(eng.mr_batch(us, vs)).astype(np.int64),
                          want)

# generic label snapshots reshard losslessly through to_mesh
hl = build_engine(h, "hl-index")
snap = hl.snapshot()
sh = snap.to_mesh(mesh)
assert np.array_equal(np.asarray(sh.mr(us, vs)), np.asarray(snap.mr(us, vs)))
assert sh.backend == "hl-index"
print("OK")
""" % {"nd": n_devices}, n_devices=n_devices)
    assert "OK" in out


def test_sharded_unit_axis_mesh_degrades():
    # a (1, 1) mesh runs in-process on the single test device: the
    # collectives become no-ops and answers are unchanged
    from repro.api import make_mesh
    h = random_hypergraph(25, 20, seed=9)
    from repro.core import MSTOracle
    oracle = MSTOracle(h)
    rng = np.random.default_rng(2)
    us, vs = rng.integers(0, h.n, 40), rng.integers(0, h.n, 40)
    want = np.array([oracle.mr(int(u), int(v)) for u, v in zip(us, vs)],
                    np.int64)
    mesh = make_mesh((1, 1), ("data", "model"))
    for sched in ("allgather", "ring"):
        eng = build_engine(h, "sharded", mesh=mesh, schedule=sched)
        np.testing.assert_array_equal(
            np.asarray(eng.mr_batch(us, vs)).astype(np.int64), want)


def test_planner_never_sharded_without_multi_device_mesh():
    from repro.api import make_mesh
    h = random_hypergraph(30, 45, seed=3)
    # no mesh: sharded is unreachable regardless of budget
    for hint in (None, 8, 10_000):
        assert plan_backend(h, hint, device_budget_bytes=0) != "sharded"
    # unit mesh: still unreachable (1 device = nothing to shard over)
    mesh1 = make_mesh((1, 1), ("data", "model"))
    assert plan_backend(h, mesh=mesh1, device_budget_bytes=0) != "sharded"


def test_planner_never_sharded_on_one_axis_mesh():
    # sharded needs two mesh axes to 2-D block-shard over; auto must not
    # route a 1-D mesh to a backend that cannot be built on it
    from util_subproc import run_with_devices
    out = run_with_devices("""
from repro.api import build_engine, plan_backend, make_mesh, random_hypergraph
h = random_hypergraph(30, 45, seed=3)
mesh = make_mesh((4,), ("data",))
picked = plan_backend(h, mesh=mesh, device_budget_bytes=0)
assert picked != "sharded", picked
eng = build_engine(h, "auto", mesh=mesh, device_budget_bytes=0)
assert eng.name == picked
print("OK")
""", n_devices=4)
    assert "OK" in out


def test_sharded_empty_hypergraph():
    h = from_edge_lists([], n=5)
    eng = build_engine(h, "sharded")
    assert eng.mr(0, 4) == 0
    np.testing.assert_array_equal(eng.mr_batch([0, 1], [2, 3]),
                                  np.zeros(2, np.int64))


def test_deprecated_frontier_aliases_removed():
    # the PR 1 compatibility aliases are gone: the unprefixed names no
    # longer resolve on the frontier module, and `batched_s_reach` is no
    # longer re-exported by repro.core at all
    import repro.core as core
    import repro.core.frontier as frontier
    with pytest.raises(AttributeError):
        frontier.batched_mr
    with pytest.raises(AttributeError):
        frontier.batched_s_reach
    with pytest.raises(AttributeError):
        core.batched_s_reach
    # the label-join engine owns the unprefixed name
    from repro.core import batched_mr
    from repro.core.query import batched_mr as query_batched_mr
    assert batched_mr is query_batched_mr
