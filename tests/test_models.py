"""Per-arch smoke tests (reduced same-family configs) + decode-vs-prefill
consistency (the KV/state cache path must reproduce the full forward)."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config, get_config
from repro.models import build_model
from repro.launch.shapes import SHAPES, cell_applicable


def _batch(cfg, B, S, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.family == "vlm":
        P = cfg.num_patches
        batch["tokens"] = batch["tokens"][:, :S - P]
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, P, cfg.vision_dim)).astype(np.float32))
    elif cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_frames, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 16
    batch = _batch(cfg, B, S, rng)
    if cfg.family == "vlm":
        logits, _ = model.apply(params, batch["tokens"], batch["patch_embeds"])
        assert logits.shape == (B, S, cfg.vocab)
    elif cfg.family == "encdec":
        logits, _ = model.apply(params, batch["tokens"], batch["frames"])
        assert logits.shape == (B, S, cfg.vocab)
    else:
        logits, _ = model.apply(params, batch["tokens"])
        assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    from repro.train import AdamConfig, adam_init, make_train_step
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamConfig(lr=1e-3, use_8bit=cfg.opt_8bit, total_steps=10)
    opt = adam_init(params, opt_cfg)
    step = jax.jit(make_train_step(model, cfg, opt_cfg))
    rng = np.random.default_rng(1)
    batch = _batch(cfg, 2, 16, rng)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, x: acc or bool(x), jax.tree.map(
            lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))),
            params, params2), False)
    assert moved


# decode consistency: teacher-forced decode must reproduce the full forward
_DECODE_TOL = dict(rtol=2e-2, atol=2e-2)    # bf16 cache round-trip


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "falcon_mamba_7b",
                                  "recurrentgemma_2b", "whisper_large_v3",
                                  "qwen2_moe_a2_7b"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    # full-precision cache so the comparison is tight
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    B, S = 2, 12
    batch = _batch(cfg, B, S, rng)
    if cfg.family == "encdec":
        full, _ = model.apply(params, batch["tokens"], batch["frames"])
    else:
        full, _ = model.apply(params, batch["tokens"])
    cache = model.init_cache(B, S, dtype=jnp.float32)
    if cfg.family == "encdec":
        cache = model.prefill_cross(params, cache, batch["frames"])
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, batch["tokens"][:, t:t+1],
                                      jnp.int32(t))
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full, np.float32),
                               **_DECODE_TOL)


def test_full_configs_match_assignment():
    """Pin the exact assigned architecture hyperparameters."""
    spec = {
        "llava_next_mistral_7b": (32, 4096, 32, 8, 14336, 32000),
        "falcon_mamba_7b": (64, 4096, 1, 1, 0, 65024),
        "qwen2_5_14b": (48, 5120, 40, 8, 13824, 152064),
        "qwen2_7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen3_1_7b": (28, 2048, 16, 8, 6144, 151936),
        "minitron_8b": (32, 4096, 32, 8, 16384, 256000),
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
    }
    for arch, (L, d, h, kv, f, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, f, v), arch
    # family-specific extras
    assert get_config("falcon_mamba_7b").ssm_state == 16
    assert get_config("qwen2_moe_a2_7b").n_experts == 60
    assert get_config("qwen2_moe_a2_7b").top_k == 4
    assert get_config("qwen2_moe_a2_7b").n_shared_experts == 4
    assert get_config("arctic_480b").n_experts == 128
    assert get_config("arctic_480b").top_k == 2
    assert get_config("arctic_480b").dense_residual
    assert get_config("qwen3_1_7b").qk_norm
    assert get_config("qwen2_7b").qkv_bias and get_config("qwen2_5_14b").qkv_bias
    assert get_config("recurrentgemma_2b").window == 2048
    assert get_config("arctic_480b").n_params() > 400e9   # ~480B total
    assert get_config("arctic_480b").n_active_params() < 30e9


def test_shape_cell_applicability():
    from repro.configs import ALIASES
    cells = [(a, s.name, cell_applicable(get_config(a), s)[0])
             for a in ARCH_IDS for s in SHAPES.values()]
    assert len(cells) == 40
    runs = sum(1 for *_, ok in cells if ok)
    skips = [(a, s) for a, s, ok in cells if not ok]
    assert runs == 32
    assert all(s == "long_500k" for _, s in skips)
    assert {a for a, _ in skips} == {
        "llava_next_mistral_7b", "qwen2_5_14b", "qwen2_7b", "qwen3_1_7b",
        "minitron_8b", "whisper_large_v3", "qwen2_moe_a2_7b", "arctic_480b"}


def test_chunked_attention_matches_dense():
    # f32 compute so the only difference is the chunked online softmax
    cfg = dataclasses.replace(get_smoke_config("qwen3_1_7b"), attn_chunk=4,
                              compute_dtype="float32")
    cfg0 = dataclasses.replace(cfg, attn_chunk=0)
    model = build_model(cfg0)
    params = model.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)))
    dense, _ = build_model(cfg0).apply(params, x)
    chunked, _ = build_model(cfg).apply(params, x)
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(chunked, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_windowed_chunked_attention():
    cfg = dataclasses.replace(get_smoke_config("recurrentgemma_2b"),
                              attn_chunk=4, window=6,
                              compute_dtype="float32")
    cfg0 = dataclasses.replace(cfg, attn_chunk=0)
    model0 = build_model(cfg0)
    params = model0.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)))
    dense, _ = model0.apply(params, x)
    chunked, _ = build_model(cfg).apply(params, x)
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(chunked, np.float32),
                               rtol=1e-4, atol=1e-4)
