"""Epidemic case study (paper Exp-5 / Fig. 4): co-location hypergraph,
risk quantification by max-reachability — now told through the workload
subsystem (``repro.workloads``): contact-tracing chains come from
witness extraction, spread horizons from hop-bounded s-reach and the
landmark s-distance oracle, superspreaders from top-k ranking, and
cohort risk from set-to-set MR.  Every headline number is asserted
against the brute-force references, so the story doubles as a check.

  PYTHONPATH=src python examples/epidemic_case_study.py
"""
import numpy as np

from repro.api import build_engine, colocation_hypergraph, verify_witness
from repro.core import (brute_force_mr_set, brute_force_s_distance,
                        brute_force_s_reach_k, brute_force_top_s, MSTOracle)


def main():
    # 21-day window, one hyperedge per (place, day): people checked in
    h = colocation_hypergraph(n_people=400, n_places=12, n_days=21,
                              p_checkin=0.03, seed=3)
    print(f"co-location hypergraph: {h.n} people, {h.m} (place, day) groups")
    eng = build_engine(h, "hl-index")
    oracle = MSTOracle(h)        # brute-force cross-check for point MR

    patient_zero = int(np.argmax(h.vertex_degrees))
    everyone = np.arange(h.n)
    risk = np.asarray(eng.mr_batch(np.full(h.n, patient_zero), everyone))
    print(f"\nindex case: person {patient_zero} "
          f"({h.degree(patient_zero)} check-ins)")

    # -- contact tracing: witness walks name the actual venues ------------
    # MR says *how strong* a transmission chain is; the witness walk says
    # *which (place, day) groups* realize it — the actionable artifact.
    order = np.argsort(-risk)
    order = order[order != patient_zero]
    # top contacts share a venue directly; a mid-risk contact shows a
    # genuine multi-gathering chain
    mid = int(order[np.searchsorted(-risk[order], -3)])
    print("\ncontact-tracing chains (top-risk and one mid-risk contact):")
    for p in [*order[:3], mid]:
        w = eng.mr_witness(patient_zero, int(p))
        assert verify_witness(h, w)            # walk is a valid s-walk
        assert w.s == oracle.mr(patient_zero, int(p))
        hops = " -> ".join(f"group {e}" for e in w.walk)
        print(f"  person {int(p):4d}  MR = {w.s}  via {hops}")

    # -- spread horizon: how fast can infection arrive? -------------------
    # s_reach_k bounds the walk length: "reachable within k gatherings".
    s = 2
    top = int(order[0])
    horizon = next(k for k in range(1, h.m + 1)
                   if eng.s_reach_k(patient_zero, top, s, k))
    assert brute_force_s_reach_k(h, patient_zero, top, s, horizon)
    assert not brute_force_s_reach_k(h, patient_zero, top, s, horizon - 1)
    print(f"\nspread horizon (s = {s}): person {top} is reachable in "
          f"{horizon} gathering(s), not fewer")

    # the landmark oracle serves certified upper bounds on that horizon
    # for the whole population at once — bound >= exact, zero iff zero
    do = eng.distance_oracle(s)
    sample = [int(p) for p in order[:5]]
    print(f"landmark s-distance bounds ({do.num_landmarks} landmarks):")
    for p in sample:
        bound = eng.s_distance(patient_zero, p, s)
        exact = brute_force_s_distance(h, patient_zero, p, s)
        assert (bound == 0) == (exact == 0) and bound >= exact
        print(f"  person {p:4d}  <= {bound} gatherings (exact {exact})")

    # -- superspreaders: top-k strongest-s ranking ------------------------
    print("\ntop-5 superspreader contacts of the index case:")
    verts, vals = eng.top_s(patient_zero, 5)
    bv, bs = brute_force_top_s(h, patient_zero, 5)
    assert np.array_equal(verts, bv) and np.array_equal(vals, bs)
    for p, v in zip(verts.tolist(), vals.tolist()):
        print(f"  person {p:4d}  MR = {v}")

    # -- cohort risk: set-to-set MR ---------------------------------------
    # "does the infected household threaten the care-home cohort?" is one
    # mr_set call — a batched label join, not |U| x |V| point queries
    household = [patient_zero] + [int(p) for p in order[:2]]
    cohort = [int(p) for p in order[-20:]]
    link = eng.mr_set(np.asarray(household), np.asarray(cohort))
    assert link == brute_force_mr_set(h, household, cohort)
    print(f"\nhousehold {household} -> {len(cohort)}-person cohort: "
          f"strongest cross link MR = {link}")

    hist = {int(t): int((risk[everyone != patient_zero] == t).sum())
            for t in np.unique(risk)}
    print("risk histogram {MR: count}:", hist)
    print("\nall workload answers verified against brute force")


if __name__ == "__main__":
    main()
