"""Epidemic case study (paper Exp-5 / Fig. 4): co-location hypergraph,
risk quantification by max-reachability, transmission-chain display.

  PYTHONPATH=src python examples/epidemic_case_study.py
"""
import numpy as np

from repro.core import (colocation_hypergraph, build_fast, minimize,
                        PaddedIndex, MSTOracle)


def transmission_chain(h, mst: MSTOracle, e_from: int, e_to: int):
    """Reconstruct the bottleneck walk between two co-location events via
    the maximum-spanning-forest path (maximin-path identity)."""
    parent = {e_from: None}
    stack = [e_from]
    while stack:
        x = stack.pop()
        if x == e_to:
            break
        for y, w in mst.adj[x]:
            if y not in parent:
                parent[y] = x
                stack.append(y)
    if e_to not in parent:
        return []
    path = [e_to]
    while parent[path[-1]] is not None:
        path.append(parent[path[-1]])
    return path[::-1]


def main():
    # 21-day window, one hyperedge per (place, day): people checked in
    h = colocation_hypergraph(n_people=400, n_places=12, n_days=21,
                              p_checkin=0.03, seed=3)
    print(f"co-location hypergraph: {h.n} people, {h.m} (place, day) groups")
    idx = minimize(build_fast(h))
    pidx = PaddedIndex(idx)

    patient_zero = int(np.argmax(h.vertex_degrees))
    everyone = np.arange(h.n)
    risk = np.asarray(pidx.mr(np.full(h.n, patient_zero), everyone))
    order = np.argsort(-risk)
    order = order[order != patient_zero]

    print(f"\nindex case: person {patient_zero} "
          f"({h.degree(patient_zero)} check-ins)")
    print("highest-risk contacts (MR = strength of potential "
          "transmission chain):")
    for p in order[:8]:
        print(f"  person {int(p):4d}  MR = {int(risk[p])}")
    hist = {int(s): int((risk[everyone != patient_zero] == s).sum())
            for s in np.unique(risk)}
    print("risk histogram {MR: count}:", hist)

    # show one concrete chain to the top contact
    top = int(order[0])
    mst = MSTOracle(h)
    best = (0, None, None)
    for eu in h.edges_of(patient_zero):
        for ev in h.edges_of(top):
            v = mst.edge_mr(int(eu), int(ev))
            if v > best[0]:
                best = (v, int(eu), int(ev))
    s, e_from, e_to = best
    chain = transmission_chain(h, mst, e_from, e_to)
    print(f"\nstrongest chain person {patient_zero} -> person {top} "
          f"(MR = {s}):")
    for a, b in zip(chain, chain[1:]):
        print(f"  group {a} -> group {b}: {h.overlap(a, b)} shared people")
    if len(chain) == 1:
        print(f"  single shared group {chain[0]} "
              f"({h.edge_size(chain[0])} people)")


if __name__ == "__main__":
    main()
