import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# ^ must precede jax import: this example simulates an 8-device slice.
"""Distributed reachability: 2-D block-sharded semiring closures under
jax.shard_map with explicit collectives (DESIGN.md §2).

  PYTHONPATH=src python examples/distributed_reachability.py
"""
import time

import jax
import numpy as np

from repro.api import build_engine, plan_backend, random_hypergraph
from repro.core import distinct_thresholds
from repro.core.distributed import (sharded_maxmin_closure,
                                    sharded_threshold_closure_mr,
                                    collective_bytes_of, sharded_maxmin_round,
                                    pad_for_mesh)
from repro.launch.mesh import make_test_mesh


def main():
    print("devices:", jax.device_count())
    mesh = make_test_mesh((2, 2), ("data", "model"))
    mesh3 = make_test_mesh((2, 2, 2), ("pod", "data", "model"))

    h = random_hypergraph(400, 600, min_size=2, max_size=6, seed=1)
    w = h.line_graph(np.int32).astype(np.float32)
    print(f"hypergraph: n={h.n} m={h.m}; line graph {w.shape}")

    # the facade's closure backend is the single-device reference: its W*
    # is exactly what the sharded closures must reproduce
    closure_eng = build_engine(h, backend="closure")
    dense = closure_eng.w_star.astype(np.float32)

    for sched in ("allgather", "ring"):
        t0 = time.perf_counter()
        got = np.asarray(sharded_maxmin_closure(w, mesh, schedule=sched))
        dt = time.perf_counter() - t0
        ok = np.array_equal(got, dense)
        print(f"maxmin closure [{sched:9s}] on 2x2 mesh: {dt:.2f}s  "
              f"correct={ok}")

    thr = distinct_thresholds(w)
    t0 = time.perf_counter()
    got = np.asarray(sharded_threshold_closure_mr(w, thr, mesh3))
    dt = time.perf_counter() - t0
    print(f"threshold closure (S={thr.size} over pod axis) on 2x2x2: "
          f"{dt:.2f}s  correct={np.array_equal(got, dense)}")

    # the "sharded" backend: the same closures behind the unified engine
    # API — computed once at build, served off a mesh-sharded snapshot
    rng = np.random.default_rng(0)
    us, vs = rng.integers(0, h.n, 256), rng.integers(0, h.n, 256)
    hl = build_engine(h, backend="hl-index")
    want = hl.mr_batch(us, vs).astype(np.int64)
    for sched in ("allgather", "ring"):
        eng = build_engine(h, backend="sharded", mesh=mesh, schedule=sched)
        ok = np.array_equal(np.asarray(eng.mr_batch(us, vs)).astype(np.int64),
                            want)
        print(f"sharded engine [{sched:9s}] == hl-index on 256 vertex "
              f"queries: {ok}")
    # the planner routes to "sharded" when a multi-device mesh is passed
    # and the closure exceeds the per-device budget
    print("auto planner with mesh + tight budget picks:",
          plan_backend(h, mesh=mesh, device_budget_bytes=0))

    # what goes over the wire per round
    from jax.sharding import NamedSharding, PartitionSpec as P
    wp = pad_for_mesh(w, mesh)
    rf = jax.jit(sharded_maxmin_round(mesh))
    lowered = rf.lower(jax.ShapeDtypeStruct(
        wp.shape, np.float32, sharding=NamedSharding(mesh, P("data", "model"))))
    info = collective_bytes_of(lowered.compile().as_text())
    print("per-round collective bytes (per device):", info["bytes"])


if __name__ == "__main__":
    main()
