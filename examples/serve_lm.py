"""Serving example: batched requests through prefill + greedy decode.

Demonstrates the serving substrate on a reduced qwen3-family model:
a queue of variable-length "requests" is padded into one batch, prefilled
in a single jit'd call, then decoded with the shared KV cache.

  PYTHONPATH=src python examples/serve_lm.py --requests 6 --gen 24
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import prefill_with_decode, greedy_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config("qwen3_1_7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # a batch of variable-length requests, left-padded to one shape
    lens = rng.integers(4, args.max_prompt + 1, args.requests)
    pad = int(lens.max())
    prompts = np.zeros((args.requests, pad), np.int32)
    for i, ln in enumerate(lens):
        prompts[i, pad - ln:] = rng.integers(1, cfg.vocab, ln)
    print(f"serving {args.requests} requests, prompt lens {lens.tolist()}, "
          f"padded to {pad}, generating {args.gen} tokens each")

    cache = model.init_cache(args.requests, pad + args.gen)
    t0 = time.perf_counter()
    last_logits, cache = jax.jit(
        lambda p, c, t: prefill_with_decode(model, p, c, t))(
            params, cache, jnp.asarray(prompts))
    jax.block_until_ready(last_logits)
    t_pre = time.perf_counter() - t0

    t0 = time.perf_counter()
    toks, _ = jax.jit(
        lambda p, c, lg: greedy_decode(model, p, c, lg, pad, args.gen))(
            params, cache, last_logits)
    toks = np.asarray(toks)
    t_dec = time.perf_counter() - t0

    thru = args.requests * args.gen / t_dec
    print(f"prefill {t_pre*1e3:.0f} ms   decode {t_dec*1e3:.0f} ms "
          f"({thru:.0f} tok/s incl. compile)")
    for i in range(min(3, args.requests)):
        print(f"  request {i}: ...{prompts[i, -4:].tolist()} -> "
              f"{toks[i][:8].tolist()}...")


if __name__ == "__main__":
    main()
