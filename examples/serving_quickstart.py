"""Serving quickstart: requests in, futures out — the admission loop
coalesces whatever is pending into fused padded device batches, and a
scoped update swaps the resident snapshot between micro-batches,
re-deriving only the touched label rows.

Also demonstrates the multi-tenant surface: weighted-fair scheduling
across tenants, strict priority bands, deadlines, streaming delivery,
and replicated serving (`ServiceConfig(replicas=N)`).

  PYTHONPATH=src python examples/serving_quickstart.py
"""
import time

import numpy as np

from repro.api import (DeadlineExceeded, MRRequest, ServiceConfig,
                       SReachRequest, TenantSpec, planted_chain_hypergraph,
                       random_hypergraph, serve)


def main():
    # --- submit typed requests, read futures ------------------------------
    h = random_hypergraph(2000, 512, seed=0)
    with serve(h, backend="sharded") as svc:        # background admission loop
        f_mr = svc.mr(4, 8)                         # Future[int]
        f_sr = svc.submit(SReachRequest(4, 8, s=2))  # Future[bool]
        print(f"MR(4, 8) = {f_mr.result()}   4 ~2~> 8 ? {f_sr.result()}")

        # a burst of mixed requests (MR + s-reach, mixed s values)
        # coalesces into a handful of fused power-of-two batches
        rng = np.random.default_rng(0)
        reqs = [MRRequest(int(u), int(v)) if rng.random() < 0.5
                else SReachRequest(int(u), int(v), int(rng.integers(1, 5)))
                for u, v in zip(rng.integers(0, h.n, 10_000),
                                rng.integers(0, h.n, 10_000))]
        futs = svc.submit_many(reqs)
        _ = [f.result() for f in futs]              # warm the bucket shapes
        t0 = time.perf_counter()
        futs = svc.submit_many(reqs)
        answers = [f.result() for f in futs]
        dt = time.perf_counter() - t0
        st = svc.stats()
        print(f"10,000 mixed requests in {dt*1e3:.0f} ms "
              f"({len(answers)/dt:.0f} q/s) across "
              f"{len(st.bucket_histogram)} bucket shapes "
              f"{sorted(st.bucket_histogram)}; max MR = {max(answers)}")

    # --- live updates: snapshot swapped between micro-batches -------------
    hc = planted_chain_hypergraph(16, 20, overlap=3, extra_size=2, seed=0)
    svc = serve(hc, backend="hl-index", start=False)   # synchronous mode
    svc.mr(0, 1)
    svc.drain()                                     # resident snapshot up
    anchor = [int(v) for v in hc.edge(0)[:2]]
    svc.update(inserts=[anchor + [hc.n]])           # scoped maintenance
    f = svc.mr(anchor[0], hc.n)
    svc.drain()                                     # swap + refresh here
    st = svc.stats()
    print(f"after a scoped update on a 16-component graph: "
          f"MR(anchor, new vertex) = {f.result()}; snapshot refresh "
          f"re-derived {svc.engine.last_snapshot_refresh_rows}/{svc.engine.h.n} "
          f"label rows ({st.snapshot_refreshes} refreshes total)")

    # --- multi-tenant: weighted-fair shares, priorities, deadlines --------
    h2 = random_hypergraph(500, 160, seed=1)
    cfg = ServiceConfig(max_batch=64,
                        tenants=(TenantSpec("analytics", weight=1.0),
                                 TenantSpec("dashboard", weight=3.0)))
    svc = serve(h2, "hl-index", config=cfg, start=False)
    rng = np.random.default_rng(1)
    for tenant in ("analytics", "dashboard"):
        svc.submit_many([
            MRRequest(int(u), int(v), tenant=tenant)
            for u, v in zip(rng.integers(0, h2.n, 200),
                            rng.integers(0, h2.n, 200))])
    svc.drain(max_batches=1)                    # one 64-slot micro-batch
    st = svc.stats()
    print(f"one contended batch, weights 1:3 -> shares "
          f"{dict(sorted(st.tenant_answered.items()))}")

    # an expired deadline fails fast with a typed error, never batched
    doomed = svc.submit(MRRequest(0, 1, priority="interactive",
                                  deadline_ms=0.5))
    time.sleep(0.002)
    svc.drain()
    try:
        doomed.result()
    except DeadlineExceeded as err:
        print(f"deadline path: {err}")
    svc.close()

    # --- replicated serving: N mesh-resident copies, one writer ----------
    grp = serve(h2, "hl-index",
                config=ServiceConfig(replicas=2), start=False)
    for req, fut in grp.submit_stream(
            [MRRequest(int(u), int(v))
             for u, v in zip(rng.integers(0, h2.n, 32),
                             rng.integers(0, h2.n, 32))]):
        pass                                    # answers in completion order
    print(f"replica group: {[r['batches'] for r in grp.replica_stats()]} "
          f"batches served round-robin across 2 replicas")
    grp.close()


if __name__ == "__main__":
    main()
