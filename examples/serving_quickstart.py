"""Serving quickstart: requests in, futures out — the admission loop
coalesces whatever is pending into fused padded device batches, and a
scoped update swaps the resident snapshot between micro-batches,
re-deriving only the touched label rows.

  PYTHONPATH=src python examples/serving_quickstart.py
"""
import time

import numpy as np

from repro.api import (MRRequest, SReachRequest, planted_chain_hypergraph,
                       random_hypergraph, serve)


def main():
    # --- submit typed requests, read futures ------------------------------
    h = random_hypergraph(2000, 512, seed=0)
    with serve(h, backend="sharded") as svc:        # background admission loop
        f_mr = svc.mr(4, 8)                         # Future[int]
        f_sr = svc.submit(SReachRequest(4, 8, s=2))  # Future[bool]
        print(f"MR(4, 8) = {f_mr.result()}   4 ~2~> 8 ? {f_sr.result()}")

        # a burst of mixed requests (MR + s-reach, mixed s values)
        # coalesces into a handful of fused power-of-two batches
        rng = np.random.default_rng(0)
        reqs = [MRRequest(int(u), int(v)) if rng.random() < 0.5
                else SReachRequest(int(u), int(v), int(rng.integers(1, 5)))
                for u, v in zip(rng.integers(0, h.n, 10_000),
                                rng.integers(0, h.n, 10_000))]
        futs = svc.submit_many(reqs)
        _ = [f.result() for f in futs]              # warm the bucket shapes
        t0 = time.perf_counter()
        futs = svc.submit_many(reqs)
        answers = [f.result() for f in futs]
        dt = time.perf_counter() - t0
        st = svc.stats()
        print(f"10,000 mixed requests in {dt*1e3:.0f} ms "
              f"({len(answers)/dt:.0f} q/s) across "
              f"{len(st.bucket_histogram)} bucket shapes "
              f"{sorted(st.bucket_histogram)}; max MR = {max(answers)}")

    # --- live updates: snapshot swapped between micro-batches -------------
    hc = planted_chain_hypergraph(16, 20, overlap=3, extra_size=2, seed=0)
    svc = serve(hc, backend="hl-index", start=False)   # synchronous mode
    svc.mr(0, 1)
    svc.drain()                                     # resident snapshot up
    anchor = [int(v) for v in hc.edge(0)[:2]]
    svc.update(inserts=[anchor + [hc.n]])           # scoped maintenance
    f = svc.mr(anchor[0], hc.n)
    svc.drain()                                     # swap + refresh here
    st = svc.stats()
    print(f"after a scoped update on a 16-component graph: "
          f"MR(anchor, new vertex) = {f.result()}; snapshot refresh "
          f"re-derived {svc.engine.last_snapshot_refresh_rows}/{svc.engine.h.n} "
          f"label rows ({st.snapshot_refreshes} refreshes total)")


if __name__ == "__main__":
    main()
