"""Quickstart: one API surface for every reachability backend.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.api import (build_engine, available_backends, plan_backend,
                       paper_figure1, random_hypergraph, compact)


def main():
    # --- the paper's running example (Figure 1) ---------------------------
    h = paper_figure1()
    eng = build_engine(h, backend="hl-index")
    print("Figure-1 hypergraph:", h.stats())
    print("MR(v5, v9)  =", eng.mr(4, 8), " (paper Example 1: 2)")
    print("MR(v1, v12) =", eng.mr(0, 11), "(paper Example 4: 2)")
    print("v1 ~2~> v10 ?", eng.s_reach(0, 9, 2), "(paper Example 3: True)")

    # --- a bigger graph: build once, serve through the same surface -------
    h = random_hypergraph(3000, 4500, min_size=2, max_size=8, seed=0)
    h, _ = compact(h)
    t0 = time.perf_counter()
    eng = build_engine(h, backend="hl-index")
    t_build = time.perf_counter() - t0
    print(f"\nn={h.n} m={h.m}: hl-index build {t_build:.2f}s "
          f"({eng.nbytes()} bytes); planner would pick "
          f"{plan_backend(h, batch_hint=10_000)!r} for this shape")

    rng = np.random.default_rng(0)
    us, vs = rng.integers(0, h.n, 10000), rng.integers(0, h.n, 10000)

    # online (index-free) vs hl-index on a few queries — same protocol
    online = build_engine(h, backend="online")
    t0 = time.perf_counter()
    online_ans = [online.mr(int(u), int(v)) for u, v in zip(us[:20], vs[:20])]
    t_online = (time.perf_counter() - t0) / 20
    t0 = time.perf_counter()
    idx_ans = [eng.mr(int(u), int(v)) for u, v in zip(us[:20], vs[:20])]
    t_idx = (time.perf_counter() - t0) / 20
    assert online_ans == idx_ans
    print(f"per-query: online {t_online*1e3:.2f} ms  vs  "
          f"hl-index {t_idx*1e6:.1f} us  ({t_online/t_idx:.0f}x)")

    # the device snapshot: 10k queries in one fused XLA program
    snap = eng.snapshot()
    ans = np.asarray(snap.mr(us, vs))     # includes compile
    t0 = time.perf_counter()
    ans = np.asarray(snap.mr(us, vs))
    t_batch = time.perf_counter() - t0
    print(f"device snapshot: 10,000 queries in {t_batch*1e3:.1f} ms "
          f"({t_batch/len(us)*1e9:.0f} ns/query); "
          f"max MR in batch = {ans.max()}")
    print("registered backends:", ", ".join(available_backends()))

    # --- live updates: scoped maintenance through the same engine ---------
    # construction reruns only on the affected line-graph component, so
    # on a multi-component graph updates cost ~1/C of a rebuild
    # (benchmarks/bench_maintenance.py tracks this as BENCH_maintenance.json)
    from repro.api import planted_chain_hypergraph
    hc = planted_chain_hypergraph(16, 40, overlap=3, extra_size=2, seed=0)
    t0 = time.perf_counter()
    ec = build_engine(hc, backend="hl-index")
    t_build_c = time.perf_counter() - t0
    snap_c = ec.snapshot()
    anchor = [int(v) for v in hc.edge(0)[:2]]
    t0 = time.perf_counter()
    ec.update(inserts=[anchor + [hc.n]], deletes=[hc.m - 1])
    t_upd = time.perf_counter() - t0
    print(f"\nupdate on {hc.m}-edge, 16-component graph "
          f"(1 insert + 1 delete): {t_upd*1e3:.1f} ms scoped vs "
          f"{t_build_c*1e3:.0f} ms full build "
          f"({t_build_c/t_upd:.0f}x); engine version -> {ec.version} "
          f"(old snapshots are stale: {snap_c.version} != {ec.version})")
    assert ec.snapshot() is not snap_c    # re-derived, serves new answers


if __name__ == "__main__":
    main()
