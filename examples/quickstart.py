"""Quickstart: build an HL-index, answer max-reachability queries.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import (paper_figure1, random_hypergraph, compact,
                        build_fast, minimize, mr_query, s_reach_query,
                        mr_online, PaddedIndex)


def main():
    # --- the paper's running example (Figure 1) ---------------------------
    h = paper_figure1()
    idx = build_fast(h)
    print("Figure-1 hypergraph:", h.stats())
    print("MR(v5, v9)  =", mr_query(idx, 4, 8), " (paper Example 1: 2)")
    print("MR(v1, v12) =", mr_query(idx, 0, 11), "(paper Example 4: 2)")
    print("v1 ~2~> v10 ?", s_reach_query(idx, 0, 9, 2), "(paper Example 3: True)")

    # --- a bigger graph: construct, minimize, serve -----------------------
    h = random_hypergraph(3000, 4500, min_size=2, max_size=8, seed=0)
    h, _ = compact(h)
    t0 = time.perf_counter()
    full = build_fast(h)
    t_build = time.perf_counter() - t0
    t0 = time.perf_counter()
    mini = minimize(full)
    t_min = time.perf_counter() - t0
    print(f"\nn={h.n} m={h.m}: Construct {t_build:.2f}s "
          f"({full.num_labels} labels), +minimize {t_min:.2f}s "
          f"({mini.num_labels} labels)")

    rng = np.random.default_rng(0)
    us, vs = rng.integers(0, h.n, 10000), rng.integers(0, h.n, 10000)

    # online vs index on a few queries
    t0 = time.perf_counter()
    online_ans = [mr_online(h, int(u), int(v)) for u, v in zip(us[:20], vs[:20])]
    t_online = (time.perf_counter() - t0) / 20
    t0 = time.perf_counter()
    idx_ans = [mr_query(mini, int(u), int(v)) for u, v in zip(us[:20], vs[:20])]
    t_idx = (time.perf_counter() - t0) / 20
    assert online_ans == idx_ans
    print(f"per-query: online {t_online*1e3:.2f} ms  vs  "
          f"Min-reach {t_idx*1e6:.1f} us  ({t_online/t_idx:.0f}x)")

    # the batched device engine: 10k queries in one XLA program
    pidx = PaddedIndex(mini)
    import jax
    f = jax.jit(pidx.mr)
    ans = np.asarray(f(us, vs))           # includes compile
    t0 = time.perf_counter()
    ans = np.asarray(f(us, vs))
    t_batch = time.perf_counter() - t0
    print(f"batched engine: 10,000 queries in {t_batch*1e3:.1f} ms "
          f"({t_batch/len(us)*1e9:.0f} ns/query); "
          f"max MR in batch = {ans.max()}")


if __name__ == "__main__":
    main()
