"""End-to-end training driver: data pipeline (with the hypergraph dedup
stage) -> supervised train loop -> checkpoints -> resume.

Default config is CPU-sized so the example finishes in minutes; pass
``--params 100m`` for the ~100M-parameter configuration (same code path,
hours on CPU / minutes on a TPU slice).

  PYTHONPATH=src python examples/train_lm.py --steps 120
"""
import argparse
import dataclasses

import numpy as np

from repro.models.common import ArchConfig
from repro.train import dedup_corpus
from repro.launch.train import run_training


def make_config(size: str) -> ArchConfig:
    if size == "100m":
        return ArchConfig(name="demo-100m", family="dense", n_layers=10,
                          d_model=640, n_heads=10, n_kv_heads=5, d_ff=2560,
                          vocab=32000, attn_chunk=0, microbatch=2,
                          scan_layers=True, remat=False)
    return ArchConfig(name="demo-5m", family="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                      vocab=2048, attn_chunk=0, microbatch=2,
                      scan_layers=True, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--params", choices=["5m", "100m"], default="5m")
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = make_config(args.params)
    print(f"config: {cfg.name}  ~{cfg.n_params()/1e6:.1f}M params")

    # --- data-pipeline dedup stage (the paper's engine in production) ----
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, cfg.vocab, 96) for _ in range(40)]
    docs += [d.copy() for d in docs[:10]]          # inject near-dups
    for d in docs[40:]:
        d[:4] = rng.integers(0, cfg.vocab, 4)
    kept, comp = dedup_corpus(docs, s=8, k=4)
    print(f"dedup stage: {len(docs)} docs -> {len(kept)} kept "
          f"({len(docs) - len(kept)} s-reachable near-dups dropped)")

    # --- train with checkpoint/resume ------------------------------------
    step, params, opt, log = run_training(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 3, 10))
    first = np.mean([m["loss"] for m in log[:5]]) if log else float("nan")
    last = np.mean([m["loss"] for m in log[-5:]]) if log else float("nan")
    print(f"loss: first-5 {first:.3f} -> last-5 {last:.3f}")
    print(f"re-run this command to resume from step {step} "
          f"(checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
