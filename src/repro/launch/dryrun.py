import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the production mesh (16×16 single-pod and
2×16×16 multi-pod), constructs ShapeDtypeStruct stand-ins for params /
optimizer state / batch / cache (no allocation), lowers the appropriate
step function (train_step / prefill / serve_step), compiles it, and
records:

  * memory_analysis()  — bytes per device (proves it fits)
  * cost_analysis()    — FLOPs / bytes for §Roofline
  * collective bytes   — parsed from the compiled HLO (§Roofline third term)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, get_config
from repro.models import build_model
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_applicable
from repro.distributed_lm.sharding import (input_structs, shard_params,
                                           cache_structs, named, batch_axes)
from repro.train.optimizer import AdamConfig, adam_init, opt_state_specs
from repro.train.train_step import make_train_step
from repro.serve.serve_step import make_serve_step, make_prefill_step
from repro.core.distributed import collective_bytes_of
from repro.launch.hlo_analysis import loop_aware_collectives
from jax.sharding import PartitionSpec as P


def _struct_tree_with_specs(shapes, specs, mesh):
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                               sharding=named(mesh, spec)),
        shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               keep_hlo: bool = False, overrides: Optional[Dict] = None,
               mesh_shape: Optional[tuple] = None) -> Dict[str, Any]:
    """Lower + compile one cell; returns the §Dry-run record.
    ``overrides`` applies dataclasses.replace on the config and
    ``mesh_shape`` re-factors the 256 chips into (data, model) — the two
    §Perf hillclimb levers (e.g. {"gqa_repeat": True}, (32, 8))."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    rec: Dict[str, Any] = dict(arch=arch, shape=shape_name,
                               multi_pod=multi_pod)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    if mesh_shape is not None:
        from repro.compat import make_mesh
        axes = (("pod", "data", "model") if len(mesh_shape) == 3
                else ("data", "model"))
        mesh = make_mesh(tuple(mesh_shape), axes)
        rec["mesh_shape"] = list(mesh_shape)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    t0 = time.time()

    from repro.models.layers import sharding_mesh
    with mesh, sharding_mesh(mesh):
        params = shard_params(model, mesh)
        if shape.kind == "train":
            opt_cfg = AdamConfig(use_8bit=cfg.opt_8bit)
            opt_shapes = jax.eval_shape(lambda p: adam_init(p, opt_cfg), params)
            ospecs = opt_state_specs(model.param_specs(), params, opt_cfg,
                                     data_size=mesh.shape["data"],
                                     zero1=cfg.zero1)
            opt_state = _struct_tree_with_specs(opt_shapes, ospecs, mesh)
            batch = input_structs(cfg, mesh, shape.global_batch, shape.seq_len)
            step_fn = make_train_step(model, cfg, opt_cfg)
            lowered = jax.jit(step_fn).lower(params, opt_state, batch)
        elif shape.kind == "prefill":
            batch = input_structs(cfg, mesh, shape.global_batch, shape.seq_len)
            step_fn = make_prefill_step(model, cfg)
            lowered = jax.jit(step_fn).lower(params, batch)
        else:  # decode
            long_ctx = shape.name.startswith("long")
            cache = cache_structs(model, cfg, mesh, shape.global_batch,
                                  shape.seq_len, long_ctx)
            ba = P(batch_axes(mesh)) if not long_ctx else P()
            tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                          sharding=named(mesh, ba))
            pos = jax.ShapeDtypeStruct((), jnp.int32,
                                       sharding=named(mesh, P()))
            step_fn = make_serve_step(model, cfg)
            lowered = jax.jit(step_fn).lower(params, cache, tokens, pos)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
    except Exception:
        mem = None
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
    except Exception:
        cost = {}
    hlo = compiled.as_text()
    coll = collective_bytes_of(hlo)          # flat (bodies counted once)
    coll_exec = loop_aware_collectives(hlo)  # × while trip counts
    n_dev = int(np.prod(list(mesh.shape.values())))

    def _mem_field(name):
        return int(getattr(mem, name, 0) or 0) if mem is not None else 0

    rec.update(
        status="ok",
        n_devices=n_dev,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        flops=float(cost.get("flops", 0.0)) if cost else 0.0,
        bytes_accessed=float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        collective=coll,
        collective_executed={k: coll_exec[k] for k in
                             ("bytes", "counts", "total_bytes")},
        loops=coll_exec.get("loops", []),
        memory=dict(
            argument_bytes=_mem_field("argument_size_in_bytes"),
            output_bytes=_mem_field("output_size_in_bytes"),
            temp_bytes=_mem_field("temp_size_in_bytes"),
            generated_code_bytes=_mem_field("generated_code_size_in_bytes"),
        ),
        model_params=cfg.n_params(),
        model_params_active=cfg.n_active_params(),
    )
    if keep_hlo:
        rec["hlo_len"] = len(hlo)
        rec["_hlo"] = hlo
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", type=str, default=None,
                    help="directory for per-cell JSON records")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose JSON record already exists")
    ap.add_argument("--set", type=str, default=None, dest="overrides",
                    help='JSON config overrides, e.g. \'{"gqa_repeat":true}\'')
    ap.add_argument("--tag", type=str, default="",
                    help="suffix for output record filenames")
    ap.add_argument("--mesh-shape", type=str, default=None,
                    help="alternative chip factorization, e.g. 32,8")
    args = ap.parse_args()
    overrides = json.loads(args.overrides) if args.overrides else None
    mesh_shape = (tuple(int(x) for x in args.mesh_shape.split(","))
                  if args.mesh_shape else None)

    cells = []
    archs = list(ALIASES.keys()) if args.all else [args.arch]
    shapes = list(SHAPES.keys()) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        tag = f"{a} × {s} × {'2x16x16' if mp else '16x16'}"
        suffix = f"__{args.tag}" if args.tag else ""
        fn = f"{ALIASES.get(a, a)}__{s}__{'mp' if mp else 'sp'}{suffix}.json"
        if args.skip_existing and args.out and \
                os.path.exists(os.path.join(args.out, fn)):
            print(f"[cached ] {tag}")
            continue
        try:
            rec = lower_cell(a, s, multi_pod=mp, overrides=overrides,
                             mesh_shape=mesh_shape)
        except Exception as e:
            failures += 1
            rec = dict(arch=a, shape=s, multi_pod=mp, status="error",
                       error=f"{type(e).__name__}: {e}",
                       tb=traceback.format_exc()[-2000:])
        print(f"[{rec['status']:7s}] {tag} "
              + (f"flops={rec.get('flops', 0):.3e} "
                 f"coll={rec.get('collective', {}).get('total_bytes', 0):.3e} "
                 f"compile={rec.get('compile_s', 0)}s"
                 if rec["status"] == "ok" else rec.get("reason",
                                                       rec.get("error", ""))))
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            rec.pop("_hlo", None)
            with open(os.path.join(args.out, fn), "w") as f:
                json.dump(rec, f, indent=1)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
