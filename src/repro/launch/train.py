"""Training launcher: real training on the current host's devices (tests /
the ~100M example) or, with ``--dryrun``, the production-mesh compile.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.train import (AdamConfig, adam_init, make_train_step,
                         SyntheticStream, SupervisorConfig, TrainSupervisor)


def run_training(cfg, *, steps: int, batch: int, seq: int, ckpt_dir: str,
                 ckpt_every: int = 50, seed: int = 0, lr: float = 3e-4,
                 mesh: Mesh | None = None, log_every: int = 10):
    """Host-scale training loop with checkpoint/restart via the
    supervisor.  Returns the supervisor metrics log."""
    model = build_model(cfg)
    opt_cfg = AdamConfig(lr=lr, total_steps=steps, warmup_steps=max(steps // 20, 1),
                         use_8bit=cfg.opt_8bit)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = adam_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model, cfg, opt_cfg), donate_argnums=(0, 1))
    data = iter(SyntheticStream(cfg, batch, seq, seed=seed))

    def to_dev(b):
        return jax.tree.map(jnp.asarray, b)

    data_dev = map(to_dev, data)
    sup = TrainSupervisor(
        SupervisorConfig(ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                         max_steps=steps),
        step_fn, data_dev)
    start, params, opt_state = sup.resume_or_init(params, opt_state)
    if start:
        print(f"[resume] from step {start}")
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
    step, params, opt_state, log = sup.run(params, opt_state,
                                           start_step=start)
    for m in log:
        if m["step"] % log_every == 0 or m["step"] == step:
            print(f"step {m['step']:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                  f"({m['step_time_s']*1e3:.0f} ms)")
    if sup.straggler_events:
        print(f"[straggler] slow steps at {sup.straggler_events}")
    return step, params, opt_state, log


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, microbatch=min(cfg.microbatch, args.batch))
    run_training(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                 ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                 lr=args.lr)


if __name__ == "__main__":
    main()
