"""The assigned input-shape cells and per-arch applicability.

LM transformer shapes are seq_len × global_batch; ``decode_*``/``long_*``
lower serve_step (one new token against a seq_len KV cache), NOT
train_step.  ``long_500k`` needs sub-quadratic attention: it runs only
for the SSM/hybrid archs; pure full-attention archs skip it (recorded —
see DESIGN.md §4)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.models.common import ArchConfig

__all__ = ["ShapeCell", "SHAPES", "cell_applicable", "all_cells"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# families with sub-quadratic sequence mixing (run long_500k)
_SUBQUADRATIC = {"ssm", "hybrid"}


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in _SUBQUADRATIC:
        return False, ("skip: pure full-attention arch — 500k-token decode "
                       "requires sub-quadratic mixing (DESIGN.md §4)")
    return True, ""


def all_cells(arch_ids: List[str], get_config) -> List[Tuple[str, str, bool, str]]:
    """[(arch, shape, applicable, reason)] for the full 40-cell table."""
    out = []
    for a in arch_ids:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = cell_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
