"""Loop-aware HLO accounting.

XLA's ``cost_analysis``/flat text parsing counts a ``while`` body ONCE,
but lax.scan-driven programs (layer stacks, microbatch accumulation,
kv-chunked attention) execute bodies trip-count times.  This module
parses the compiled HLO into computations, recovers while trip counts
from the loop-condition constants, propagates multipliers along the call
graph (body/condition/to_apply/calls), and reports *executed* collective
bytes — the number §Roofline's collective term needs.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["parse_computations", "loop_aware_collectives"]

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# `%name (args...) -> ret {`   or   `ENTRY %name (...) -> ... {`
# (args may contain nested parens — match loosely on name + arrow + brace)
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_LINE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_CALLREF = re.compile(
    r"(?:body|condition|to_apply|calls)=\{?%?([\w.\-]+)\}?")
_WHILE = re.compile(r"\bwhile\(")
_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")
# XLA annotates loops with the statically-known trip count
_KNOWN_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(tok: str) -> int:
    total = 0
    for d, dims in _SHAPE.findall(tok):
        if d not in _DTYPE_BYTES:
            continue
        n = int(np.prod([int(x) for x in dims.split(",") if x])) if dims else 1
        total += n * _DTYPE_BYTES[d]
    return total


def parse_computations(hlo: str) -> Dict[str, Dict]:
    """-> {comp_name: {lines, coll_bytes, coll_counts, entry}}"""
    comps: Dict[str, Dict] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        hdr = _COMP_HDR.match(stripped)
        if hdr and (line.startswith("ENTRY") or not line.startswith(" ")):
            cur = hdr.group(1)
            comps[cur] = dict(lines=[], entry=line.startswith("ENTRY"))
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur]["lines"].append(stripped)
    for name, c in comps.items():
        per_op = {k: 0 for k in _COLL_OPS}
        counts = {k: 0 for k in _COLL_OPS}
        for ln in c["lines"]:
            m = _COLL_LINE.search(ln)
            if m:
                per_op[m.group(2)] += _shape_bytes(m.group(1))
                counts[m.group(2)] += 1
        c["coll_bytes"] = per_op
        c["coll_counts"] = counts
    return comps


def _trip_count(cond_comp: Dict) -> int:
    """Heuristic: the loop bound is the max s32 scalar constant compared in
    the condition computation (lax.scan lowers to `iter < C`)."""
    best = 1
    for ln in cond_comp["lines"]:
        for c in _CONST.findall(ln):
            best = max(best, int(c))
    return best


def loop_aware_collectives(hlo: str) -> Dict:
    """Executed collective bytes per op kind, multiplying while bodies by
    their trip counts (nested loops compose multiplicatively)."""
    comps = parse_computations(hlo)
    entry = next((n for n, c in comps.items() if c["entry"]), None)
    if entry is None:
        return {"bytes": {k: 0 for k in _COLL_OPS}, "total_bytes": 0,
                "loops": []}

    mult: Dict[str, float] = {}
    loops: List[Tuple[str, int]] = []

    def visit(name: str, m: float, depth: int = 0):
        if name not in comps or depth > 50:
            return
        mult[name] = mult.get(name, 0.0) + m
        c = comps[name]
        for ln in c["lines"]:
            refs = _CALLREF.findall(ln)
            if not refs:
                continue
            is_while = bool(_WHILE.search(ln))
            trip = 1
            if is_while:
                cond_name = None
                body_name = None
                for kindm in re.finditer(
                        r"(body|condition)=\{?%?([\w.\-]+)\}?", ln):
                    if kindm.group(1) == "condition":
                        cond_name = kindm.group(2)
                    else:
                        body_name = kindm.group(2)
                kt = _KNOWN_TRIP.search(ln)
                if kt:
                    trip = int(kt.group(1))
                elif cond_name and cond_name in comps:
                    trip = _trip_count(comps[cond_name])
                if body_name:
                    loops.append((body_name, trip))
                    visit(body_name, m * trip, depth + 1)
                if cond_name:
                    visit(cond_name, m * (trip + 1), depth + 1)
            else:
                for r in refs:
                    visit(r, m, depth + 1)

    visit(entry, 1.0)
    total = {k: 0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    for name, m in mult.items():
        c = comps.get(name)
        if not c:
            continue
        for k in _COLL_OPS:
            total[k] += int(c["coll_bytes"][k] * m)
            counts[k] += int(c["coll_counts"][k] * m)
    return {"bytes": total, "counts": counts,
            "total_bytes": int(sum(total.values())),
            "loops": sorted(set(loops))}
