"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init and
only then calls these.
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.compat import make_mesh as _make_mesh

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16×16 = 256 chips, axes (data, model).
    Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — the ``pod``
    axis carries only DP gradient all-reduce (training) or the threshold
    batch (reachability engine) across the inter-pod DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for CPU tests (requires the host-device-count flag)."""
    return _make_mesh(shape, axes)
