import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import (jax locks device count on first init).
"""Paper-core dry-run: the reachability closure at production scale on the
production mesh — the workload that IS the paper's technique.

Cells (one squaring round each; a full closure is ⌈log2 m⌉ rounds):
  * maxmin-closure     m=65536, f32, 2-D block over (data, model) —
    (max,min) semiring, VPU-bound on TPU.
  * threshold-closure  m=65536 × S=32 thresholds, f32 boolean matmul —
    the MXU reformulation; S shards over `pod` on the multi-pod mesh.
  * bisection ladder   log2(S)=5 effective thresholds — the beyond-paper
    optimization (see EXPERIMENTS.md §Perf).

Records the same fields as the LM dry-run so §Roofline reads both.

  PYTHONPATH=src python -m repro.launch.closure_dryrun --out results/dryrun_core
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import loop_aware_collectives
from repro.core.distributed import sharded_maxmin_round

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


VPU_OPS = 2.0e12        # ~f32 vector ops/s/chip (the maxmin form can't
                        # use the MXU — see DESIGN.md §2)


def lower_closure_cell(kind: str, m: int = 65536, s_thresholds: int = 32,
                       *, multi_pod: bool = False, schedule: str = "allgather",
                       dtype: str = "float32") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec = dict(kind=kind, m=m, S=s_thresholds, multi_pod=multi_pod,
               schedule=schedule, dtype=dtype, n_devices=n_dev)
    axes = ("data", "model")
    dt = jnp.dtype(dtype)
    dbytes = dt.itemsize
    t0 = time.time()
    with mesh:
        if kind == "maxmin":
            spec = P(*axes)
            fn = jax.jit(sharded_maxmin_round(mesh, schedule=schedule,
                                              axes=axes))
            arg = jax.ShapeDtypeStruct((m, m), dt,
                                       sharding=NamedSharding(mesh, spec))
            lowered = fn.lower(arg)
            # one round of maxmin: 2·m³ compare/select ops — VPU rate
            flops = 2.0 * m ** 3
            hbm = 3 * m * m * dbytes
            peak = VPU_OPS
        else:
            s_eff = (int(np.ceil(np.log2(s_thresholds))) + 1
                     if kind == "bisection" else s_thresholds)
            batch_spec = (P("pod", *axes) if multi_pod else P(None, *axes))

            def round_body(blk):
                row = jax.lax.all_gather(blk, axes[1], axis=2, tiled=True)
                col = jax.lax.all_gather(blk, axes[0], axis=1, tiled=True)
                prod = jnp.einsum("sij,sjk->sik", row, col,
                                  preferred_element_type=jnp.float32)
                return (prod > 0).astype(blk.dtype)

            fn = jax.jit(shard_map(round_body, mesh=mesh,
                                       in_specs=batch_spec,
                                       out_specs=batch_spec))
            arg = jax.ShapeDtypeStruct((s_eff, m, m), dt,
                                       sharding=NamedSharding(mesh, batch_spec))
            lowered = fn.lower(arg)
            flops = 2.0 * s_eff * m ** 3          # MXU MACs
            hbm = 3 * s_eff * m * m * dbytes
            peak = PEAK_FLOPS if dtype == "bfloat16" else PEAK_FLOPS / 2
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    hlo = compiled.as_text()
    coll = loop_aware_collectives(hlo)
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
    except Exception:
        cost = {}
    t_comp = flops / (n_dev * peak)
    t_mem = hbm / (n_dev * HBM_BW)
    t_coll = coll["total_bytes"] / LINK_BW
    terms = dict(compute=t_comp, memory=t_mem, collective=t_coll)
    rec.update(status="ok", lower_s=round(t_lower, 2),
               compile_s=round(t_compile, 2),
               flops_analytic=flops, hbm_bytes=hbm,
               hlo_flops_per_dev=float(cost.get("flops", 0.0)),
               collective_executed={k: coll[k] for k in
                                    ("bytes", "counts", "total_bytes")},
               t_compute_s=t_comp, t_memory_s=t_mem, t_collective_s=t_coll,
               dominant=max(terms, key=terms.get),
               mfu_bound=(t_comp / max(terms.values())) if kind != "maxmin"
               else 0.0)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun_core")
    ap.add_argument("--m", type=int, default=65536)
    ap.add_argument("--S", type=int, default=32)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    cells = [("maxmin", False, "allgather", "float32"),
             ("maxmin", False, "ring", "float32"),
             ("threshold", False, "allgather", "float32"),
             ("threshold", True, "allgather", "float32"),
             ("threshold", False, "allgather", "bfloat16"),
             ("bisection", False, "allgather", "float32"),
             ("bisection", False, "allgather", "bfloat16"),
             ("bisection", True, "allgather", "bfloat16")]
    for kind, mp, sched, dtype in cells:
        tag = f"{kind}__{'mp' if mp else 'sp'}__{sched}__{dtype}"
        try:
            rec = lower_closure_cell(kind, args.m, args.S, multi_pod=mp,
                                     schedule=sched, dtype=dtype)
        except Exception as e:
            rec = dict(kind=kind, multi_pod=mp, schedule=sched, dtype=dtype,
                       status="error", error=f"{type(e).__name__}: {e}",
                       tb=traceback.format_exc()[-1500:])
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] == "ok":
            print(f"[ok   ] {tag:34s} comp={rec['t_compute_s']:.4f}s "
                  f"mem={rec['t_memory_s']:.4f}s "
                  f"coll={rec['t_collective_s']:.4f}s "
                  f"dominant={rec['dominant']} compile={rec['compile_s']}s")
        else:
            print(f"[error] {tag}: {rec['error']}")


if __name__ == "__main__":
    main()
