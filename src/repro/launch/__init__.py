"""Launchers: mesh construction, dry-run, training, serving.

NOTE: ``dryrun`` must be imported/run as the process entry point (it sets
XLA_FLAGS before jax initializes); do not import it from an
already-initialized process and expect 512 devices.
"""
from .mesh import make_production_mesh, make_test_mesh
from .shapes import SHAPES, ShapeCell, cell_applicable, all_cells

__all__ = ["make_production_mesh", "make_test_mesh", "SHAPES", "ShapeCell",
           "cell_applicable", "all_cells"]
