"""Serving launcher: batched prefill + greedy decode on the host devices.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.serve import prefill_with_decode, greedy_decode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (args.batch, args.prompt_len)), jnp.int32)
    cache = model.init_cache(args.batch, args.prompt_len + args.gen)
    if cfg.family == "encdec":
        frames = jnp.asarray(rng.normal(
            size=(args.batch, cfg.enc_frames, cfg.d_model)).astype(np.float32))
        cache = model.prefill_cross(params, cache, frames)

    t0 = time.perf_counter()
    last_logits, cache = jax.jit(
        lambda p, c, t: prefill_with_decode(model, p, c, t))(params, cache,
                                                             prompts)
    jax.block_until_ready(last_logits)
    t_prefill = time.perf_counter() - t0

    t0 = time.perf_counter()
    toks, cache = jax.jit(
        lambda p, c, lg: greedy_decode(model, p, c, lg, args.prompt_len,
                                       args.gen))(params, cache, last_logits)
    toks = np.asarray(toks)
    t_gen = time.perf_counter() - t0

    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: {t_gen*1e3:.1f} ms "
          f"({args.gen*args.batch/t_gen:.1f} tok/s incl. compile)")
    print("sample tokens:", toks[0][:16].tolist())


if __name__ == "__main__":
    main()
