"""llava-next-mistral-7b [vlm]: mistral-7b backbone + anyres patch stub.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, rope_theta=1e6, vision_dim=1024, num_patches=576,
    microbatch=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, vision_dim=16, num_patches=4, attn_chunk=0, microbatch=1)
