"""whisper-large-v3 [audio]: enc-dec backbone, conv frontend stubbed
(input_specs supplies 1500 precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, enc_frames=1500, tie_embeddings=True,
    microbatch=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, enc_frames=8, attn_chunk=0, microbatch=1)
