"""falcon-mamba-7b [ssm]: 64 Mamba-1 blocks, attention-free.
[arXiv:2410.05355; unverified]"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=65024, ssm_state=16, d_conv=4, expand=2, scan_chunk=256,
    microbatch=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, vocab=256, ssm_state=4, scan_chunk=8,
    microbatch=1)
