"""qwen3-1.7b [dense]: qk_norm, GQA kv=8.  [hf:Qwen/Qwen3-8B; hf]"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=6144,
    vocab=151936, qk_norm=True, head_dim=128, rope_theta=1e6, microbatch=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, attn_chunk=0, microbatch=1)
