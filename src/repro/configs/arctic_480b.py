"""arctic-480b [moe]: 128 experts top-2 + parallel dense residual MLP.
EP over model x data axes, 8-bit Adam + ZeRO-1 (DESIGN.md 5).
[hf:Snowflake/snowflake-arctic-base; hf]"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, n_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
    expert_sharding="model+data", opt_8bit=True, microbatch=16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab=256, n_experts=8, top_k=2, moe_d_ff=96, expert_sharding="ffn",
    attn_chunk=0, microbatch=1, opt_8bit=True)
