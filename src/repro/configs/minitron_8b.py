"""minitron-8b [dense]: pruned nemotron, 256k vocab.  [arXiv:2407.14679; hf]"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
    vocab=256000, microbatch=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, attn_chunk=0, microbatch=1)
