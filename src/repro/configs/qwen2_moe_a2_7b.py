"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed experts, top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=151936, n_experts=60, top_k=4, n_shared_experts=4, moe_d_ff=1408,
    expert_sharding="ffn", microbatch=16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
    vocab=256, n_experts=8, top_k=2, n_shared_experts=1, moe_d_ff=64,
    attn_chunk=0, microbatch=1)
