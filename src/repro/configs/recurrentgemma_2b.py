"""recurrentgemma-2b [hybrid]: RG-LRU + local attention (window 2048),
pattern (rec, rec, attn); MQA kv=1.  [arXiv:2402.19427; hf]"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, window=2048, head_dim=256, block_pattern=("rec", "rec", "attn"),
    d_rnn=2560, tie_embeddings=True, microbatch=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab=256, window=8, head_dim=16, d_rnn=64, attn_chunk=0, microbatch=1)
