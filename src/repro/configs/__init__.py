"""Assigned architecture configs (exact specs from the public pool) plus
reduced smoke variants and the paper-core reachability workloads.

``get_config(name)`` returns the full config; ``get_smoke_config(name)``
returns a same-family reduction that runs a forward/train step on CPU.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ArchConfig

ARCH_IDS: List[str] = [
    "llava_next_mistral_7b",
    "falcon_mamba_7b",
    "qwen2_5_14b",
    "qwen2_7b",
    "qwen3_1_7b",
    "minitron_8b",
    "whisper_large_v3",
    "qwen2_moe_a2_7b",
    "arctic_480b",
    "recurrentgemma_2b",
]

# canonical dashed ids from the assignment -> module names
ALIASES: Dict[str, str] = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen2-7b": "qwen2_7b",
    "qwen3-1.7b": "qwen3_1_7b",
    "minitron-8b": "minitron_8b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "arctic-480b": "arctic_480b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
