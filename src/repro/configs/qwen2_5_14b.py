"""qwen2.5-14b [dense]: GQA kv=8, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab=152064, qkv_bias=True, rope_theta=1e6, microbatch=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, attn_chunk=0, microbatch=1)
