"""Shims over jax API drift so the same source runs on the pinned
container jax (0.4.x) and current releases.

* ``shard_map``: promoted from ``jax.experimental.shard_map`` to
  ``jax.shard_map`` (and ``check_rep`` renamed ``check_vma``) in newer
  releases.
* ``make_mesh``: ``axis_types`` / ``jax.sharding.AxisType`` only exist on
  newer releases; older meshes are Auto-typed implicitly.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):                       # new API
    shard_map = jax.shard_map
else:                                               # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)

try:
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))
