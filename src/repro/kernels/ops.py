"""jit'd dispatch wrappers for the Pallas kernels.

On TPU the compiled kernels run natively; on CPU (this container) they run
under ``interpret=True`` — same kernel body, Python-evaluated, used by the
test suite to validate against ``ref.py``.  Set ``REPRO_FORCE_REF=1`` to
bypass Pallas entirely (pure-jnp fallbacks).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .maxmin_matmul import maxmin_matmul_pallas
from .overlap import overlap_pallas
from .threshold_closure import threshold_step_pallas
from .label_join import label_join_pallas

__all__ = ["maxmin_matmul", "overlap", "threshold_step", "label_join",
           "maxmin_closure_kernel", "threshold_mr_kernel", "use_interpret",
           "interpret_available"]


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


_INTERPRET_PROBE: Optional[bool] = None


def interpret_available() -> bool:
    """Whether ``pallas_call(interpret=True)`` works on this host.

    Probed once with a tiny kernel and cached; tests use it to skip
    cleanly on builds where the Pallas interpreter is unavailable
    (e.g. a jaxlib compiled without the Mosaic interpret path).
    """
    global _INTERPRET_PROBE
    if _INTERPRET_PROBE is None:
        try:
            from jax.experimental import pallas as pl

            def _copy(x_ref, o_ref):
                o_ref[...] = x_ref[...]

            x = jnp.arange(8, dtype=jnp.int32)
            out = pl.pallas_call(
                _copy, out_shape=jax.ShapeDtypeStruct((8,), jnp.int32),
                interpret=True)(x)
            _INTERPRET_PROBE = bool((np.asarray(out) == np.arange(8)).all())
        except Exception:
            _INTERPRET_PROBE = False
    return _INTERPRET_PROBE


def _force_ref() -> bool:
    return os.environ.get("REPRO_FORCE_REF", "0") == "1"


def maxmin_matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128):
    if _force_ref():
        return ref.maxmin_matmul_ref(a, b)
    return maxmin_matmul_pallas(a, b, bm=bm, bn=bn, bk=bk,
                                interpret=use_interpret())


def overlap(b_inc, *, bm: int = 128, bn: int = 128, bk: int = 128):
    if _force_ref():
        return ref.overlap_ref(b_inc)
    return overlap_pallas(b_inc, bm=bm, bn=bn, bk=bk,
                          interpret=use_interpret())


def threshold_step(r, *, bm: int = 128, bn: int = 128, bk: int = 128):
    if _force_ref():
        return ref.threshold_step_ref(r)
    return threshold_step_pallas(r, bm=bm, bn=bn, bk=bk,
                                 interpret=use_interpret())


def label_join(ru, su, rv, sv, *, bq: int = 128):
    if _force_ref():
        return ref.label_join_ref(ru, su, rv, sv)
    return label_join_pallas(ru, su, rv, sv, bq=bq,
                             interpret=use_interpret())


# ---------------------------------------------------------------------------
# closure drivers on top of the kernels
# ---------------------------------------------------------------------------

def maxmin_closure_kernel(w: jax.Array, *, rounds: Optional[int] = None,
                          **blocks) -> jax.Array:
    """Bottleneck closure via the Pallas (max,min) kernel."""
    m = w.shape[0]
    n_rounds = rounds if rounds is not None else max(1, int(np.ceil(np.log2(max(m, 2)))))
    r = w
    for _ in range(n_rounds):
        r = jnp.maximum(r, maxmin_matmul(r, r, **blocks))
    return r


def threshold_mr_kernel(w: jax.Array, thresholds: np.ndarray, *,
                        rounds: Optional[int] = None, **blocks) -> jax.Array:
    """MR matrix via the fused threshold-closure kernel (MXU path)."""
    m = w.shape[0]
    n_rounds = rounds if rounds is not None else max(1, int(np.ceil(np.log2(max(m, 2)))))
    t = jnp.asarray(thresholds)
    adj = (w[None, :, :] >= t[:, None, None]).astype(jnp.float32)
    eye = jnp.eye(m, dtype=jnp.float32)[None]
    r = jnp.maximum(adj, eye)
    for _ in range(n_rounds):
        r = threshold_step(r, **blocks)
    mr = (r * t[:, None, None].astype(jnp.float32)).max(axis=0)
    mr = mr.at[jnp.arange(m), jnp.arange(m)].set(jnp.diagonal(w).astype(jnp.float32))
    return mr.astype(w.dtype)
