"""Pallas TPU kernel: single-token flash decode attention.

The hot spot of the ``decode_32k`` / ``long_500k`` cells: one query token
per (batch, head) against a long KV cache.  Streaming online-softmax over
KV chunks — the cache is read exactly once HBM→VMEM (the cell is
memory-bound, §Roofline), with running (m, l, acc) carried in the output
blocks across the chunk grid dimension.

Layout: q [B, H, hd]; k/v [B, S, H, hd] (GQA already broadcast to full
heads — the repeat is free bandwidth-wise when kv < H because pages can
be aliased upstream); additive mask [B, S] (0 / -inf encodes both the
causal bound and rolling-window validity).

Grid: (B, H, S/chunk), chunk innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["flash_decode_pallas"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, *,
            kg: int, scale: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                                   # [hd]
    k = k_ref[0, :, 0, :]                             # [chunk, hd]
    v = v_ref[0, :, 0, :]
    s = (k @ q) * scale + mask_ref[0]                 # [chunk]
    m_prev = m_ref[0, 0, 0]
    l_prev = l_ref[0, 0, 0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum()
    acc = o_ref[0, 0] * corr + p @ v
    m_ref[0, 0, 0] = m_new
    l_ref[0, 0, 0] = l_new

    @pl.when(pl.program_id(2) == kg - 1)
    def _final():
        o_ref[0, 0] = acc / jnp.maximum(l_new, 1e-30)

    @pl.when(pl.program_id(2) < kg - 1)
    def _carry():
        o_ref[0, 0] = acc


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                        mask: jax.Array, *, chunk: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q [B,H,hd] f32/bf16; k/v [B,S,H,hd]; mask [B,S] additive f32.
    Returns [B,H,hd] in q's dtype (f32 accumulation)."""
    b, h, hd = q.shape
    s = k.shape[1]
    pad = (-s) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)), constant_values=_NEG)
    kg = k.shape[1] // chunk
    qf = q.astype(jnp.float32)
    out, _, _ = pl.pallas_call(
        functools.partial(_kernel, kg=kg, scale=1.0 / np.sqrt(hd)),
        grid=(b, h, kg),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda bi, hi, ki: (bi, hi, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, chunk), lambda bi, hi, ki: (bi, ki)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, hd), lambda bi, hi, ki: (bi, hi, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, hi, ki: (bi, hi, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, hi, ki: (bi, hi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, k.astype(jnp.float32), v.astype(jnp.float32),
      mask.astype(jnp.float32))
    return out.astype(q.dtype)
