"""Pallas TPU kernel: fused boolean-closure squaring step over a
threshold batch.

One round of the MXU reformulation of the bottleneck closure (DESIGN.md
§2): for each threshold slice R[s] ∈ {0,1}^{m×m}, compute

    out[s] = (R[s] @ R[s] > 0)

with the binarization fused into the epilogue of the matmul so the raw
path-count products never round-trip to HBM.  This is the kernel that
turns the paper's (max, min) semiring into MXU work.

Grid: (S, M/bm, N/bn, K/bk), k innermost.  The accumulator lives in the
output VMEM block (f32); on the last k step it is binarized in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["threshold_step_pallas"]


def _kernel(a_ref, b_ref, o_ref, *, kg: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                          preferred_element_type=jnp.float32)[None]

    @pl.when(pl.program_id(3) == kg - 1)
    def _binarize():
        o_ref[...] = (o_ref[...] > 0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def threshold_step_pallas(r: jax.Array, *, bm: int = 128, bn: int = 128,
                          bk: int = 128, interpret: bool = False) -> jax.Array:
    """out[s] = (R[s] @ R[s] > 0) for a [S, m, m] float 0/1 batch."""
    s, m, m2 = r.shape
    assert m == m2
    if s == 0 or m == 0:
        return r
    pad = (-m) % max(bm, bn, bk)
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, pad)))
    mp = r.shape[1]
    mg, ng, kg = mp // bm, mp // bn, mp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, kg=kg),
        grid=(s, mg, ng, kg),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda ss, i, j, kk: (ss, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda ss, i, j, kk: (ss, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda ss, i, j, kk: (ss, i, j)),
        out_shape=jax.ShapeDtypeStruct((s, mp, mp), r.dtype),
        interpret=interpret,
    )(r, r)
    return out[:, :m, :m]
