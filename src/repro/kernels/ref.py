"""Pure-jnp oracles for every Pallas kernel.

Each function is the semantic ground truth the kernels are validated
against (tests sweep shapes/dtypes and assert_allclose kernel-vs-ref).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["maxmin_matmul_ref", "overlap_ref", "threshold_step_ref",
           "label_join_ref"]


def maxmin_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[i,j] = max_k min(A[i,k], B[k,j]).  Non-negative domain, so the
    empty-k reduction identity is 0."""
    if a.shape[1] == 0:
        return jnp.zeros((a.shape[0], b.shape[1]), a.dtype)
    return jnp.minimum(a[:, :, None], b[None, :, :]).max(axis=1)


def overlap_ref(b_inc: jax.Array, sizes: jax.Array | None = None) -> jax.Array:
    """Line graph W = B·Bᵀ from a 0/1 incidence matrix [m, n]; the diagonal
    is |e_i| either way (row self-product), optionally overridden by
    ``sizes`` (used when B is a padded block of a larger incidence)."""
    w = b_inc @ b_inc.T
    if sizes is not None:
        m = b_inc.shape[0]
        w = w.at[jnp.arange(m), jnp.arange(m)].set(sizes.astype(w.dtype))
    return w


def threshold_step_ref(r: jax.Array) -> jax.Array:
    """One boolean-closure squaring round over a threshold batch:
    out[s] = (R[s] @ R[s] > 0), float 0/1 in, float 0/1 out."""
    return (jax.lax.batch_matmul(r, r) > 0).astype(r.dtype)


def label_join_ref(ru: jax.Array, su: jax.Array,
                   rv: jax.Array, sv: jax.Array) -> jax.Array:
    """Batched HL-index label join (Algorithm 5 semantics):
    out[q] = max over common hubs of min(s_u, s_v).

    ru/rv: [Q, L] ascending hub ranks (INT32_MAX padding);
    su/sv: [Q, L] s values (0 padding).
    """
    eq = ru[:, :, None] == rv[:, None, :]                      # [Q, L, L]
    cand = jnp.where(eq, jnp.minimum(su[:, :, None], sv[:, None, :]), 0)
    return cand.max(axis=(1, 2)) if ru.size else jnp.zeros((ru.shape[0],),
                                                           su.dtype)
