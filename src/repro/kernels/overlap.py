"""Pallas TPU kernel: hyperedge-overlap (line-graph) construction.

W = B·Bᵀ over the 0/1 incidence matrix B [m, n] — OD(e_i, e_j) counts the
shared vertices of two hyperedges.  This is the MXU-friendly half of the
paper's workload: a plain matmul against the matrix's own transpose.

TPU mapping: blocks of B are streamed HBM→VMEM; each grid step issues a
[bm, bk]·[bk, bn] MXU contraction (``preferred_element_type=float32`` so
bf16 inputs accumulate in f32).  Grid (M/bm, M/bn, N/bk), k innermost;
the j-block of rows is read via the same operand with a transposed index
map, so the kernel never materializes Bᵀ.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["overlap_pallas"]


def _kernel(a_ref, bt_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], bt_ref[...].T,
                          preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def overlap_pallas(b_inc: jax.Array, *, bm: int = 128, bn: int = 128,
                   bk: int = 128, interpret: bool = False) -> jax.Array:
    """W = B·Bᵀ (f32 accumulate).  Diagonal = |e_i| (row self-product), so
    the result is exactly the line graph of ``hypergraph.line_graph``."""
    m, n = b_inc.shape
    if m == 0 or n == 0:
        return jnp.zeros((m, m), jnp.float32)
    mp, kp = (-m) % max(bm, bn), (-n) % bk
    if mp or kp:
        b_inc = jnp.pad(b_inc, ((0, mp), (0, kp)))
    mpad, npad = b_inc.shape
    mg, ng, kg = mpad // bm, mpad // bn, npad // bk

    out = pl.pallas_call(
        _kernel,
        grid=(mg, ng, kg),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),  # row block j — transposed in-kernel
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mpad, mpad), jnp.float32),
        interpret=interpret,
    )(b_inc, b_inc)
    return out[:m, :m]
