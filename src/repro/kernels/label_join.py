"""Pallas TPU kernel: batched HL-index label join (Algorithm 5).

out[q] = max over common hubs of min(s_u[q], s_v[q]) — the serving-path
inner loop.  Each query row holds two padded, rank-sorted label lists; the
kernel evaluates the all-pairs hub-equality join on the VPU (a
[bq, bl, bl] compare + select + reduce), which beats the sequential
two-pointer merge on a vector unit for the label lengths the paper
reports (avg |L| well under 128).

Tiling: grid (Q/bq, L/bl, L/bl).  The output block ``o[bq]`` is indexed
by the query dimension only, so it stays VMEM-resident across the whole
(j, k) label-tile sweep (initialized on the first tile, max-accumulated
after) — the all-pairs intermediate is bounded to [bq, bl, bl] no matter
how wide the label rows are.  That is what keeps closure-derived
snapshots (where L = m) and heavy-tail label rows inside VMEM instead of
materializing a [bq, L, L] cube.

Sentinel contract (shared with ``DeviceSnapshot`` / ``pad_label_rows``):

* rank padding is ``INT32_MAX`` (2^31 - 1) on both operands;
* query rows added to reach a ``bq`` multiple carry ``INT32_MAX - 1`` on
  the u side, so an all-padding row never self-matches;
* therefore **real ranks must be <= MAX_RANK = 2^31 - 3**: a real rank
  equal to either sentinel would alias padding.  Rank keys are hyperedge
  importance ranks (or raw hyperedge ids for closure snapshots), so the
  bound is m <= 2^31 - 2 hyperedges — ``validate_ranks`` asserts it once
  per snapshot (``KernelSnapshot``), not per query batch.  Pad-pad
  matches themselves are inert either way: padding svals are 0, the
  identity of the join max.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["label_join_pallas", "validate_ranks", "MAX_RANK"]

_PAD = np.iinfo(np.int32).max          # rank-slot padding (both operands)
_PAD_ROW = _PAD - 1                    # u-side padded *query rows*
MAX_RANK = _PAD - 2                    # largest legal real rank (2^31 - 3)


def validate_ranks(ranks) -> None:
    """Raise if any real rank aliases a padding sentinel.

    One host-visible reduction; callers run it once per snapshot (not
    per batch).  The padded label form uses ``INT32_MAX`` for empty
    slots and ``INT32_MAX - 1`` for whole padded query rows, so real
    ranks above ``MAX_RANK`` would silently join against padding.
    """
    ranks = jnp.asarray(ranks)
    if ranks.size == 0:
        return
    real_max = int(jnp.where(ranks == _PAD, -1, ranks).max())
    if real_max > MAX_RANK:
        raise ValueError(
            f"label rank {real_max} aliases the padding sentinels; the "
            f"kernel join supports real ranks <= {MAX_RANK} (2^31 - 3), "
            f"i.e. at most 2^31 - 2 hyperedges")


def _kernel(ru_ref, su_ref, rv_ref, sv_ref, o_ref):
    @pl.when((pl.program_id(1) == 0) & (pl.program_id(2) == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ru = ru_ref[...]                   # [bq, bl] u-side label tile j
    su = su_ref[...]
    rv = rv_ref[...]                   # [bq, bl] v-side label tile k
    sv = sv_ref[...]
    eq = ru[:, :, None] == rv[:, None, :]
    cand = jnp.where(eq, jnp.minimum(su[:, :, None], sv[:, None, :]), 0)
    o_ref[...] = jnp.maximum(o_ref[...], cand.max(axis=(1, 2)))


@functools.partial(jax.jit, static_argnames=("bq", "bl", "interpret"))
def label_join_pallas(ru: jax.Array, su: jax.Array, rv: jax.Array,
                      sv: jax.Array, *, bq: int = 128, bl: int = 256,
                      interpret: bool = False) -> jax.Array:
    """ru/rv [Q, L] int32 ascending ranks (INT32_MAX pad — padding never
    matches since real ranks <= MAX_RANK), su/sv [Q, L] int32 (0 pad).
    Returns [Q] int32.  Q and L need not be block multiples; Q = 0 and
    L = 0 are legal (nothing joins: all zeros)."""
    q, lmax = ru.shape
    if q == 0 or lmax == 0:
        return jnp.zeros((q,), su.dtype)
    bl = min(bl, lmax)                 # one tile when the rows are narrow
    qpad, lpad = (-q) % bq, (-lmax) % bl
    if qpad or lpad:
        ru = jnp.pad(ru, ((0, qpad), (0, lpad)), constant_values=_PAD)
        rv = jnp.pad(rv, ((0, qpad), (0, lpad)), constant_values=_PAD)
        su = jnp.pad(su, ((0, qpad), (0, lpad)))
        sv = jnp.pad(sv, ((0, qpad), (0, lpad)))
    if qpad:
        # padded query rows: ranks all-INT32_MAX on both sides would
        # "match"; force the u-side pad rows to the row sentinel (their
        # answers are sliced off below either way)
        ru = ru.at[q:, :].set(_PAD_ROW)
    qg = ru.shape[0] // bq
    lg = ru.shape[1] // bl

    out = pl.pallas_call(
        _kernel,
        grid=(qg, lg, lg),
        in_specs=[
            pl.BlockSpec((bq, bl), lambda i, j, k: (i, j)),   # u ranks
            pl.BlockSpec((bq, bl), lambda i, j, k: (i, j)),   # u svals
            pl.BlockSpec((bq, bl), lambda i, j, k: (i, k)),   # v ranks
            pl.BlockSpec((bq, bl), lambda i, j, k: (i, k)),   # v svals
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((ru.shape[0],), su.dtype),
        interpret=interpret,
    )(ru, su, rv, sv)
    return out[:q]
