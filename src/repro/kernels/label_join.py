"""Pallas TPU kernel: batched HL-index label join (Algorithm 5).

out[q] = max over common hubs of min(s_u[q], s_v[q]) — the serving-path
inner loop.  Each query row holds two padded, rank-sorted label lists; the
kernel evaluates the all-pairs hub-equality join on the VPU (an [bq, L, L]
compare + select + reduce), which beats the sequential two-pointer merge
on a vector unit for the label lengths the paper reports (avg |L| well
under 128).

Grid: (Q/bq,).  All four operands stream [bq, L] VMEM blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["label_join_pallas"]


def _kernel(ru_ref, su_ref, rv_ref, sv_ref, o_ref):
    ru = ru_ref[...]
    su = su_ref[...]
    rv = rv_ref[...]
    sv = sv_ref[...]
    eq = ru[:, :, None] == rv[:, None, :]
    cand = jnp.where(eq, jnp.minimum(su[:, :, None], sv[:, None, :]), 0)
    o_ref[...] = cand.max(axis=(1, 2))


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def label_join_pallas(ru: jax.Array, su: jax.Array, rv: jax.Array,
                      sv: jax.Array, *, bq: int = 128,
                      interpret: bool = False) -> jax.Array:
    """ru/rv [Q, L] int32 ascending ranks (INT32_MAX pad — padding never
    matches since real ranks < m), su/sv [Q, L] int32 (0 pad)."""
    q, lmax = ru.shape
    pad = (-q) % bq
    if pad:
        ru, su, rv, sv = (jnp.pad(x, ((0, pad), (0, 0))) for x in (ru, su, rv, sv))
        # padded query rows: ranks all-INT32_MAX on both sides would "match";
        # force the u-side pad rows to a different sentinel.
        ru = ru.at[q:, :].set(jnp.iinfo(jnp.int32).max - 1)
    qg = ru.shape[0] // bq

    out = pl.pallas_call(
        _kernel,
        grid=(qg,),
        in_specs=[pl.BlockSpec((bq, lmax), lambda i: (i, 0)) for _ in range(4)],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ru.shape[0],), su.dtype),
        interpret=interpret,
    )(ru, su, rv, sv)
    return out[:q]
