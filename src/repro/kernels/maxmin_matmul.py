"""Pallas TPU kernel: (max, min)-semiring matmul.

C[i,j] = max_k min(A[i,k], B[k,j]) — the inner step of the bottleneck-path
closure (the paper's max-reachability between hyperedges).

TPU mapping: the MXU cannot evaluate a (max, min) contraction (it is a
fixed multiply-accumulate array), so this kernel is VPU work.  The design
goal is therefore *bandwidth*: stream 128-aligned A/B tiles HBM→VMEM once
per (i, j, k) grid step and keep the [bm, kc, bn] broadcast intermediate
small enough to live in VREG/VMEM (k is sub-tiled by ``k_chunk``).

Grid: (M/bm, N/bn, K/bk) with k innermost so the output block stays
resident in VMEM across the k sweep (revisiting accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["maxmin_matmul_pallas"]


def _kernel(a_ref, b_ref, o_ref, *, k_chunk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                      # [bm, bk]
    b = b_ref[...]                      # [bk, bn]
    bk = a.shape[1]
    # ceil, not floor: bk // k_chunk drops the tail columns when bk is
    # not a k_chunk multiple.  dynamic_slice clamps the last start index
    # so the final chunk overlaps the previous one — exact here, because
    # (max, min) accumulation is idempotent.
    steps = -(-bk // k_chunk)

    def body(i, acc):
        a_c = jax.lax.dynamic_slice_in_dim(a, i * k_chunk, k_chunk, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(b, i * k_chunk, k_chunk, axis=0)
        c = jnp.minimum(a_c[:, :, None], b_c[None, :, :]).max(axis=1)
        return jnp.maximum(acc, c)

    acc = jax.lax.fori_loop(0, steps, body, o_ref[...])
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "k_chunk",
                                             "interpret"))
def maxmin_matmul_pallas(a: jax.Array, b: jax.Array, *, bm: int = 128,
                         bn: int = 128, bk: int = 128, k_chunk: int = 8,
                         interpret: bool = False) -> jax.Array:
    """(max, min) matmul with explicit VMEM tiling.  Non-negative inputs;
    shapes are padded to block multiples with the semiring zero.  Empty
    operands (m, n or k of 0) return the semiring-zero result directly."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    if m == 0 or n == 0 or k == 0:
        return jnp.zeros((m, n), a.dtype)
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        a = jnp.pad(a, ((0, mp), (0, kp)))
    if np_ or kp:
        b = jnp.pad(b, ((0, kp), (0, np_)))
    mg, ng, kg = a.shape[0] // bm, b.shape[1] // bn, a.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_kernel, k_chunk=min(k_chunk, bk)),
        grid=(mg, ng, kg),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), a.dtype),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
