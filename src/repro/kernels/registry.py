"""Registry of every Pallas kernel in the package.

Single source of truth for "what kernels exist and what validates
them": the differential-test harness (``tests/test_kernels_diff.py``)
asserts it fuzzes every entry, and docs check 7
(``tools/check_docs.py``) asserts the kernel-capability table in
docs/ARCHITECTURE.md matches it both ways.  A kernel added without a
registry entry fails the harness-coverage assertion; an entry without a
doc row fails the docs build.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from . import ref
from .label_join import label_join_pallas
from .maxmin_matmul import maxmin_matmul_pallas
from .overlap import overlap_pallas
from .threshold_closure import threshold_step_pallas

__all__ = ["KERNEL_REGISTRY", "KernelSpec"]


@dataclass(frozen=True)
class KernelSpec:
    kernel: Callable          # the pallas_call wrapper
    reference: Callable       # its pure-jnp oracle in ref.py
    unit: str                 # compute unit the TPU lowering targets
    consumer: str             # production call-site served by the kernel


KERNEL_REGISTRY: dict[str, KernelSpec] = {
    "label_join": KernelSpec(
        kernel=label_join_pallas, reference=ref.label_join_ref, unit="VPU",
        consumer="KernelSnapshot.mr — serving-path batched merge-join"),
    "maxmin_matmul": KernelSpec(
        kernel=maxmin_matmul_pallas, reference=ref.maxmin_matmul_ref,
        unit="VPU",
        consumer="sharded closure build/update local contraction"),
    "overlap": KernelSpec(
        kernel=overlap_pallas, reference=ref.overlap_ref, unit="MXU",
        consumer="line-graph W = B·Bᵀ construction"),
    "threshold_step": KernelSpec(
        kernel=threshold_step_pallas, reference=ref.threshold_step_ref,
        unit="MXU",
        consumer="threshold_mr_kernel boolean-closure squaring round"),
}
