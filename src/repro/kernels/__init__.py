"""Pallas TPU kernels for the paper's compute hot-spots + jnp oracles."""
from . import ref
from .maxmin_matmul import maxmin_matmul_pallas
from .overlap import overlap_pallas
from .threshold_closure import threshold_step_pallas
from .label_join import label_join_pallas, validate_ranks, MAX_RANK
from .registry import KERNEL_REGISTRY, KernelSpec
from .ops import (maxmin_matmul, overlap, threshold_step, label_join,
                  maxmin_closure_kernel, threshold_mr_kernel, use_interpret,
                  interpret_available)

__all__ = [
    "ref", "maxmin_matmul_pallas", "overlap_pallas", "threshold_step_pallas",
    "label_join_pallas", "validate_ranks", "MAX_RANK",
    "KERNEL_REGISTRY", "KernelSpec",
    "maxmin_matmul", "overlap", "threshold_step",
    "label_join", "maxmin_closure_kernel", "threshold_mr_kernel",
    "use_interpret", "interpret_available",
]
