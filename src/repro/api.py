"""Public facade for hypergraph reachability — the one import surface.

    from repro.api import build_engine, random_hypergraph

    h = random_hypergraph(1000, 1500)
    eng = build_engine(h, backend="auto", batch_hint=10_000)
    eng.mr(u, v)                     # scalar max-reachability
    eng.s_reach(u, v, s)             # scalar s-reachability
    eng.mr_batch(us, vs)             # [Q] vectorized
    snap = eng.snapshot()            # device-resident padded form
    snap.mr(us, vs)                  # fused XLA batch join

Every backend (see ``available_backends()``) answers through the same
``ReachabilityEngine`` protocol; ``backend="auto"`` lets the planner pick.
Examples, benchmarks, and the cross-validation suite all route through
this module, so a new backend is one ``register_backend`` entry away from
being benchmarked and validated.

Hyperedge updates go through the same engine — no rebuilding by hand:

    eng.update(inserts=[[3, 7, 9]], deletes=[4])   # in place
    eng.mr(u, v)                     # answers == full rebuild
    snap2 = eng.snapshot()           # fresh (the old snapshot is stale:
                                     #  snap.version != eng.version)

``update_capabilities()`` maps each backend to how it absorbs updates:
scoped construction on the affected line-graph component(s)
(``hl-index``/``hl-index-basic``), 1-hop adjacency-cache patches
(``online``/``frontier``), whole-structure recompute behind the same
call (``closure``/``sharded``), or ``UpdateUnsupported`` (the static
baselines).

Indexes survive the process that built them (``repro.store``):

    save_index("paper.hlidx", eng)               # versioned, checksummed
    eng2 = build_engine(restore="paper.hlidx")   # mmap load, no rebuild
    store = IndexStore("idx.d", checkpoint_every=64)
    store.attach(eng)                            # WAL: updates journal first
    eng3 = build_engine(restore="idx.d")         # checkpoint + WAL replay

Serving heavy request traffic goes through the request-based service
instead of hand-assembled batches (``repro.serve.reach_service``);
serving knobs live in a typed ``ServiceConfig``:

    svc = serve(h, batch_hint=10_000,            # engine + admission loop
                config=ServiceConfig(max_batch=1024))
    f = svc.mr(4, 8)                             # Future[int]
    g = svc.submit(SReachRequest(4, 8, s=2))     # Future[bool], mixed s ok
    f.result(); g.result()
    svc.update(inserts=[[3, 7, 9]])              # snapshot swapped between
    svc.close()                                  #   micro-batches

The service coalesces pending requests into fused padded device batches
(power-of-two buckets bound XLA recompiles) and reuses one
version-keyed resident snapshot across batches — after a scoped update
only the dirty label rows are re-derived.

The service is multi-tenant: requests carry ``tenant`` / ``priority`` /
``deadline_ms`` metadata, the admission queue is weighted-fair across
tenants within strict priority bands, expired requests fail fast with
``DeadlineExceeded``, and ``submit_stream`` delivers answers in
completion order.  ``ServiceConfig(replicas=N)`` scales reads by
serving round-robin off N mesh-resident snapshot replicas
(``ReplicaGroup``) — updates apply on the single writer and only the
dirty label rows fan out to the replicas:

    svc = serve(h, config=ServiceConfig(
        tenants=(TenantSpec("analytics", weight=1.0),
                 TenantSpec("dashboard", weight=4.0)),
        replicas=2))
    svc.submit(MRRequest(4, 8, tenant="dashboard",
                         priority="interactive", deadline_ms=50.0))

Beyond point mr/s-reach, the engines answer five *workload* query
families (``repro.workloads``; per-backend support in
``workload_capabilities()``, unsupported ops raise
``WorkloadUnsupported``):

    eng.mr_witness(u, v)             # Witness: the hyperedge walk itself
    eng.s_reach_k(u, v, s, k)        # s-walk of <= k hyperedges?
    eng.mr_set(us, vs)               # set-to-set max MR (batched join)
    eng.mr_from_set(us, targets)     # multi-source MR per target
    eng.top_s(u, k)                  # k strongest-s neighbors of u
    eng.s_distance(u, v, s)          # certified landmark upper bound

The same families serve as typed requests (``WitnessRequest``,
``SReachKRequest``, ``MRSetRequest``, ``TopSRequest``,
``SDistanceRequest``) through ``serve()`` — same tenant/priority/
deadline metadata, own dispatch groups, refused at admission when the
backend lacks the capability.

Multi-device serving goes through the same two calls — build a mesh and
pass it:

    from repro.api import build_engine, make_mesh

    mesh = make_mesh((2, 2), ("data", "model"))   # or any device grid
    eng = build_engine(h, backend="auto", mesh=mesh)   # planner may pick
    eng = build_engine(h, backend="sharded", mesh=mesh, schedule="ring")
    eng.mr_batch(us, vs)             # served off the block-sharded W*

Index **construction** itself shards over the mesh too — the one stage
that used to be host-serial (see ``repro.core.hlindex.build_sharded``):

    eng = build_engine(h, "hl-index", mesh=mesh)      # auto: sharded build
    eng = build_engine(h, "hl-index", construction="sharded", workers=4)
    eng = build_engine(h, "sharded", mesh=mesh, build_labels=True)

The sharded builder partitions the rank-ordered root sequence at
line-graph component boundaries, precomputes the shared neighbor index
as one CSR (overlaps on the mesh when one is passed), and merges with a
deterministic reconciliation pass — labels are byte-identical to the
serial ``build_fast`` (property-tested), so every downstream contract
(scoped maintenance splice, dirty-rows snapshot caching, serving) is
unchanged.  ``build_labels=True`` flips the ``sharded`` backend from the
resident-closure regime to serving mesh-sharded label snapshots.

``make_mesh`` (re-exported from ``repro.compat``) hides jax-version API
drift; ``snap.to_mesh(mesh)`` re-lands any label snapshot sharded over a
mesh.  The architecture — data flow, backend catalogue, planner policy,
construction modes, and the sharding schedules — is documented in
``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import dataclasses
import warnings

from repro.compat import make_mesh
from repro.core.engine import (ReachabilityEngine, DeviceSnapshot,
                               SnapshotUnsupported, UpdateUnsupported,
                               WorkloadUnsupported, WORKLOAD_OPS,
                               available_backends, update_capabilities,
                               workload_capabilities, plan_backend,
                               register_backend, validate_batch)
from repro.core.engine import build as build_engine
from repro.core.hypergraph import (Hypergraph, from_edge_lists, compact,
                                   random_hypergraph,
                                   planted_chain_hypergraph,
                                   colocation_hypergraph, paper_figure1)
from repro.serve.reach_service import (MRRequest, MRSetRequest,
                                       ReachabilityService, Request,
                                       SDistanceRequest, ServiceConfig,
                                       SReachKRequest, SReachRequest,
                                       TopSRequest, WitnessRequest)
from repro.serve.replicas import ReplicaGroup
from repro.serve.scheduler import (PRIORITY_CLASSES, DeadlineExceeded,
                                   TenantSpec)
from repro.store import (IndexStore, load_index, read_hif, save_index,
                         write_hif)
from repro.workloads import DistanceOracle, Witness, verify_witness

__all__ = [
    "ReachabilityEngine", "DeviceSnapshot", "SnapshotUnsupported",
    "UpdateUnsupported", "build_engine", "available_backends",
    "update_capabilities", "plan_backend", "register_backend",
    "validate_batch", "make_mesh",
    "ReachabilityService", "ReplicaGroup", "serve", "ServiceConfig",
    "TenantSpec", "PRIORITY_CLASSES", "DeadlineExceeded",
    "Request", "MRRequest", "SReachRequest",
    # workload surface: one pinned set — engine capabilities, request
    # kinds, and the answer/verification types
    "WorkloadUnsupported", "WORKLOAD_OPS", "workload_capabilities",
    "WitnessRequest", "SReachKRequest", "MRSetRequest", "TopSRequest",
    "SDistanceRequest", "Witness", "verify_witness", "DistanceOracle",
    "Hypergraph", "from_edge_lists", "compact", "random_hypergraph",
    "planted_chain_hypergraph", "colocation_hypergraph", "paper_figure1",
    "IndexStore", "save_index", "load_index", "read_hif", "write_hif",
]

# service knobs that used to ride along in serve(**opts); still accepted
# for one release through the deprecation shim below
_LEGACY_SERVICE_KWARGS = ("max_batch", "min_bucket", "max_wait_ms",
                          "axes", "use_kernels")


def serve(h_or_engine, backend: str = "auto", *,
          config: ServiceConfig = None, mesh=None,
          start: bool = True, batch_hint=None,
          **opts) -> ReachabilityService:
    """One-call serving: build an engine (unless given one) and wrap it
    in a ``ReachabilityService`` (or, with ``config.replicas > 1``, a
    ``ReplicaGroup``).

    Args:
      h_or_engine: a ``Hypergraph`` to build an engine over, or an
        already-built ``ReachabilityEngine`` to serve as-is.
      config: a ``ServiceConfig`` — the typed home of every serving knob
        (batching, tenant weights, priorities, replicas; see its
        docstring).  Defaults to ``ServiceConfig()``.
      backend / batch_hint / mesh / engine ``**opts``: forwarded to
        ``build_engine`` when a hypergraph is passed.  ``mesh`` is also
        handed to the service so the resident snapshot is kept
        mesh-sharded.
      start: start the background admission thread (``start=False`` =
        synchronous mode; call ``svc.drain()``).

    ``config.axes`` names the mesh (row, column) axes in both layers
    and is forwarded to both: the ``sharded`` engine's block-sharding
    and the service's ``to_mesh`` re-landing.  ``config.use_kernels``
    is likewise two-layer: it reaches the engine build (Pallas
    closure/batch paths, for backends that take it) and the service
    (Pallas label-join serving view) — with a prebuilt engine it
    configures the service alone.

    Deprecated: the service knobs (``max_batch``, ``min_bucket``,
    ``max_wait_ms``, ``axes``, ``use_kernels``) are still accepted as
    bare keyword arguments for one release — they fold into ``config``
    with a ``DeprecationWarning``.  Everything else in ``**opts`` is an
    engine-build option.
    """
    legacy = {k: opts.pop(k) for k in _LEGACY_SERVICE_KWARGS if k in opts}
    cfg = config if config is not None else ServiceConfig()
    if legacy:
        warnings.warn(
            f"passing service options {sorted(legacy)} to serve() as bare "
            f"keyword arguments is deprecated; pass "
            f"config=ServiceConfig(...) instead",
            DeprecationWarning, stacklevel=2)
        cfg = dataclasses.replace(cfg, **legacy)
    if isinstance(h_or_engine, Hypergraph):
        if cfg.use_kernels is not None:
            opts["use_kernels"] = cfg.use_kernels
        # resolve "auto" here so backend-specific options route correctly
        # (axes must reach the sharded engine even when the planner — not
        # the caller — picked it)
        resolved = backend if backend != "auto" else plan_backend(
            h_or_engine, batch_hint, mesh=mesh,
            device_budget_bytes=opts.get("device_budget_bytes"))
        if cfg.axes is not None and resolved == "sharded":
            opts["axes"] = cfg.axes  # same axes in both layers
        engine = build_engine(h_or_engine, resolved, batch_hint=batch_hint,
                              mesh=mesh, **opts)
    else:
        rejected = sorted(opts)
        if backend != "auto":
            rejected.append(f"backend={backend!r}")
        if batch_hint is not None:
            rejected.append(f"batch_hint={batch_hint!r}")
        if rejected:
            raise ValueError(
                f"engine options {rejected} make no sense with an "
                f"already-built engine — they would be silently ignored")
        engine = h_or_engine
    if cfg.replicas > 1:
        return ReplicaGroup(engine, config=cfg, mesh=mesh, start=start)
    return ReachabilityService(engine, config=cfg, mesh=mesh, start=start)
