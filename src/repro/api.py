"""Public facade for hypergraph reachability — the one import surface.

    from repro.api import build_engine, random_hypergraph

    h = random_hypergraph(1000, 1500)
    eng = build_engine(h, backend="auto", batch_hint=10_000)
    eng.mr(u, v)                     # scalar max-reachability
    eng.s_reach(u, v, s)             # scalar s-reachability
    eng.mr_batch(us, vs)             # [Q] vectorized
    snap = eng.snapshot()            # device-resident padded form
    snap.mr(us, vs)                  # fused XLA batch join

Every backend (see ``available_backends()``) answers through the same
``ReachabilityEngine`` protocol; ``backend="auto"`` lets the planner pick.
Examples, benchmarks, and the cross-validation suite all route through
this module, so a new backend is one ``register_backend`` entry away from
being benchmarked and validated.

Hyperedge updates go through the same engine — no rebuilding by hand:

    eng.update(inserts=[[3, 7, 9]], deletes=[4])   # in place
    eng.mr(u, v)                     # answers == full rebuild
    snap2 = eng.snapshot()           # fresh (the old snapshot is stale:
                                     #  snap.version != eng.version)

``update_capabilities()`` maps each backend to how it absorbs updates:
scoped construction on the affected line-graph component(s)
(``hl-index``/``hl-index-basic``), 1-hop adjacency-cache patches
(``online``/``frontier``), whole-structure recompute behind the same
call (``closure``/``sharded``), or ``UpdateUnsupported`` (the static
baselines).

Multi-device serving goes through the same two calls — build a mesh and
pass it:

    from repro.api import build_engine, make_mesh

    mesh = make_mesh((2, 2), ("data", "model"))   # or any device grid
    eng = build_engine(h, backend="auto", mesh=mesh)   # planner may pick
    eng = build_engine(h, backend="sharded", mesh=mesh, schedule="ring")
    eng.mr_batch(us, vs)             # served off the block-sharded W*

``make_mesh`` (re-exported from ``repro.compat``) hides jax-version API
drift; ``snap.to_mesh(mesh)`` re-lands any label snapshot sharded over a
mesh.  The architecture — data flow, backend catalogue, planner policy,
and the sharding schedules — is documented in ``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

from repro.compat import make_mesh
from repro.core.engine import (ReachabilityEngine, DeviceSnapshot,
                               SnapshotUnsupported, UpdateUnsupported,
                               available_backends, update_capabilities,
                               plan_backend, register_backend)
from repro.core.engine import build as build_engine
from repro.core.hypergraph import (Hypergraph, from_edge_lists, compact,
                                   random_hypergraph,
                                   planted_chain_hypergraph,
                                   colocation_hypergraph, paper_figure1)

__all__ = [
    "ReachabilityEngine", "DeviceSnapshot", "SnapshotUnsupported",
    "UpdateUnsupported", "build_engine", "available_backends",
    "update_capabilities", "plan_backend", "register_backend",
    "make_mesh",
    "Hypergraph", "from_edge_lists", "compact", "random_hypergraph",
    "planted_chain_hypergraph", "colocation_hypergraph", "paper_figure1",
]
