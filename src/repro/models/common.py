"""Shared architecture config for the assigned model pool."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One config object spans all five families; family-specific fields
    default to inert values.  Exact per-arch instances live in
    ``repro.configs.<arch>``."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0               # routed-expert hidden dim
    dense_residual: bool = False    # arctic: parallel dense MLP beside MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # experts sharding: "model" (experts over model axis),
    # "model+data" (experts over model, hidden over data — 480B-scale EP),
    # "ffn" (experts replicated, hidden over model)
    expert_sharding: str = "ffn"
    # capacity-based dispatch (Switch-style): expert inputs shrink from
    # [E, tokens, d] to [E, cap, d] with cap ≈ top_k·t·cf/E — the §Perf B
    # lever (dropped-token overflow is the standard trade)
    moe_capacity: bool = False

    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # default d_model // 16
    scan_chunk: int = 256           # chunked associative scan window

    # --- hybrid (recurrentgemma / griffin) ---
    window: int = 0                 # local-attention window
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    d_rnn: int = 0                  # RG-LRU width (default d_model)

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_frames: int = 1500          # stub conv frontend output length

    # --- VLM stub (llava) ---
    vision_dim: int = 0             # >0 activates the patch-embed stub input
    num_patches: int = 576

    # --- attention memory ---
    attn_chunk: int = 1024          # flash-style chunk size (0 = disabled)

    # --- §Perf hillclimb knobs (beyond-paper optimizations) ---
    # replicate K/V heads across TP and broadcast to full heads before the
    # score contraction, so every attention tensor stays head-sharded and
    # the GQA (kv, rep) reshape never forces a GSPMD reshard (see
    # EXPERIMENTS.md §Perf A)
    gqa_repeat: bool = False
    # explicit with_sharding_constraint on block/attention activations
    act_shard: bool = False

    # --- training/runtime knobs ---
    scan_layers: bool = True
    remat: bool = True
    # "full": nothing saveable (min memory, re-runs fwd collectives in bwd);
    # "dots": dots_with_no_batch_dims_saveable (saves matmul outputs — no
    # re-forward, ~25% fewer activation all-reduces; §Perf A iter 2)
    remat_policy: str = "full"
    microbatch: int = 1             # grad-accumulation microbatches
    opt_8bit: bool = False          # blockwise int8 Adam moments
    zero1: bool = True              # shard optimizer state over data axis
    param_dtype: str = "float32"    # master copy dtype
    compute_dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def dtr(self) -> int:
        return self.dt_rank if self.dt_rank else max(1, self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def drnn(self) -> int:
        return self.d_rnn if self.d_rnn else self.d_model

    def n_params(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di, st, dtr = self.d_inner, self.ssm_state, self.dtr
            per = (d * 2 * di + self.d_conv * di + di * (dtr + 2 * st)
                   + dtr * di + di * st + di + di * d)
            return self.n_layers * per + emb
        if self.family == "moe":
            routed = self.n_experts * 3 * d * self.moe_d_ff
            shared = self.n_shared_experts * 3 * d * self.moe_d_ff
            dense = 3 * d * f if self.dense_residual else 0
            router = d * self.n_experts
            return self.n_layers * (attn + routed + shared + dense + router) + emb
        if self.family == "hybrid":
            pat = self.block_pattern or ("rec", "rec", "attn")
            n_attn = sum(1 for i in range(self.n_layers)
                         if pat[i % len(pat)] == "attn")
            n_rec = self.n_layers - n_attn
            rec = 2 * d * self.drnn + 2 * self.drnn * self.drnn // self.drnn \
                + self.drnn * d + 4 * self.drnn  # proj + gates + out
            rec = 3 * d * self.drnn + 4 * self.drnn
            mlp = 3 * d * f
            return n_attn * (attn + mlp) + n_rec * (rec + mlp) + emb
        if self.family == "encdec":
            enc = self.enc_layers * (attn + 2 * d * f)
            dec = self.n_layers * (2 * attn + 2 * d * f)
            return enc + dec + emb
        # dense / vlm
        mlp = 3 * d * f
        extra = self.vision_dim * d + d * d if self.vision_dim else 0
        return self.n_layers * (attn + mlp) + emb + extra

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k + shared + dense)."""
        if self.family != "moe":
            return self.n_params()
        d, f = self.d_model, self.d_ff
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
        act = self.top_k * 3 * d * self.moe_d_ff \
            + self.n_shared_experts * 3 * d * self.moe_d_ff \
            + (3 * d * f if self.dense_residual else 0) \
            + d * self.n_experts
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + act) + emb
