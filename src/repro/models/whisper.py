"""Whisper-large-v3 backbone (audio family): encoder-decoder transformer.

Per the assignment the modality frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings [B, enc_frames, d_model] (the two
conv1d layers + log-mel stage are not modeled).  Positions are sinusoidal
(so arbitrary decoder lengths lower — whisper's learned 448-position table
would cap the 32k cells; noted in DESIGN.md §Arch-applicability).

Decoder layers: self-attention (causal) + cross-attention over the
encoder states + GELU MLP; pre-LayerNorm like the original.  Decode path
precomputes the cross K/V once (``prefill_cross``) and carries only the
self-attention cache.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ArchConfig
from . import layers as L

Params = Dict[str, Any]

__all__ = ["WhisperModel"]


def _init_enc_block(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rms(cfg.d_model), "attn": L.init_attention(k1, cfg),
            "ln2": L.init_rms(cfg.d_model),
            "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, kind="gelu")}


def _enc_block_specs(cfg: ArchConfig) -> Params:
    return {"ln1": L.rms_specs(), "attn": L.attention_specs(cfg),
            "ln2": L.rms_specs(), "mlp": L.mlp_specs(kind="gelu")}


def _enc_block_apply(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = x + L.attention_apply(p["attn"], cfg, L.rms_norm(p["ln1"], x, cfg.norm_eps),
                              causal=False, use_rope=False)
    return x + L.mlp_apply(p["mlp"], L.rms_norm(p["ln2"], x, cfg.norm_eps),
                           kind="gelu")


def _init_dec_block(key, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.init_rms(cfg.d_model), "self_attn": L.init_attention(k1, cfg),
            "ln2": L.init_rms(cfg.d_model), "cross_attn": L.init_attention(k2, cfg),
            "ln3": L.init_rms(cfg.d_model),
            "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, kind="gelu")}


def _dec_block_specs(cfg: ArchConfig) -> Params:
    return {"ln1": L.rms_specs(), "self_attn": L.attention_specs(cfg),
            "ln2": L.rms_specs(), "cross_attn": L.attention_specs(cfg),
            "ln3": L.rms_specs(), "mlp": L.mlp_specs(kind="gelu")}


def _dec_block_apply(p: Params, cfg: ArchConfig, x: jax.Array,
                     enc: jax.Array) -> jax.Array:
    x = x + L.attention_apply(p["self_attn"], cfg,
                              L.rms_norm(p["ln1"], x, cfg.norm_eps),
                              causal=True, use_rope=False)
    x = x + L.attention_apply(p["cross_attn"], cfg,
                              L.rms_norm(p["ln2"], x, cfg.norm_eps),
                              kv_x=enc, use_rope=False)
    return x + L.mlp_apply(p["mlp"], L.rms_norm(p["ln3"], x, cfg.norm_eps),
                           kind="gelu")


class WhisperModel:
    """Enc-dec backbone; inputs are (frames [B,F,D] stub, tokens [B,S])."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def init(self, key) -> Params:
        cfg = self.cfg
        kE, kD, kT = jax.random.split(key, 3)
        return {
            "embed": jax.random.normal(kT, (cfg.vocab, cfg.d_model),
                                       jnp.float32) * 0.02,
            "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(
                jax.random.split(kE, cfg.enc_layers)),
            "enc_ln": L.init_rms(cfg.d_model),
            "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(
                jax.random.split(kD, cfg.n_layers)),
            "dec_ln": L.init_rms(cfg.d_model),
        }

    def param_specs(self) -> Params:
        cfg = self.cfg
        enc = jax.tree.map(lambda s: P(None, *s), _enc_block_specs(cfg),
                           is_leaf=lambda s: isinstance(s, P))
        dec = jax.tree.map(lambda s: P(None, *s), _dec_block_specs(cfg),
                           is_leaf=lambda s: isinstance(s, P))
        # whisper's 51866-token vocab does not divide the 16-way model
        # axis (input shardings must tile exactly), so the embedding
        # shards on d_model instead; the tied head's contraction then
        # reduces over the sharded feature dim (one small all-reduce).
        return {"embed": P(None, "model"), "enc_blocks": enc,
                "enc_ln": L.rms_specs(), "dec_blocks": dec,
                "dec_ln": L.rms_specs()}

    # -- encoder -------------------------------------------------------------
    def encode(self, params: Params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        pos = L.sinusoidal_positions(jnp.arange(frames.shape[1]), cfg.d_model)
        x = frames.astype(dt) + pos[None].astype(dt)
        block = functools.partial(_enc_block_apply, cfg=cfg)
        if cfg.remat:
            block = jax.checkpoint(block, policy=L.remat_policy(cfg))

        def scan_fn(h, lp):
            return block(lp, x=h), None

        x, _ = jax.lax.scan(scan_fn, x, params["enc_blocks"])
        return L.rms_norm(params["enc_ln"], x, cfg.norm_eps)

    # -- decoder full-sequence -------------------------------------------------
    def apply(self, params: Params, tokens: jax.Array,
              frames: jax.Array) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        enc = self.encode(params, frames)
        dt = jnp.dtype(cfg.compute_dtype)
        s = tokens.shape[1]
        pos = L.sinusoidal_positions(jnp.arange(s), cfg.d_model)
        x = params["embed"][tokens].astype(dt) + pos[None].astype(dt)
        block = functools.partial(_dec_block_apply, cfg=cfg)
        if cfg.remat:
            block = jax.checkpoint(block, policy=L.remat_policy(cfg))

        def scan_fn(h, lp):
            return block(lp, x=h, enc=enc), None

        x, _ = jax.lax.scan(scan_fn, x, params["dec_blocks"])
        x = L.rms_norm(params["dec_ln"], x, cfg.norm_eps)
        return x @ params["embed"].astype(x.dtype).T, jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        logits, aux = self.apply(params, batch["tokens"], batch["frames"])
        return L.cross_entropy_loss(logits, batch["labels"], self.cfg.vocab) + aux

    # -- decode ----------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        kv, hd = cfg.n_kv_heads, cfg.hd
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype),
            "cross_k": jnp.zeros((cfg.n_layers, batch, cfg.enc_frames, kv, hd), dtype),
            "cross_v": jnp.zeros((cfg.n_layers, batch, cfg.enc_frames, kv, hd), dtype),
        }

    def cache_specs(self, long_ctx: bool = False) -> Params:
        sspec = (P(None, None, ("data", "model"), None, None) if long_ctx
                 else P(None, "data", "model", None, None))
        cspec = P(None, None if long_ctx else "data", None, None, None)
        return {"k": sspec, "v": sspec, "cross_k": cspec, "cross_v": cspec}

    def prefill_cross(self, params: Params, cache: Params,
                      frames: jax.Array) -> Params:
        """Precompute per-layer cross K/V from the encoder output."""
        cfg = self.cfg
        enc = self.encode(params, frames)
        b, f = enc.shape[:2]

        def one_layer(lp):
            ca = lp["cross_attn"]
            k = L.dense_apply(ca["wk"], enc).reshape(b, f, cfg.n_kv_heads, cfg.hd)
            v = L.dense_apply(ca["wv"], enc).reshape(b, f, cfg.n_kv_heads, cfg.hd)
            return k, v

        ks, vs = jax.vmap(one_layer)(params["dec_blocks"])
        return dict(cache, cross_k=ks.astype(cache["cross_k"].dtype),
                    cross_v=vs.astype(cache["cross_v"].dtype))

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, Params]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        pos_emb = L.sinusoidal_positions(pos[None], cfg.d_model)
        x = params["embed"][tokens].astype(dt) + pos_emb[None].astype(dt)

        def scan_fn(h, inp):
            lp, ck, cv, xk, xv = inp
            a, ck2, cv2 = L.attention_decode(lp["self_attn"], cfg,
                                             L.rms_norm(lp["ln1"], h, cfg.norm_eps),
                                             ck, cv, pos, use_rope=False)
            h = h + a
            c, _, _ = L.attention_decode(lp["cross_attn"], cfg,
                                         L.rms_norm(lp["ln2"], h, cfg.norm_eps),
                                         xk, xv, pos, use_rope=False,
                                         update_cache=False,
                                         causal_mask=False)
            h = h + c
            h = h + L.mlp_apply(lp["mlp"], L.rms_norm(lp["ln3"], h, cfg.norm_eps),
                                kind="gelu")
            return h, (ck2, cv2)

        x, (ks, vs) = jax.lax.scan(scan_fn, x,
                                   (params["dec_blocks"], cache["k"],
                                    cache["v"], cache["cross_k"],
                                    cache["cross_v"]))
        x = L.rms_norm(params["dec_ln"], x, cfg.norm_eps)
        logits = x @ params["embed"].astype(x.dtype).T
        return logits, dict(cache, k=ks, v=vs)
