"""LM substrate: the assigned architecture pool (DESIGN.md §4)."""
from .common import ArchConfig
from .registry import build_model
from .transformer import TransformerLM
from .mamba import MambaLM
from .rglru import GriffinLM
from .whisper import WhisperModel
from . import layers

__all__ = ["ArchConfig", "build_model", "TransformerLM", "MambaLM",
           "GriffinLM", "WhisperModel", "layers"]
