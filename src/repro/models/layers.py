"""Model building blocks: norms, rotary, GQA attention, MLPs, MoE.

Conventions:
  * params are plain nested dicts of jnp arrays; every ``init_*`` has a
    matching ``*_specs`` returning an identical pytree of PartitionSpec
    (model-parallel over the ``model`` mesh axis).
  * ``apply`` functions are pure; compute dtype is the caller's (bf16),
    master params f32 are cast at entry.
  * attention supports GQA (kv heads broadcast), optional qkv bias
    (qwen2), optional per-head qk RMSNorm (qwen3), sliding windows
    (recurrentgemma), cross-attention (whisper), and a one-token decode
    path against a (possibly sequence-sharded) KV cache.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .common import ArchConfig

__all__ = [
    "rms_norm", "init_rms", "rms_specs", "rope_cos_sin", "apply_rope",
    "init_dense", "dense_specs", "init_attention", "attention_specs",
    "attention_apply", "attention_decode", "init_mlp", "mlp_specs",
    "mlp_apply", "init_moe", "moe_specs", "moe_apply", "cross_entropy_loss",
    "sinusoidal_positions",
]

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# sharding-constraint helpers (§Perf knobs)
# ---------------------------------------------------------------------------

import contextlib
import contextvars

_MESH_VAR: "contextvars.ContextVar" = contextvars.ContextVar(
    "repro_constraint_mesh", default=None)


@contextlib.contextmanager
def sharding_mesh(mesh):
    """Make ``mesh`` visible to ``constrain`` during tracing (jax 0.8
    requires concrete NamedShardings for with_sharding_constraint)."""
    tok = _MESH_VAR.set(mesh)
    try:
        yield
    finally:
        _MESH_VAR.reset(tok)


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint against the threaded mesh; "batch" expands
    to the mesh's ('pod','data') axes; no-op when no mesh is threaded
    (single-device tests)."""
    mesh = _MESH_VAR.get()
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    names = tuple(mesh.axis_names)
    batch = tuple(a for a in ("pod", "data") if a in names) or None
    entries = tuple(batch if s == "batch" else
                    (s if (s is None or s in names) else None)
                    for s in spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rms(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_specs() -> Params:
    return {"scale": P(None)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary / sinusoidal positions
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: jax.Array, hd: int, theta: float,
                 dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """positions [...,] -> cos/sin [..., hd/2]."""
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., S, H, hd]; cos/sin [..., S, hd/2] (broadcast over heads)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def sinusoidal_positions(positions: jax.Array, d: int) -> jax.Array:
    """Whisper-style sinusoidal embedding for given positions [...,] ->
    [..., d].  Built from iota in-graph (no baked constants)."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = positions[..., None].astype(jnp.float32) / (10000 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# dense projection
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, bias: bool = False,
               scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense_specs(spec_in, spec_out, bias: bool = False) -> Params:
    p = {"w": P(spec_in, spec_out)}
    if bias:
        p["b"] = P(spec_out)
    return p


def dense_apply(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig) -> Params:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, cfg.n_heads * hd, cfg.qkv_bias),
        "wk": init_dense(ks[1], d, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "wv": init_dense(ks[2], d, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "wo": init_dense(ks[3], cfg.n_heads * hd, d, False),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms(hd)
        p["k_norm"] = init_rms(hd)
    return p


def attention_specs(cfg: ArchConfig) -> Params:
    p = {
        "wq": dense_specs(None, "model", cfg.qkv_bias),
        "wk": dense_specs(None, "model", cfg.qkv_bias),
        "wv": dense_specs(None, "model", cfg.qkv_bias),
        "wo": dense_specs("model", None, False),
    }
    if cfg.qk_norm:
        p["q_norm"] = rms_specs()
        p["k_norm"] = rms_specs()
    return p


def _qkv(p: Params, cfg: ArchConfig, x: jax.Array, kv_x: Optional[jax.Array],
         positions: Optional[jax.Array], use_rope: bool):
    b, s = x.shape[:2]
    hd = cfg.hd
    src = x if kv_x is None else kv_x
    q = dense_apply(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = dense_apply(p["wk"], src).reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    v = dense_apply(p["wv"], src).reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if use_rope:
        pos = positions if positions is not None else jnp.arange(s)[None, :]
        cos, sin = rope_cos_sin(pos, hd, cfg.rope_theta, x.dtype)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: Optional[jax.Array],
          n_rep: int) -> jax.Array:
    """q [b,sq,h,hd], k/v [b,sk,kv,hd]; GQA via reshape to groups.
    Softmax in f32; mask is additive (0 / -inf), broadcast [b?,1?,sq,sk]."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    q = q.reshape(b, sq, kv, n_rep, hd)
    scores = jnp.einsum("bqkrh,bskh->bkrqs", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(hd)
    if mask is not None:
        scores = scores + mask[:, None, None, :, :]
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", w, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, n_rep: int, *,
                  causal: bool, window: int, chunk_q: int,
                  chunk_k: int, shard_heads: bool = False) -> jax.Array:
    """Flash-style online-softmax attention, double-chunked over q and kv.

    Bounds the transient score block to [b, kv, r, cq, ck] f32 regardless
    of sequence length — the substrate that makes the 32k-prefill dry-run
    cells fit (DESIGN §5).  Pure JAX (scan over kv chunks inside a map
    over q chunks); differentiates for training.
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    assert sq % chunk_q == 0 and sk % chunk_k == 0, (sq, sk, chunk_q, chunk_k)
    nq, nk = sq // chunk_q, sk // chunk_k
    qs = q.reshape(b, nq, chunk_q, kv, n_rep, hd).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(b, nk, chunk_k, kv, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nk, chunk_k, kv, hd).transpose(1, 0, 3, 2, 4)
    if shard_heads:
        # §Perf A iter 3: pin the kv-group dim (= full heads when
        # gqa_repeat) to `model` through the chunk transposes so the bwd
        # pass never falls back to gather-all-heads.
        qs = constrain(qs, None, "batch", "model", None, None, None)
        ks = constrain(ks, None, "batch", "model", None, None)
        vs = constrain(vs, None, "batch", "model", None, None)
    scale = 1.0 / np.sqrt(hd)

    def per_q(args):
        qi, qblk = args                     # qblk [b, kv, r, cq, hd]

        def step(carry, kin):
            ki, kblk, vblk = kin            # kblk/vblk [b, kv, ck, hd]
            m, l, acc = carry
            s_ = jnp.einsum("bkrqh,bksh->bkrqs", qblk, kblk,
                            preferred_element_type=jnp.float32) * scale
            qpos = qi * chunk_q + jnp.arange(chunk_q)[:, None]
            kpos = ki * chunk_k + jnp.arange(chunk_k)[None, :]
            ok = jnp.ones((chunk_q, chunk_k), bool)
            if causal:
                ok &= kpos <= qpos
            if window:
                ok &= kpos > qpos - window
            s_ = jnp.where(ok[None, None, None], s_, -jnp.inf)
            m_new = jnp.maximum(m, s_.max(-1))
            # fully-masked prefixes leave m_new = -inf; exp(-inf - -inf)
            # is NaN — a finite stand-in makes every exp() collapse to 0
            # (and m = -inf implies l = acc = 0, so corr = 0 is exact)
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p_ = jnp.exp(s_ - m_safe[..., None])
            corr = jnp.exp(m - m_safe)
            l_new = l * corr + p_.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkrqs,bksh->bkrqh", p_.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            if shard_heads:
                m_new = constrain(m_new, "batch", "model", None, None)
                l_new = constrain(l_new, "batch", "model", None, None)
                acc_new = constrain(acc_new, "batch", "model", None, None, None)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, n_rep, chunk_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv, n_rep, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, kv, n_rep, chunk_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      (jnp.arange(nk), ks, vs))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    outs = jax.lax.map(per_q, (jnp.arange(nq), qs))   # [nq, b, kv, r, cq, hd]
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, hd)


def attention_apply(p: Params, cfg: ArchConfig, x: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    kv_x: Optional[jax.Array] = None,
                    positions: Optional[jax.Array] = None,
                    use_rope: bool = True) -> jax.Array:
    """Full-sequence attention (train / prefill).  ``kv_x`` switches to
    cross-attention (no mask, no rope on cross keys per whisper).
    Long sequences route to the chunked online-softmax path."""
    b, s = x.shape[:2]
    cross = kv_x is not None
    q, k, v = _qkv(p, cfg, x, kv_x, positions, use_rope and not cross)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    if cfg.gqa_repeat and n_rep > 1:
        # §Perf A: replicate K/V over TP and broadcast kv→H heads locally
        # so the (kv, rep) score batch dims never split a sharded head dim.
        q = constrain(q, "batch", None, "model", None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
        bk_, sk_, kvh, hd_ = k.shape
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             (bk_, sk_, kvh, n_rep, hd_)).reshape(
                                 bk_, sk_, kvh * n_rep, hd_)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             (bk_, sk_, kvh, n_rep, hd_)).reshape(
                                 bk_, sk_, kvh * n_rep, hd_)
        n_rep = 1
    sk = k.shape[1]
    chunk = cfg.attn_chunk
    if not cross and chunk and s > chunk and s % chunk == 0 and sk % chunk == 0:
        out = _sdpa_chunked(q, k, v, n_rep, causal=causal, window=window,
                            chunk_q=chunk, chunk_k=chunk,
                            shard_heads=cfg.gqa_repeat and cfg.act_shard)
    else:
        mask = None
        if not cross and causal:
            qi = jnp.arange(s)[:, None]
            ki = jnp.arange(sk)[None, :]
            ok = ki <= qi
            if window:
                ok &= ki > qi - window
            mask = jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)[None]
            mask = jnp.broadcast_to(mask, (b, s, sk))
        out = _sdpa(q, k, v, mask, n_rep)
    if cfg.act_shard:
        out = constrain(out, "batch", None, "model", None)
    return dense_apply(p["wo"], out.reshape(b, s, cfg.n_heads * cfg.hd))


def attention_decode(p: Params, cfg: ArchConfig, x: jax.Array,
                     cache_k: jax.Array, cache_v: jax.Array, pos: jax.Array,
                     *, window: int = 0, use_rope: bool = True,
                     update_cache: bool = True, slot=None,
                     causal_mask: bool = True
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode.  x [b, 1, d]; cache [b, S, kv, hd]; pos [] int.
    Returns (out [b,1,d], new_k, new_v).  With the cache sharded on S the
    softmax reductions lower to tiny [b,h]-sized all-reduces (DESIGN §5).
    ``slot`` enables a rolling-window cache: the new K/V is written at
    ``slot`` (= pos % S) while RoPE still uses the absolute ``pos`` — the
    ``ki <= pos`` mask is then exact for both warmup (pos < S) and steady
    state (all S slots live).  For cross-attention set
    update_cache=False (static encoder cache)."""
    b = x.shape[0]
    hd = cfg.hd
    q, k, v = _qkv(p, cfg, x, None, pos[None, None] if use_rope else None,
                   use_rope)
    write_at = pos if slot is None else slot
    if update_cache:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, write_at, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, write_at, 0, 0))
    sk = cache_k.shape[1]
    ki = jnp.arange(sk)[None, :]
    ok = (ki <= pos) if causal_mask else jnp.ones((1, sk), bool)
    if window and slot is None and causal_mask:
        ok &= ki > pos - window
    mask = jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)
    mask = jnp.broadcast_to(mask, (b, 1, sk))
    out = _sdpa(q, cache_k.astype(x.dtype), cache_v.astype(x.dtype), mask,
                cfg.n_heads // cfg.n_kv_heads)
    return dense_apply(p["wo"], out.reshape(b, 1, cfg.n_heads * hd)), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, kind: str = "swiglu") -> Params:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"gate": init_dense(ks[0], d, f), "up": init_dense(ks[1], d, f),
                "down": init_dense(ks[2], f, d)}
    return {"fc1": init_dense(ks[0], d, f, bias=True),
            "fc2": init_dense(ks[1], f, d, bias=True)}


def mlp_specs(kind: str = "swiglu") -> Params:
    if kind == "swiglu":
        return {"gate": dense_specs(None, "model"), "up": dense_specs(None, "model"),
                "down": dense_specs("model", None)}
    return {"fc1": dense_specs(None, "model", bias=True),
            "fc2": dense_specs("model", None, bias=True)}


def mlp_apply(p: Params, x: jax.Array, kind: str = "swiglu") -> jax.Array:
    if kind == "swiglu":
        return dense_apply(p["down"],
                           jax.nn.silu(dense_apply(p["gate"], x)) *
                           dense_apply(p["up"], x))
    return dense_apply(p["fc2"], jax.nn.gelu(dense_apply(p["fc1"], x)))


# ---------------------------------------------------------------------------
# MoE (top-k routing, one-hot dispatch/combine einsums — MXU friendly)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ArchConfig) -> Params:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": init_dense(ks[0], d, e, scale=scale),
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) / np.sqrt(f),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared_experts * f)
    if cfg.dense_residual:
        p["dense"] = init_mlp(ks[5], d, cfg.d_ff)
    return p


def moe_specs(cfg: ArchConfig) -> Params:
    if cfg.expert_sharding == "model":
        es = P("model", None, None)
        es_d = P("model", None, None)
    elif cfg.expert_sharding == "model+data":
        es = P("model", None, "data")
        es_d = P("model", "data", None)
    else:                                  # "ffn": replicate experts
        es = P(None, None, "model")
        es_d = P(None, "model", None)
    p = {"router": dense_specs(None, None),
         "w_gate": es, "w_up": es, "w_down": es_d}
    if cfg.n_shared_experts:
        p["shared"] = mlp_specs()
    if cfg.dense_residual:
        p["dense"] = mlp_specs()
    return p


def moe_apply(p: Params, cfg: ArchConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k routing with one-hot dispatch einsums (no
    scatter — the standard TPU MoE formulation).  Two dispatch modes:

    * dense (default, paper-faithful capacity-free): expert inputs are
      [E, t, d] — exact, but the dispatch tensor scales with E·t.
    * capacity (cfg.moe_capacity, §Perf B): Switch-style [E, cap, d] with
      cap = ⌈top_k·t·capacity_factor/E⌉; overflow tokens drop (standard
      trade — the router aux loss keeps loads balanced).

    Returns (out, aux_loss).
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = dense_apply(p["router"], xt.astype(jnp.float32))      # [t, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, cfg.top_k)               # [t, k]
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=x.dtype)     # [t, k, E]

    # aux load-balancing loss (Switch-style)
    density = (onehot.sum(1) > 0).astype(jnp.float32).mean(0)      # [E]
    density_proxy = probs.mean(0)
    aux = (density * density_proxy).sum() * (cfg.n_experts ** 2) \
        * cfg.router_aux_weight / cfg.top_k

    if cfg.moe_capacity:
        cap = int(np.ceil(cfg.top_k * t * cfg.capacity_factor
                          / cfg.n_experts))
        cap = max(cap, 1)
        flat = onehot.reshape(t * cfg.top_k, cfg.n_experts)        # slot-major
        pos = (jnp.cumsum(flat, axis=0) - flat)                    # arrival idx
        pos = (pos * flat).sum(-1).reshape(t, cfg.top_k)           # [t, k]
        keep = (pos < cap).astype(x.dtype)
        pos_oh = jax.nn.one_hot(pos, cap, dtype=x.dtype)           # [t, k, cap]
        # [t, k, E, cap] one-hot dispatch (the Switch dispatch tensor)
        disp = onehot[..., None] * pos_oh[:, :, None, :] * keep[..., None, None]
        comb = disp * gate_vals[..., None, None].astype(x.dtype)
        # expert-parallel layout: E over `model`, ffn hidden over `data`
        # when expert_sharding="model+data" — pin the activations so GSPMD
        # keeps the (17.8 GB/layer) expert weights resident instead of
        # gathering them (§Perf B iter 2)
        e_ax = "model" if cfg.expert_sharding.startswith("model") else None
        f_ax = "data" if cfg.expert_sharding == "model+data" else (
            "model" if cfg.expert_sharding == "ffn" else None)
        xin = jnp.einsum("tkec,td->ecd", disp, xt)                 # [E, cap, d]
        if cfg.act_shard:
            xin = constrain(xin, e_ax, None, None)
        hg = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin,
                                    p["w_gate"].astype(x.dtype)))
        hu = jnp.einsum("ecd,edf->ecf", xin, p["w_up"].astype(x.dtype))
        if cfg.act_shard:
            hg = constrain(hg, e_ax, None, f_ax)
            hu = constrain(hu, e_ax, None, f_ax)
        ye = jnp.einsum("ecf,efd->ecd", hg * hu,
                        p["w_down"].astype(x.dtype))
        if cfg.act_shard:
            ye = constrain(ye, e_ax, None, None)
        out = jnp.einsum("tkec,ecd->td", comb, ye)
    else:
        combine = (onehot * gate_vals[..., None].astype(x.dtype)).sum(1)
        dispatch = (onehot.sum(1) > 0).astype(x.dtype)             # [t, E]
        xin = jnp.einsum("te,td->etd", dispatch, xt)
        hg = jax.nn.silu(jnp.einsum("etd,edf->etf", xin,
                                    p["w_gate"].astype(x.dtype)))
        hu = jnp.einsum("etd,edf->etf", xin, p["w_up"].astype(x.dtype))
        ye = jnp.einsum("etf,efd->etd", hg * hu, p["w_down"].astype(x.dtype))
        out = jnp.einsum("etd,te->td", ye, combine)

    if cfg.n_shared_experts:
        out = out + mlp_apply(p["shared"], xt)
    if cfg.dense_residual:
        out = out + mlp_apply(p["dense"], xt)
    return out.reshape(b, s, d), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       vocab: int) -> jax.Array:
    """Mean next-token CE.  One-hot contraction (not gather) so the
    vocab-sharded logits reduce with a single small all-reduce."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, vocab, dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot,
                      preferred_element_type=jnp.float32)
    return (lse - gold).mean()


def remat_policy(cfg: ArchConfig):
    """Map cfg.remat_policy to a jax.checkpoint policy."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable
