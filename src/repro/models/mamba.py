"""Mamba-1 selective SSM (falcon-mamba-7b): attention-free family.

Train path: chunked parallel scan — the selective recurrence
``h_t = Ā_t h_{t-1} + B̄_t x_t`` is a first-order linear recurrence, solved
with ``jax.lax.associative_scan`` *within* fixed-size chunks and a cheap
sequential ``lax.scan`` carrying the boundary state *across* chunks.  The
chunking bounds the materialized [chunk, d_inner, d_state] state tensor
(the full-sequence scan would need seq·d_inner·d_state floats — 2 GB/seq
at 4k context), which is the TPU-memory adaptation of Mamba's
"hardware-aware" fused scan.

Decode path: O(1) recurrent step on (conv window, ssm state) — no KV
cache, which is why this arch owns the ``long_500k`` cell.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ArchConfig
from . import layers as L

Params = Dict[str, Any]

__all__ = ["MambaLM"]


def _init_block(key, cfg: ArchConfig) -> Params:
    d, di, st, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dtr
    ks = jax.random.split(key, 6)
    return {
        "ln": L.init_rms(d),
        "in_proj": L.init_dense(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": L.init_dense(ks[2], di, dtr + 2 * st),
        "dt_proj": L.init_dense(ks[3], dtr, di, bias=True),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None],
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.init_dense(ks[4], di, d),
    }


def _block_specs(cfg: ArchConfig) -> Params:
    return {
        "ln": L.rms_specs(),
        "in_proj": L.dense_specs(None, "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "x_proj": L.dense_specs("model", None),
        "dt_proj": L.dense_specs(None, "model", bias=True),
        "A_log": P("model", None),
        "D": P("model"),
        "out_proj": L.dense_specs("model", None),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv1d.  x [B, S, di]; w [K, di].  ``state`` is the
    trailing K-1 window from the previous segment (decode path)."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(k))
    return out + b.astype(x.dtype)


def _selective_scan_chunked(u: jax.Array, dt: jax.Array, A: jax.Array,
                            Bc: jax.Array, Cc: jax.Array, chunk: int,
                            h0: jax.Array | None = None
                            ) -> Tuple[jax.Array, jax.Array]:
    """u/dt [B,S,di], A [di,st], Bc/Cc [B,S,st] -> (y [B,S,di], h_last).

    Discretize: Ā = exp(dt·A) (per-channel, per-state), B̄x = dt·B·u.
    Within a chunk: associative_scan over (Ā, B̄x) pairs; across chunks:
    sequential carry of the boundary state.
    """
    b, s, di = u.shape
    st = A.shape[1]
    pad = (-s) % chunk
    if pad:
        # dt = 0 discretizes to Ā = 1, B̄x = 0: padded steps are identity
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    s_p = s + pad
    nc = s_p // chunk
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A[None, None])   # [B,S,di,st]
    dBx = (dt * u)[..., None].astype(jnp.float32) * Bc[:, :, None, :]  # [B,S,di,st]
    dA = dA.reshape(b, nc, chunk, di, st).transpose(1, 0, 2, 3, 4)
    dBx = dBx.reshape(b, nc, chunk, di, st).transpose(1, 0, 2, 3, 4)
    Ccs = Cc.reshape(b, nc, chunk, st).transpose(1, 0, 2, 3)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    def chunk_step(h, inp):
        da, dbx, cc = inp                       # [B, chunk, di, st]
        a_acc, b_acc = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        hs = a_acc * h[:, None] + b_acc         # [B, chunk, di, st]
        y = jnp.einsum("bcds,bcs->bcd", hs, cc)
        return hs[:, -1], y

    h = (jnp.zeros((b, di, st), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))
    h, ys = jax.lax.scan(chunk_step, h, (dA, dBx, Ccs))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s_p, di)[:, :s]
    return y.astype(u.dtype), h


def _block_apply(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    di, st, dtr = cfg.d_inner, cfg.ssm_state, cfg.dtr
    res = x
    x = L.rms_norm(p["ln"], x, cfg.norm_eps)
    xz = L.dense_apply(p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = jax.nn.silu(_causal_conv(xs, p["conv_w"], p["conv_b"]))
    proj = L.dense_apply(p["x_proj"], xs)
    dt_r, Bc, Cc = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(L.dense_apply(p["dt_proj"], dt_r).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    y, _ = _selective_scan_chunked(xs, dt, A, Bc.astype(jnp.float32),
                                   Cc.astype(jnp.float32), cfg.scan_chunk)
    y = y + xs * p["D"].astype(xs.dtype)
    y = y * jax.nn.silu(z)
    return res + L.dense_apply(p["out_proj"], y)


def _block_decode(p: Params, cfg: ArchConfig, x: jax.Array, conv_state,
                  ssm_state) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [B, 1, d]; conv_state [B, K-1, di]; ssm_state [B, di, st]."""
    di, st, dtr = cfg.d_inner, cfg.ssm_state, cfg.dtr
    res = x
    x = L.rms_norm(p["ln"], x, cfg.norm_eps)
    xz = L.dense_apply(p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)
    new_conv = jnp.concatenate([conv_state[:, 1:], xs.astype(conv_state.dtype)],
                               axis=1)
    xs = jax.nn.silu(_causal_conv(xs, p["conv_w"], p["conv_b"],
                                  state=conv_state))
    proj = L.dense_apply(p["x_proj"], xs)
    dt_r, Bc, Cc = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(L.dense_apply(p["dt_proj"], dt_r).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A[None, None])                       # [B,1,di,st]
    dBx = (dt * xs.astype(jnp.float32))[..., None] * Bc[:, :, None, :].astype(jnp.float32)
    h = ssm_state.astype(jnp.float32) * dA[:, 0] + dBx[:, 0]          # [B,di,st]
    y = jnp.einsum("bds,bs->bd", h, Cc[:, 0].astype(jnp.float32))[:, None]
    y = y.astype(xs.dtype) + xs * p["D"].astype(xs.dtype)
    y = y * jax.nn.silu(z)
    out = res + L.dense_apply(p["out_proj"], y)
    return out, new_conv, h.astype(ssm_state.dtype)


class MambaLM:
    """falcon-mamba-7b: 64 Mamba-1 blocks, RMSNorm, untied head."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def init(self, key) -> Params:
        cfg = self.cfg
        kE, kH, kB = jax.random.split(key, 3)
        return {
            "embed": jax.random.normal(kE, (cfg.vocab, cfg.d_model),
                                       jnp.float32) * 0.02,
            "ln_f": L.init_rms(cfg.d_model),
            "blocks": jax.vmap(lambda k: _init_block(k, cfg))(
                jax.random.split(kB, cfg.n_layers)),
            "lm_head": L.init_dense(kH, cfg.d_model, cfg.vocab),
        }

    def param_specs(self) -> Params:
        blk = jax.tree.map(lambda s: P(None, *s), _block_specs(self.cfg),
                           is_leaf=lambda s: isinstance(s, P))
        return {"embed": P("model", None), "ln_f": L.rms_specs(),
                "blocks": blk, "lm_head": L.dense_specs(None, "model")}

    def apply(self, params: Params, tokens: jax.Array,
              patch_embeds=None) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
        block = functools.partial(_block_apply, cfg=cfg)
        if cfg.remat:
            block = jax.checkpoint(block, policy=L.remat_policy(cfg))

        def scan_fn(h, lp):
            return block(lp, x=h), None

        x, _ = jax.lax.scan(scan_fn, x, params["blocks"])
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        return L.dense_apply(params["lm_head"], x), jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        logits, aux = self.apply(params, batch["tokens"])
        return L.cross_entropy_loss(logits, batch["labels"], self.cfg.vocab) + aux

    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        return {
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1,
                               cfg.d_inner), dtype),
            "ssm": jnp.zeros((cfg.n_layers, batch, cfg.d_inner,
                              cfg.ssm_state), jnp.float32),
        }

    def cache_specs(self, long_ctx: bool = False) -> Params:
        # state is O(1) in seq — shard the wide d_inner dim over `model`,
        # batch over `data` when present
        bspec = None if long_ctx else "data"
        return {"conv": P(None, bspec, None, "model"),
                "ssm": P(None, bspec, "model", None)}

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, Params]:
        cfg = self.cfg
        x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))

        def scan_fn(h, inp):
            lp, cs, ss = inp
            h2, cs2, ss2 = _block_decode(lp, cfg, h, cs, ss)
            return h2, (cs2, ss2)

        x, (conv, ssm) = jax.lax.scan(scan_fn, x,
                                      (params["blocks"], cache["conv"],
                                       cache["ssm"]))
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        return L.dense_apply(params["lm_head"], x), {"conv": conv, "ssm": ssm}
