"""Model registry: family -> implementation class."""
from __future__ import annotations

from .common import ArchConfig
from .transformer import TransformerLM
from .mamba import MambaLM
from .rglru import GriffinLM
from .whisper import WhisperModel

__all__ = ["build_model"]

_FAMILIES = {
    "dense": TransformerLM,
    "moe": TransformerLM,
    "vlm": TransformerLM,
    "ssm": MambaLM,
    "hybrid": GriffinLM,
    "encdec": WhisperModel,
}


def build_model(cfg: ArchConfig):
    try:
        cls = _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r} for arch {cfg.name}")
    return cls(cfg)
