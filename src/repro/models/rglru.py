"""RecurrentGemma / Griffin hybrid (recurrentgemma-2b): RG-LRU recurrent
blocks + local sliding-window MQA, pattern (rec, rec, attn) repeating.

The RG-LRU recurrence  h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t)
is a diagonal linear recurrence → ``associative_scan`` over the sequence
(state is [B, S, d_rnn] — no d_state blow-up, so no chunking needed).
Local attention (window 2048) keeps the attn blocks sub-quadratic, which
is why this arch runs the ``long_500k`` cell.

Layer driving: the 26-layer stack is grouped into 8 scanned (rec, rec,
attn) groups + an unscanned (rec, rec) tail, keeping the lowered HLO one
group body deep.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ArchConfig
from . import layers as L

Params = Dict[str, Any]

__all__ = ["GriffinLM"]

_C = 8.0   # RG-LRU recurrence sharpness constant (Griffin paper)


# ---------------------------------------------------------------------------
# RG-LRU temporal block
# ---------------------------------------------------------------------------

def _init_rec(key, cfg: ArchConfig) -> Params:
    d, dr = cfg.d_model, cfg.drnn
    ks = jax.random.split(key, 6)
    return {
        "ln": L.init_rms(d),
        "in_x": L.init_dense(ks[0], d, dr),
        "in_gate": L.init_dense(ks[1], d, dr),
        "conv_w": jax.random.normal(ks[2], (4, dr), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "w_a": L.init_dense(ks[3], dr, dr, bias=True),
        "w_i": L.init_dense(ks[4], dr, dr, bias=True),
        "lam": jnp.full((dr,), 4.0, jnp.float32),   # sigmoid(4) ≈ .982 decay
        "out": L.init_dense(ks[5], dr, d),
    }


def _rec_specs(cfg: ArchConfig) -> Params:
    return {
        "ln": L.rms_specs(),
        "in_x": L.dense_specs(None, "model"),
        "in_gate": L.dense_specs(None, "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "w_a": L.dense_specs(None, "model", bias=True),
        "w_i": L.dense_specs(None, "model", bias=True),
        "lam": P("model"),
        "out": L.dense_specs("model", None),
    }


def _causal_conv(x, w, b, state=None):
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(k))
    return out + b.astype(x.dtype)


def _rglru(p: Params, xs: jax.Array, h0: Optional[jax.Array] = None
           ) -> Tuple[jax.Array, jax.Array]:
    """xs [B, S, dr] -> (ys, h_last).  f32 recurrence."""
    r = jax.nn.sigmoid(L.dense_apply(p["w_a"], xs).astype(jnp.float32))
    i = jax.nn.sigmoid(L.dense_apply(p["w_i"], xs).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xs.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    a_acc, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h = h + a_acc * h0[:, None].astype(jnp.float32)
    return h.astype(xs.dtype), h[:, -1]


def _rec_apply(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    res = x
    x = L.rms_norm(p["ln"], x, cfg.norm_eps)
    gate = jax.nn.gelu(L.dense_apply(p["in_gate"], x))
    xs = _causal_conv(L.dense_apply(p["in_x"], x), p["conv_w"], p["conv_b"])
    ys, _ = _rglru(p, xs)
    return res + L.dense_apply(p["out"], ys * gate)


def _rec_decode(p: Params, cfg: ArchConfig, x: jax.Array, conv_state,
                h_state) -> Tuple[jax.Array, jax.Array, jax.Array]:
    res = x
    x = L.rms_norm(p["ln"], x, cfg.norm_eps)
    gate = jax.nn.gelu(L.dense_apply(p["in_gate"], x))
    xin = L.dense_apply(p["in_x"], x)
    new_conv = jnp.concatenate([conv_state[:, 1:], xin.astype(conv_state.dtype)],
                               axis=1)
    xs = _causal_conv(xin, p["conv_w"], p["conv_b"], state=conv_state)
    r = jax.nn.sigmoid(L.dense_apply(p["w_a"], xs).astype(jnp.float32))
    i = jax.nn.sigmoid(L.dense_apply(p["w_i"], xs).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)[:, 0]
    gated = (jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
             * (i[:, 0] * xs[:, 0].astype(jnp.float32)))
    h = a * h_state.astype(jnp.float32) + gated
    ys = h[:, None].astype(xs.dtype)
    return res + L.dense_apply(p["out"], ys * gate), new_conv, h


# ---------------------------------------------------------------------------
# group = (rec, rec, attn), each followed by an MLP
# ---------------------------------------------------------------------------

def _init_group(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 7)
    return {
        "rec1": _init_rec(ks[0], cfg), "mlp1": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff),
        "ln_m1": L.init_rms(cfg.d_model),
        "rec2": _init_rec(ks[2], cfg), "mlp2": L.init_mlp(ks[3], cfg.d_model, cfg.d_ff),
        "ln_m2": L.init_rms(cfg.d_model),
        "ln_a": L.init_rms(cfg.d_model), "attn": L.init_attention(ks[4], cfg),
        "mlp3": L.init_mlp(ks[5], cfg.d_model, cfg.d_ff),
        "ln_m3": L.init_rms(cfg.d_model),
    }


def _group_specs(cfg: ArchConfig) -> Params:
    return {
        "rec1": _rec_specs(cfg), "mlp1": L.mlp_specs(), "ln_m1": L.rms_specs(),
        "rec2": _rec_specs(cfg), "mlp2": L.mlp_specs(), "ln_m2": L.rms_specs(),
        "ln_a": L.rms_specs(), "attn": L.attention_specs(cfg),
        "mlp3": L.mlp_specs(), "ln_m3": L.rms_specs(),
    }


def _mlp_res(p, ln, cfg, x):
    return x + L.mlp_apply(p, L.rms_norm(ln, x, cfg.norm_eps))


def _group_apply(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = _rec_apply(p["rec1"], cfg, x)
    x = _mlp_res(p["mlp1"], p["ln_m1"], cfg, x)
    x = _rec_apply(p["rec2"], cfg, x)
    x = _mlp_res(p["mlp2"], p["ln_m2"], cfg, x)
    x = x + L.attention_apply(p["attn"], cfg,
                              L.rms_norm(p["ln_a"], x, cfg.norm_eps),
                              causal=True, window=cfg.window)
    return _mlp_res(p["mlp3"], p["ln_m3"], cfg, x)


class GriffinLM:
    """recurrentgemma-2b: 26 layers = 8 × (rec, rec, attn) + (rec, rec)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.n_groups = cfg.n_layers // 3
        self.n_tail = cfg.n_layers - 3 * self.n_groups   # trailing rec blocks

    def init(self, key) -> Params:
        cfg = self.cfg
        kE, kB, kT = jax.random.split(key, 3)
        p: Params = {
            "embed": jax.random.normal(kE, (cfg.vocab, cfg.d_model),
                                       jnp.float32) * 0.02,
            "ln_f": L.init_rms(cfg.d_model),
            "groups": jax.vmap(lambda k: _init_group(k, cfg))(
                jax.random.split(kB, self.n_groups)),
        }
        tails = []
        for i, k in enumerate(jax.random.split(kT, self.n_tail)):
            k1, k2 = jax.random.split(k)
            tails.append({"rec": _init_rec(k1, cfg),
                          "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff),
                          "ln_m": L.init_rms(cfg.d_model)})
        p["tail"] = tails
        return p

    def param_specs(self) -> Params:
        cfg = self.cfg
        grp = jax.tree.map(lambda s: P(None, *s), _group_specs(cfg),
                           is_leaf=lambda s: isinstance(s, P))
        tail = [{"rec": _rec_specs(cfg), "mlp": L.mlp_specs(),
                 "ln_m": L.rms_specs()} for _ in range(self.n_tail)]
        return {"embed": P("model", None), "ln_f": L.rms_specs(),
                "groups": grp, "tail": tail}

    def apply(self, params: Params, tokens: jax.Array,
              patch_embeds=None) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
        group = functools.partial(_group_apply, cfg=cfg)
        if cfg.remat:
            group = jax.checkpoint(group, policy=L.remat_policy(cfg))

        def scan_fn(h, gp):
            return group(gp, x=h), None

        x, _ = jax.lax.scan(scan_fn, x, params["groups"])
        for tp in params["tail"]:
            x = _rec_apply(tp["rec"], cfg, x)
            x = _mlp_res(tp["mlp"], tp["ln_m"], cfg, x)
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        # gemma-style tied head
        return x @ params["embed"].astype(x.dtype).T, jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        logits, aux = self.apply(params, batch["tokens"])
        return L.cross_entropy_loss(logits, batch["labels"], self.cfg.vocab) + aux

    # -- decode --------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        w = min(cfg.window, max_seq)
        g, dr = self.n_groups, cfg.drnn
        return {
            "conv1": jnp.zeros((g, batch, 3, dr), dtype),
            "h1": jnp.zeros((g, batch, dr), jnp.float32),
            "conv2": jnp.zeros((g, batch, 3, dr), dtype),
            "h2": jnp.zeros((g, batch, dr), jnp.float32),
            "k": jnp.zeros((g, batch, w, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((g, batch, w, cfg.n_kv_heads, cfg.hd), dtype),
            "tail_conv": jnp.zeros((max(self.n_tail, 1), batch, 3, dr), dtype),
            "tail_h": jnp.zeros((max(self.n_tail, 1), batch, dr), jnp.float32),
        }

    def cache_specs(self, long_ctx: bool = False) -> Params:
        b = None if long_ctx else "data"
        return {
            "conv1": P(None, b, None, "model"), "h1": P(None, b, "model"),
            "conv2": P(None, b, None, "model"), "h2": P(None, b, "model"),
            "k": P(None, b, None, None, None), "v": P(None, b, None, None, None),
            "tail_conv": P(None, b, None, "model"), "tail_h": P(None, b, "model"),
        }

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, Params]:
        """Local attention uses a rolling window cache: position pos lands
        in slot pos % window, and the mask covers the last `window` steps."""
        cfg = self.cfg
        x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
        w = cache["k"].shape[2]
        slot = pos % w

        def group_step(h, inp):
            gp, c1, h1, c2, h2, ck, cv = inp
            h_, c1n, h1n = _rec_decode(gp["rec1"], cfg, h, c1, h1)
            h_ = _mlp_res(gp["mlp1"], gp["ln_m1"], cfg, h_)
            h_, c2n, h2n = _rec_decode(gp["rec2"], cfg, h_, c2, h2)
            h_ = _mlp_res(gp["mlp2"], gp["ln_m2"], cfg, h_)
            a, ckn, cvn = L.attention_decode(
                gp["attn"], cfg, L.rms_norm(gp["ln_a"], h_, cfg.norm_eps),
                ck, cv, pos, slot=slot)
            h_ = h_ + a
            h_ = _mlp_res(gp["mlp3"], gp["ln_m3"], cfg, h_)
            return h_, (c1n, h1n, c2n, h2n, ckn, cvn)

        x, (c1, h1, c2, h2, ks, vs) = jax.lax.scan(
            group_step, x, (params["groups"], cache["conv1"], cache["h1"],
                            cache["conv2"], cache["h2"], cache["k"],
                            cache["v"]))
        tail_conv, tail_h = [], []
        for i, tp in enumerate(params["tail"]):
            x, cn, hn = _rec_decode(tp["rec"], cfg, x, cache["tail_conv"][i],
                                    cache["tail_h"][i])
            x = _mlp_res(tp["mlp"], tp["ln_m"], cfg, x)
            tail_conv.append(cn)
            tail_h.append(hn)
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        logits = x @ params["embed"].astype(x.dtype).T
        new_cache = {"conv1": c1, "h1": h1, "conv2": c2, "h2": h2,
                     "k": ks, "v": vs,
                     "tail_conv": (jnp.stack(tail_conv) if tail_conv
                                   else cache["tail_conv"]),
                     "tail_h": (jnp.stack(tail_h) if tail_h
                                else cache["tail_h"])}
        return logits, new_cache
