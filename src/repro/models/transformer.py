"""Decoder-only transformer LM covering the dense / moe / vlm families.

Layer stacks are parameter-stacked ([L, ...] leading dim) and driven by
``lax.scan`` so the lowered HLO is one layer body regardless of depth
(compile-time and HLO-size control for the 512-device dry-run), with
optional ``jax.checkpoint`` remat around the block body.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ArchConfig
from . import layers as L

Params = Dict[str, Any]

__all__ = ["TransformerLM"]


def _init_block(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"ln1": L.init_rms(cfg.d_model), "attn": L.init_attention(k1, cfg),
         "ln2": L.init_rms(cfg.d_model)}
    if cfg.family == "moe":
        p["moe"] = L.init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff)
    return p


def _block_specs(cfg: ArchConfig) -> Params:
    p = {"ln1": L.rms_specs(), "attn": L.attention_specs(cfg),
         "ln2": L.rms_specs()}
    if cfg.family == "moe":
        p["moe"] = L.moe_specs(cfg)
    else:
        p["mlp"] = L.mlp_specs()
    return p


def _block_apply(p: Params, cfg: ArchConfig, x: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    if cfg.act_shard:
        x = L.constrain(x, "batch", None, None)
    h = x + L.attention_apply(p["attn"], cfg, L.rms_norm(p["ln1"], x, cfg.norm_eps),
                              causal=True, window=cfg.window)
    aux = jnp.zeros((), jnp.float32)
    y = L.rms_norm(p["ln2"], h, cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = L.moe_apply(p["moe"], cfg, y)
    else:
        y = L.mlp_apply(p["mlp"], y)
    out = h + y
    if cfg.act_shard:
        out = L.constrain(out, "batch", None, None)
    return out, aux


def _block_decode(p: Params, cfg: ArchConfig, x: jax.Array, ck, cv, pos
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    a, ck, cv = L.attention_decode(p["attn"], cfg,
                                   L.rms_norm(p["ln1"], x, cfg.norm_eps),
                                   ck, cv, pos, window=cfg.window)
    h = x + a
    y = L.rms_norm(p["ln2"], h, cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = L.moe_apply(p["moe"], cfg, y)
    else:
        y = L.mlp_apply(p["mlp"], y)
    return h + y, ck, cv


class TransformerLM:
    """Dense / MoE / VLM decoder LM (llava, qwen*, minitron, arctic, ...)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- params ------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        kE, kH, kB, kV = jax.random.split(key, 4)
        p: Params = {
            "embed": jax.random.normal(kE, (cfg.vocab, cfg.d_model),
                                       jnp.float32) * 0.02,
            "ln_f": L.init_rms(cfg.d_model),
            "blocks": jax.vmap(lambda k: _init_block(k, cfg))(
                jax.random.split(kB, cfg.n_layers)),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = L.init_dense(kH, cfg.d_model, cfg.vocab)
        if cfg.vision_dim:
            kv1, kv2 = jax.random.split(kV)
            p["vision_proj"] = {
                "fc1": L.init_dense(kv1, cfg.vision_dim, cfg.d_model, bias=True),
                "fc2": L.init_dense(kv2, cfg.d_model, cfg.d_model, bias=True),
            }
        return p

    def param_specs(self) -> Params:
        cfg = self.cfg
        blk = jax.tree.map(lambda s: P(None, *s), _block_specs(cfg),
                           is_leaf=lambda s: isinstance(s, P))
        p: Params = {"embed": P("model", None), "ln_f": L.rms_specs(),
                     "blocks": blk}
        if not cfg.tie_embeddings:
            p["lm_head"] = L.dense_specs(None, "model")
        if cfg.vision_dim:
            p["vision_proj"] = {"fc1": L.dense_specs(None, "model", bias=True),
                                "fc2": L.dense_specs("model", None, bias=True)}
        return p

    # -- embedding helpers ---------------------------------------------------
    def _embed(self, params: Params, tokens: jax.Array,
               patch_embeds: Optional[jax.Array]) -> jax.Array:
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        x = params["embed"][tokens].astype(dt)
        if cfg.vision_dim and patch_embeds is not None:
            vp = params["vision_proj"]
            pe = L.dense_apply(vp["fc2"], jax.nn.gelu(
                L.dense_apply(vp["fc1"], patch_embeds.astype(dt))))
            x = jnp.concatenate([pe, x], axis=1)     # patches prepended
        return x

    def _head(self, params: Params, x: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            return x @ params["embed"].astype(x.dtype).T
        return L.dense_apply(params["lm_head"], x)

    # -- full-sequence forward ----------------------------------------------
    def apply(self, params: Params, tokens: jax.Array,
              patch_embeds: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
        """-> (logits [B, S, V], aux_loss)."""
        cfg = self.cfg
        x = self._embed(params, tokens, patch_embeds)

        block = functools.partial(_block_apply, cfg=cfg)
        if cfg.remat:
            block = jax.checkpoint(block, policy=L.remat_policy(cfg))

        def scan_fn(carry, layer_p):
            h, aux = carry
            h2, a = block(layer_p, x=h)
            return (h2, aux + a), None

        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                                       params["blocks"])
        else:
            aux = jnp.zeros((), jnp.float32)
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["blocks"])
                x, a = block(lp, x=x)
                aux = aux + a
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        return self._head(params, x), aux

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        logits, aux = self.apply(params, batch["tokens"],
                                 batch.get("patch_embeds"))
        labels = batch["labels"]
        # logits cover [patches + tokens]; labels align with the full stream
        return L.cross_entropy_loss(logits[:, -labels.shape[1]:], labels,
                                    self.cfg.vocab) + aux

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    def cache_specs(self, long_ctx: bool = False) -> Params:
        # batch over data + sequence over model (exact partitioned softmax:
        # the seq-dim reductions lower to [b,h]-sized all-reduces, DESIGN
        # §5).  For batch=1 long-context decode shard seq over both axes.
        spec = (P(None, None, ("data", "model"), None, None) if long_ctx
                else P(None, "data", "model", None, None))
        return {"k": spec, "v": spec}

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, Params]:
        """tokens [B, 1]; pos scalar int32 -> (logits [B, 1, V], cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens, None)

        def scan_fn(h, inp):
            lp, ck, cv = inp
            h2, ck2, cv2 = _block_decode(lp, cfg, h, ck, cv, pos)
            return h2, (ck2, cv2)

        x, (ks, vs) = jax.lax.scan(scan_fn, x,
                                   (params["blocks"], cache["k"], cache["v"]))
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        return self._head(params, x), {"k": ks, "v": vs}
