"""Per-arch / per-shape sharding glue: batch specs, param placement,
dry-run input specs.

Conventions (DESIGN.md §5):
  * batch dim over ('pod', 'data') when multi-pod, else ('data',)
  * long-context decode (batch too small to shard): KV cache sequence dim
    over 'data' (exact partitioned softmax)
  * params: model-parallel over 'model' per the models' param_specs();
    the 'pod' axis never shards params (pure DP across DCI)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig

Params = Any

__all__ = ["batch_axes", "batch_specs", "input_structs", "shard_params",
           "named", "cache_structs"]


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def batch_specs(cfg: ArchConfig, mesh: Mesh) -> Dict[str, P]:
    """PartitionSpecs for one training batch dict."""
    ba = P(batch_axes(mesh))
    specs = {"tokens": ba, "labels": ba}
    if cfg.family == "vlm":
        specs["patch_embeds"] = ba
    elif cfg.family == "encdec":
        specs["frames"] = ba
    return specs


def input_structs(cfg: ArchConfig, mesh: Mesh, batch: int, seq: int
                  ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
    allocation) for one global training batch — the dry-run pattern."""
    sp = batch_specs(cfg, mesh)
    out = {}
    if cfg.family == "vlm":
        npatch = min(cfg.num_patches, seq // 2)
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq - npatch), jnp.int32,
                                             sharding=named(mesh, sp["tokens"]))
        out["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                                             sharding=named(mesh, sp["labels"]))
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, npatch, cfg.vision_dim), jnp.float32,
            sharding=named(mesh, sp["patch_embeds"]))
        return out
    out["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                                         sharding=named(mesh, sp["tokens"]))
    out["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                                         sharding=named(mesh, sp["labels"]))
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_frames, cfg.d_model), jnp.float32,
            sharding=named(mesh, sp["frames"]))
    return out


def cache_structs(model, cfg: ArchConfig, mesh: Mesh, batch: int, seq: int,
                  long_ctx: bool) -> Params:
    """ShapeDtypeStructs for the KV/state cache, with shardings."""
    shapes = jax.eval_shape(lambda: model.init_cache(batch, seq))
    specs = model.cache_specs(long_ctx=long_ctx)

    def to_struct(sds, spec):
        if not long_ctx and "pod" in mesh.axis_names:
            # extend batch sharding over the pod axis too
            entries = list(spec)
            for i, e in enumerate(entries):
                if e == "data":
                    entries[i] = ("pod", "data")
                    break
            spec = P(*entries)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=named(mesh, spec))

    return jax.tree.map(to_struct, shapes, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def shard_params(model, mesh: Mesh) -> Params:
    """ShapeDtypeStructs for params with NamedShardings attached."""
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = model.param_specs()
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                               sharding=named(mesh, spec)),
        shapes, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
