"""Gradient compression: int8 all-reduce over the data axis.

Wire format: blockwise-int8 codes + f32 absmax scales per shard; each
device all-gathers the (codes, scales) pairs — 4x fewer bytes than an f32
ring all-reduce — then dequantizes and sums locally.  Exposed as a
shard_map transform usable by an explicit-DP train step (flag-gated; the
default pjit path lets XLA place the f32 reductions).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.train.optimizer import quantize_blockwise

Params = Any

__all__ = ["compressed_allreduce"]


def compressed_allreduce(tree: Params, mesh: Mesh, axis: str = "data",
                         block: int = 256) -> Params:
    """Mean-reduce per-device gradient shards across ``axis`` with int8
    wire traffic.  Tree leaves carry a leading per-device dim of size
    mesh.shape[axis] (one local gradient per device); the output drops it
    (the mean, replicated along ``axis``)."""
    import numpy as np
    n = mesh.shape[axis]

    def one(leaf):
        assert leaf.shape[0] == n, (leaf.shape, n)
        shape = leaf.shape[1:]
        nelem = int(np.prod(shape))

        def body(g):                        # g [1, ...] — this device's grad
            codes, scale = quantize_blockwise(g[0].astype(jnp.float32), block)
            all_codes = jax.lax.all_gather(codes, axis)       # [n, nb, blk] i8
            all_scale = jax.lax.all_gather(scale, axis)       # [n, nb, 1] f32
            deq = all_codes.astype(jnp.float32) * all_scale   # [n, nb, blk]
            summed = deq.sum(axis=0).reshape(-1)[:nelem]
            return (summed / n).reshape(shape).astype(g.dtype)

        # out is replicated by construction (same all_gather everywhere);
        # the static varying-ness checker can't see that through gather
        return shard_map(body, mesh=mesh, in_specs=P(axis),
                             out_specs=P(), check_vma=False)(leaf)

    return jax.tree.map(one, tree)
