"""LM distribution glue: shardings, gradient compression."""
from .sharding import (batch_axes, batch_specs, input_structs, shard_params,
                       named, cache_structs)
from .compression import compressed_allreduce

__all__ = ["batch_axes", "batch_specs", "input_structs", "shard_params",
           "named", "cache_structs", "compressed_allreduce"]
