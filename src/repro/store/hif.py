"""HIF (Hypergraph Interchange Format) import/export.

HIF is the community-standard JSON schema for exchanging hypergraphs
(https://github.com/pszufe/HIF-standard): a top-level object with
``network-type``, optional ``metadata``, and three arrays —

    "nodes":      [{"node": <id>, ...}, ...]        (may be empty)
    "edges":      [{"edge": <id>, ...}, ...]        (may be empty)
    "incidences": [{"edge": <id>, "node": <id>}, ...]

Node/edge ids are arbitrary JSON scalars (strings, ints); the importer
densifies them by first appearance — the ``nodes``/``edges`` arrays
first (so isolated vertices and their declared order survive), then the
incidence stream.  Within one hyperedge, duplicate (edge, node)
incidences canonicalize away (``from_edge_lists`` dedup-sorts members,
as everywhere in this repo); *distinct hyperedges with identical member
sets are preserved* — only ``Hypergraph.compact`` merges those.  Edges
declared with no incidences are dropped — a memberless hyperedge has no
reachability meaning here.  Directed networks are rejected.

``write_hif`` emits dense integer ids, so import → export → import is
an identity on the ``Hypergraph`` arrays (tests/test_store.py).
"""
from __future__ import annotations

import json
from typing import Optional

from ..core.hypergraph import Hypergraph, from_edge_lists

__all__ = ["read_hif", "write_hif"]


def _scalar_id(entry, key):
    """An HIF array entry is either a bare scalar id or an object
    carrying the id under ``key``."""
    if isinstance(entry, dict):
        if key not in entry:
            raise ValueError(f"HIF {key} record without a {key!r} field: "
                             f"{entry!r}")
        return entry[key]
    return entry


def read_hif(path) -> Hypergraph:
    """Load an HIF JSON file as a dense :class:`Hypergraph`."""
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "incidences" not in doc:
        raise ValueError(f"{path}: not an HIF document (no 'incidences')")
    ntype = doc.get("network-type", "undirected")
    if ntype == "directed":
        raise ValueError(f"{path}: directed HIF networks are not supported "
                         f"(reachability here is undirected set-overlap)")

    node_ids: dict = {}
    edge_ids: dict = {}

    def node_of(raw) -> int:
        if raw not in node_ids:
            node_ids[raw] = len(node_ids)
        return node_ids[raw]

    def edge_of(raw) -> int:
        if raw not in edge_ids:
            edge_ids[raw] = len(edge_ids)
        return edge_ids[raw]

    for entry in doc.get("nodes", []):
        node_of(_scalar_id(entry, "node"))
    for entry in doc.get("edges", []):
        edge_of(_scalar_id(entry, "edge"))

    members = [[] for _ in range(len(edge_ids))]
    for inc in doc["incidences"]:
        if not isinstance(inc, dict) or "edge" not in inc or "node" not in inc:
            raise ValueError(f"{path}: malformed incidence record: {inc!r}")
        e = edge_of(inc["edge"])
        while e >= len(members):
            members.append([])
        members[e].append(node_of(inc["node"]))

    # memberless hyperedges carry no reachability information — drop them
    edges = [mem for mem in members if mem]
    return from_edge_lists(edges, n=len(node_ids))


def write_hif(path, h: Hypergraph, *, metadata: Optional[dict] = None) -> None:
    """Write ``h`` as an HIF JSON file with dense integer ids."""
    doc = {
        "network-type": "undirected",
        "metadata": dict(metadata) if metadata else {},
        "nodes": [{"node": int(v)} for v in range(h.n)],
        "edges": [{"edge": int(e)} for e in range(h.m)],
        "incidences": [
            {"edge": int(e), "node": int(h.e_idx[k])}
            for e in range(h.m)
            for k in range(int(h.e_ptr[e]), int(h.e_ptr[e + 1]))
        ],
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
