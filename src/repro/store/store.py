"""Durable store directory: checkpoints + WAL + atomic CURRENT pointer.

Layout of a store directory::

    CURRENT                      -> name of the live checkpoint (atomic)
    checkpoint-<version>.hlidx   -> one save_index file (format.py)
    wal-<version>.log            -> the update journal following it

``checkpoint(engine)`` writes the index file (to a temp name, then
``os.replace`` + directory fsync — the file named by ``CURRENT`` is
always complete), rotates the WAL to a fresh ``wal-<version>.log``, and
deletes superseded checkpoint/WAL files — that deletion *is* the
periodic log compaction: every journaled record at or below the new
checkpoint's version is now redundant.

``restore()`` is the warm-restart path: load the ``CURRENT`` checkpoint
(mmap, no construction — ``format.load_index``) and replay the WAL's
delta suffix through the engine's own ``update`` path, so scoped
maintenance and the dirty-rows contract apply exactly as they did live.
A torn final record (crash mid-append) is dropped by checksum, never an
error.  The store then re-attaches as the engine's WAL sink, so serving
resumes with the same durability guarantees.

The store *is* the engine's WAL sink (``engine.attach_wal(store)``):
``append`` journals before the apply, ``committed`` runs after it and
triggers auto-compaction once ``checkpoint_every`` records accumulate.
"""
from __future__ import annotations

import os
import pathlib
from typing import Optional

from .format import (CorruptStore, StoreError, load_index, read_manifest,
                     save_index)
from .wal import WriteAheadLog

__all__ = ["IndexStore", "restore_engine"]

_CKPT_FMT = "checkpoint-{:012d}.hlidx"
_WAL_FMT = "wal-{:012d}.log"


def _fsync_dir(path) -> None:
    """Make a directory entry rename durable (POSIX; no-op elsewhere)."""
    try:
        fd = os.open(os.fspath(path), os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class IndexStore:
    """One durable home for one engine lineage.

    Args:
      path: store directory (created if missing).
      checkpoint_every: auto-compact — write a fresh checkpoint and
        truncate the log once this many WAL records accumulate (None =
        only explicit ``checkpoint()`` calls compact).
      verify: CRC-check checkpoint segments on restore (default True).
    """

    def __init__(self, path, *, checkpoint_every: Optional[int] = None,
                 verify: bool = True):
        self.path = pathlib.Path(path)
        self.path.mkdir(parents=True, exist_ok=True)
        self.checkpoint_every = checkpoint_every
        self.verify = verify
        self._wal: Optional[WriteAheadLog] = None

    # -- inspection --------------------------------------------------------

    def current_checkpoint(self) -> Optional[pathlib.Path]:
        cur = self.path / "CURRENT"
        if not cur.is_file():
            return None
        return self.path / cur.read_text().strip()

    @property
    def checkpoint_version(self) -> Optional[int]:
        p = self.current_checkpoint()
        if p is None:
            return None
        return int(p.name[len("checkpoint-"):].split(".")[0])

    @property
    def records_since_checkpoint(self) -> int:
        return self._wal.count if self._wal is not None else 0

    def manifest(self) -> dict:
        p = self.current_checkpoint()
        if p is None:
            raise StoreError(f"{self.path}: no checkpoint yet")
        return read_manifest(p)

    # -- checkpoint + compaction -------------------------------------------

    def checkpoint(self, engine, *, neighbors=None) -> pathlib.Path:
        """Write a checkpoint of ``engine`` at its current version,
        atomically swing ``CURRENT`` to it, rotate the WAL, and delete
        superseded files (log compaction).  Safe at any point of the
        lineage; crash-safe at every step (the temp file is renamed into
        place before ``CURRENT`` moves)."""
        version = int(engine.version)
        name = _CKPT_FMT.format(version)
        final = self.path / name
        tmp = self.path / (name + ".tmp")
        save_index(tmp, engine, neighbors=neighbors)
        os.replace(tmp, final)
        cur_tmp = self.path / "CURRENT.tmp"
        cur_tmp.write_text(name + "\n")
        os.replace(cur_tmp, self.path / "CURRENT")
        _fsync_dir(self.path)
        # rotate: a fresh (empty) log follows this checkpoint — any
        # record at or below `version` is baked into the file just
        # written, so the old logs (and checkpoints) are compacted away
        if self._wal is not None:
            self._wal.close()
        wal_path = self.path / _WAL_FMT.format(version)
        if wal_path.exists():
            wal_path.unlink()
        self._wal = WriteAheadLog(wal_path, base_version=version)
        for p in self.path.glob("checkpoint-*.hlidx"):
            if p.name != name:
                p.unlink()
        for p in self.path.glob("wal-*.log"):
            if p != wal_path:
                p.unlink()
        for p in self.path.glob("*.tmp"):
            p.unlink()
        return final

    # -- the engine-facing WAL sink protocol -------------------------------

    def attach(self, engine) -> None:
        """Make this store ``engine``'s WAL sink: every subsequent
        ``engine.update`` journals durably here before applying.  The
        engine must continue the store's lineage (checkpoint version +
        logged records == engine version); an empty store seeds itself
        with a checkpoint of the engine first."""
        ck = self.checkpoint_version
        if ck is None:
            self.checkpoint(engine)
            engine.attach_wal(self)
            return
        if self._wal is None:
            self._wal = WriteAheadLog(self.path / _WAL_FMT.format(ck),
                                      base_version=ck)
        if int(engine.version) != self._wal.last_version:
            raise StoreError(
                f"engine version {engine.version} does not continue this "
                f"store's lineage (checkpoint {ck} + {self._wal.count} "
                f"logged updates = version {self._wal.last_version}); "
                f"checkpoint() it instead")
        engine.attach_wal(self)

    def append(self, version: int, inserts, deletes) -> None:
        """WAL sink: journal one update durably (called by
        ``engine.update`` *before* the in-memory apply)."""
        if self._wal is None:
            raise StoreError("store has no open WAL; call checkpoint() or "
                             "attach() first")
        self._wal.append(version, inserts, deletes)

    def committed(self, engine) -> None:
        """WAL sink: the update applied; compact if the log grew past
        ``checkpoint_every`` records."""
        if (self.checkpoint_every is not None and self._wal is not None
                and self._wal.count >= int(self.checkpoint_every)):
            self.checkpoint(engine)

    # -- warm restart ------------------------------------------------------

    def restore(self, *, mesh=None, verify: Optional[bool] = None,
                expect_backend: Optional[str] = None, attach: bool = True):
        """Load the ``CURRENT`` checkpoint and replay the WAL suffix.

        The checkpoint loads mmap-backed (no construction); each logged
        record replays through ``engine.update`` — the same scoped
        maintenance + dirty-rows path live updates took — with the WAL
        detached, so replay never re-journals.  Replay asserts version
        contiguity; a torn/corrupt tail record was already dropped by
        the checksum scan.  With ``attach`` (default) the store then
        re-attaches as the engine's WAL sink and serving can resume.
        """
        p = self.current_checkpoint()
        if p is None:
            raise StoreError(f"{self.path}: nothing to restore "
                             f"(no CURRENT checkpoint)")
        verify = self.verify if verify is None else verify
        engine = load_index(p, mesh=mesh, verify=verify,
                            expect_backend=expect_backend)
        ck = int(engine.version)
        wal_path = self.path / _WAL_FMT.format(ck)
        records = []
        if wal_path.exists():
            # opening also truncates any torn tail for good, so the
            # subsequent attach() appends after the last *valid* record
            with WriteAheadLog(wal_path, base_version=ck) as w:
                records = w.records()
        for version, inserts, deletes in records:
            if version <= engine.version:
                continue
            if version != engine.version + 1:
                raise CorruptStore(
                    f"{wal_path}: record {version} does not continue "
                    f"engine version {engine.version} — lineage gap")
            engine.update(inserts, deletes)
        if attach:
            self.attach(engine)
        return engine

    def close(self) -> None:
        if self._wal is not None:
            self._wal.close()
            self._wal = None

    def __enter__(self) -> "IndexStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def restore_engine(path, *, mesh=None, verify: bool = True,
                   expect_backend: Optional[str] = None,
                   checkpoint_every: Optional[int] = None, attach: bool = True):
    """Restore an engine from either a store *directory* (checkpoint +
    WAL replay + re-attach — the ``build_engine(restore=...)`` path) or
    a single ``save_index`` *file* (plain load, no journal)."""
    p = pathlib.Path(path)
    if p.is_dir():
        store = IndexStore(p, checkpoint_every=checkpoint_every,
                           verify=verify)
        return store.restore(mesh=mesh, expect_backend=expect_backend,
                             attach=attach)
    return load_index(p, mesh=mesh, verify=verify,
                      expect_backend=expect_backend)
