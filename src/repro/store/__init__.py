"""Durable index store: on-disk snapshots, write-ahead log, HIF I/O.

Three layers (ISSUE 6 / ROADMAP item 3):

- ``format``: the versioned, mmap-loadable index file —
  ``save_index`` / ``load_index`` with per-segment checksums.
- ``wal`` + ``store``: the write-ahead update log and the checkpoint
  directory that together give crash-safe continuous ingest and warm
  restart (``IndexStore``, ``restore_engine``).
- ``hif``: Hypergraph Interchange Format import/export for external
  datasets (``read_hif`` / ``write_hif``).
"""
from .format import (FORMAT_REGISTRY, FORMAT_VERSION, CorruptStore,
                     StoreError, StoreUnsupported, load_index, load_segments,
                     read_manifest, save_index)
from .hif import read_hif, write_hif
from .store import IndexStore, restore_engine
from .wal import WriteAheadLog, scan_wal

__all__ = [
    "FORMAT_REGISTRY",
    "FORMAT_VERSION",
    "StoreError",
    "CorruptStore",
    "StoreUnsupported",
    "save_index",
    "load_index",
    "read_manifest",
    "load_segments",
    "WriteAheadLog",
    "scan_wal",
    "IndexStore",
    "restore_engine",
    "read_hif",
    "write_hif",
]
