"""Write-ahead update log: the durability layer in front of ``apply_updates``.

Every ``engine.update(inserts, deletes)`` with a WAL attached appends one
record — *fsynced before the in-memory structure changes* — so a crash at
any point loses at most updates that were never acknowledged:

    [ magic u32 | version u64 | payload len u32 | payload CRC-32 u32 | payload ]

The payload is the canonical JSON batch produced by
``repro.core.maintenance.normalize_update_batch`` (dedup-sorted inserts,
sorted unique deletes — replaying it is byte-identical to applying the
original).  ``version`` is the engine version *after* the update applies:
records are strictly monotonic, continuing the checkpoint they follow, so
replay can assert lineage contiguity.

Torn-tail policy (the crash contract, exercised in
tests/test_crash_recovery.py): scanning stops at the first record whose
header is truncated, whose magic is wrong, whose payload runs past EOF,
or whose CRC mismatches — that record and everything after it is
*dropped, not an error* (a crash mid-append legitimately leaves exactly
this state).  Opening the log for append truncates the torn bytes first,
so new records never land after garbage.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import List, Sequence, Tuple

from .format import StoreError

__all__ = ["WriteAheadLog", "scan_wal"]

_REC_MAGIC = 0x484C5741                      # "HLWA"
_REC = struct.Struct("<IQII")                # magic, version, len, crc

WalRecord = Tuple[int, List[List[int]], List[int]]   # version, inserts, deletes


def scan_wal(path) -> Tuple[List[WalRecord], int, str]:
    """Read every valid record of a WAL file.

    Returns ``(records, valid_bytes, tail_status)`` where ``records`` is
    ``[(version, inserts, deletes), ...]`` in append order,
    ``valid_bytes`` is the prefix length holding them, and
    ``tail_status`` is ``"ok"`` or why scanning stopped
    (``"torn-header"`` / ``"torn-payload"`` / ``"bad-magic"`` /
    ``"bad-checksum"`` / ``"bad-payload"``) — the dropped tail is the
    crash contract, never an exception."""
    records: List[WalRecord] = []
    if not os.path.exists(path):
        return records, 0, "ok"
    with open(path, "rb") as f:
        data = f.read()
    off = 0
    status = "ok"
    while True:
        if off + _REC.size > len(data):
            if off != len(data):
                status = "torn-header"
            break
        magic, version, plen, crc = _REC.unpack_from(data, off)
        if magic != _REC_MAGIC:
            status = "bad-magic"
            break
        end = off + _REC.size + plen
        if end > len(data):
            status = "torn-payload"
            break
        payload = data[off + _REC.size:end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            status = "bad-checksum"
            break
        try:
            rec = json.loads(payload)
            records.append((int(version), rec["inserts"], rec["deletes"]))
        except (ValueError, KeyError, TypeError):
            status = "bad-payload"
            break
        off = end
    return records, off, status


class WriteAheadLog:
    """Append-only checksummed update journal.

    Opening scans the existing file, truncates any torn tail (see module
    docstring), and resumes the version lineage from the last valid
    record (or ``base_version`` — the checkpoint version this log
    follows — when empty).  ``append`` writes, flushes, and fsyncs
    before returning: callers apply the update only after it is durable.
    """

    def __init__(self, path, *, base_version: int = 0):
        self.path = os.fspath(path)
        records, valid_bytes, self.tail_status = scan_wal(self.path)
        self.last_version = int(records[-1][0]) if records else int(base_version)
        self.count = len(records)
        self._f = open(self.path, "a+b")
        if self._f.seek(0, os.SEEK_END) != valid_bytes:
            self._f.truncate(valid_bytes)    # drop the torn tail for good
        os.fsync(self._f.fileno())

    def append(self, version: int, inserts: Sequence[Sequence[int]],
               deletes: Sequence[int]) -> None:
        """Durably journal one update batch as record ``version`` (must
        be ``last_version + 1`` — the monotonic lineage invariant)."""
        version = int(version)
        if version != self.last_version + 1:
            raise StoreError(
                f"WAL versions are monotonic: expected record "
                f"{self.last_version + 1}, got {version}")
        payload = json.dumps(
            {"inserts": [[int(x) for x in e] for e in inserts],
             "deletes": [int(d) for d in deletes]},
            separators=(",", ":")).encode()
        self._f.write(_REC.pack(_REC_MAGIC, version, len(payload),
                                zlib.crc32(payload) & 0xFFFFFFFF))
        self._f.write(payload)
        self._f.flush()
        os.fsync(self._f.fileno())
        self.last_version = version
        self.count += 1

    def committed(self, engine) -> None:
        """Post-apply hook of the WAL sink protocol (see
        ``ReachabilityEngine.update``); the bare log needs no action —
        ``IndexStore`` overrides the sink to compact here."""

    def records(self) -> List[WalRecord]:
        """Re-scan the file (valid records only, append order)."""
        return scan_wal(self.path)[0]

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "WriteAheadLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
