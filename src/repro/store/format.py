"""Versioned, mmap-loadable on-disk index format (layout ``aligned-segments-v1``).

One checkpoint is one file:

    [ 32-byte header | segment 0 | pad | segment 1 | ... | JSON manifest ]

* **header** — magic ``HLSTORE\\0``, the u32 format version, and the
  manifest's (offset, length, CRC-32), all little-endian.  The manifest
  lives at the *end* of the file so segment offsets can be recorded
  absolutely without a two-pass length fixup; a truncated file therefore
  fails the manifest CRC instead of loading silently.
* **segments** — each index array (labels, ranks, the hypergraph CSR,
  optional ``NeighborCSR`` / closure blocks) as one contiguous
  little-endian raw block, 64-byte aligned, with name / dtype / shape /
  offset / CRC-32 recorded in the manifest's segment table.
* **manifest** — JSON: format version, backend name, engine version
  lineage, payload kind, the engine options needed to reconstruct the
  update path (builder, minimizer, mesh axes, ...), index stats, and the
  segment table.

``load_index`` maps the whole file once (``np.memmap`` read-only) and
hands every array out as a zero-copy view into it, so a service restart
is page-in + ``DeviceSnapshot.to_mesh`` — construction never runs, and
label bytes are identical to the saved engine's (asserted in
tests/test_store.py).  ``verify=True`` (default) checks every segment
CRC at load; ``verify=False`` defers integrity to the OS page cache for
pure-lazy startup.

Format evolution is registry-driven: ``FORMAT_REGISTRY`` maps every
readable format version to its layout codename, and the format-version
table in docs/ARCHITECTURE.md is CI-checked against it both ways
(tools/check_docs.py check 6).
"""
from __future__ import annotations

import functools
import json
import os
import struct
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.engine import ClosureEngine, HLIndexBasicEngine, HLIndexEngine
from ..core.hlindex import HLIndex, build_basic, build_fast, build_sharded
from ..core.hypergraph import Hypergraph, NeighborCSR
from ..core.minimal import minimize
from ..core.query import DeviceSnapshot

__all__ = [
    "FORMAT_VERSION", "FORMAT_REGISTRY", "MAGIC",
    "StoreError", "CorruptStore", "StoreUnsupported",
    "save_index", "load_index", "read_manifest", "load_segments",
]

MAGIC = b"HLSTORE\x00"

# On-disk format version -> layout codename.  Every version this build
# can *read* has a row here; docs/ARCHITECTURE.md carries the matching
# human-readable table and CI fails if the two drift (check_docs check 6).
FORMAT_VERSION = 1
FORMAT_REGISTRY: Dict[int, str] = {
    1: "aligned-segments-v1",
}

_ALIGN = 64
# magic[8] | format u32 | manifest offset u64 | manifest len u64 | crc u32
_HEADER = struct.Struct("<8sIQQI")

# backends whose resident structure serializes; everything else is
# index-free (online/frontier/mst-oracle/...) — rebuilding those is the
# cheap path by design, so persisting them would only persist the graph
_STORABLE = ("hl-index", "hl-index-basic", "closure", "sharded")


class StoreError(RuntimeError):
    """Persistence-layer misuse or lineage violation."""


class CorruptStore(StoreError):
    """A checkpoint file failed magic / CRC / structural validation."""


class StoreUnsupported(NotImplementedError):
    """Raised for engines whose backend has no serializable index form."""


# ---------------------------------------------------------------------------
# segment file primitives
# ---------------------------------------------------------------------------

def _le(a: np.ndarray) -> np.ndarray:
    """Contiguous little-endian view/copy of ``a`` (no-op on LE hosts)."""
    a = np.ascontiguousarray(a)
    if a.dtype.byteorder == ">":
        a = a.astype(a.dtype.newbyteorder("<"))
    return a


def _crc(buf) -> int:
    return zlib.crc32(buf) & 0xFFFFFFFF


def _write_store_file(path, meta: Dict, segments: Sequence[Tuple[str, np.ndarray]]) -> Dict:
    """Write header + aligned segments + trailing manifest; fsync."""
    with open(path, "wb") as f:
        f.write(b"\x00" * _HEADER.size)
        off = _HEADER.size
        entries: List[Dict] = []
        for name, arr in segments:
            arr = _le(np.asarray(arr))
            pad = (-off) % _ALIGN
            f.write(b"\x00" * pad)
            off += pad
            data = arr.tobytes()
            f.write(data)
            entries.append({"name": name, "dtype": arr.dtype.str,
                            "shape": list(arr.shape), "offset": off,
                            "nbytes": len(data), "crc32": _crc(data)})
            off += len(data)
        manifest = dict(meta)
        manifest["format"] = FORMAT_VERSION
        manifest["layout"] = FORMAT_REGISTRY[FORMAT_VERSION]
        manifest["segments"] = entries
        blob = json.dumps(manifest, sort_keys=True).encode()
        f.write(blob)
        f.seek(0)
        f.write(_HEADER.pack(MAGIC, FORMAT_VERSION, off, len(blob),
                             _crc(blob)))
        f.flush()
        os.fsync(f.fileno())
    return manifest


def read_manifest(path) -> Dict:
    """Header + manifest of a checkpoint file (CRC-verified, no arrays)."""
    with open(path, "rb") as f:
        head = f.read(_HEADER.size)
        if len(head) < _HEADER.size:
            raise CorruptStore(f"{path}: truncated header "
                               f"({len(head)} < {_HEADER.size} bytes)")
        magic, fmt, moff, mlen, mcrc = _HEADER.unpack(head)
        if magic != MAGIC:
            raise CorruptStore(f"{path}: bad magic {magic!r} — not an "
                               f"HL-index store file")
        if fmt not in FORMAT_REGISTRY:
            raise CorruptStore(
                f"{path}: on-disk format version {fmt} is not readable by "
                f"this build (known: {sorted(FORMAT_REGISTRY)})")
        f.seek(moff)
        blob = f.read(mlen)
    if len(blob) != mlen or _crc(blob) != mcrc:
        raise CorruptStore(f"{path}: manifest checksum mismatch — the file "
                           f"is truncated or corrupt")
    return json.loads(blob)


def load_segments(path, *, verify: bool = True) -> Tuple[Dict, Dict[str, np.ndarray]]:
    """(manifest, {segment name -> array}) with every array a zero-copy
    read-only view into one ``np.memmap`` of the file.  ``verify`` checks
    each segment's CRC-32 (reads every page once); ``verify=False`` keeps
    the load pure-lazy."""
    manifest = read_manifest(path)
    raw = np.memmap(path, dtype=np.uint8, mode="r")
    arrays: Dict[str, np.ndarray] = {}
    for seg in manifest["segments"]:
        lo, hi = seg["offset"], seg["offset"] + seg["nbytes"]
        if hi > raw.size:
            raise CorruptStore(f"{path}: segment {seg['name']!r} extends "
                               f"past end of file")
        buf = raw[lo:hi]
        if verify and _crc(buf) != seg["crc32"]:
            raise CorruptStore(f"{path}: segment {seg['name']!r} checksum "
                               f"mismatch")
        arrays[seg["name"]] = buf.view(np.dtype(seg["dtype"])) \
                                 .reshape(seg["shape"])
    return manifest, arrays


# ---------------------------------------------------------------------------
# ragged list <-> (ptr, values) segments
# ---------------------------------------------------------------------------

def _ragged(arrs: Sequence[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    ptr = np.zeros(len(arrs) + 1, np.int64)
    if len(arrs):
        np.cumsum(np.fromiter((a.size for a in arrs), np.int64, len(arrs)),
                  out=ptr[1:])
        vals = (np.concatenate([np.asarray(a) for a in arrs])
                if int(ptr[-1]) else np.empty(0, np.int64))
    else:
        vals = np.empty(0, np.int64)
    return ptr, vals


def _unragged(ptr: np.ndarray, vals: np.ndarray) -> List[np.ndarray]:
    return [vals[int(ptr[i]):int(ptr[i + 1])] for i in range(ptr.size - 1)]


def _jsonable_stats(stats: Dict) -> Dict:
    out = {}
    for k, v in stats.items():
        if isinstance(v, (bool, int, np.integer)):
            out[k] = int(v)
        elif isinstance(v, (float, np.floating)):
            out[k] = float(v)
        else:
            out[k] = v
    return out


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------

def _hlindex_opts(engine) -> Dict:
    """Recover the build options a restored engine needs so its *scoped
    update path* keeps using the same builder/minimizer as the original
    (construction mode, worker pool, cover_check for the basic variant)."""
    opts: Dict = {"construction": engine.construction,
                  "minimize_labels": engine._minimizer is not None}
    kw = dict(getattr(engine._builder, "keywords", {}))
    base = kw.pop("base", None)
    opts["workers"] = kw.get("workers")
    opts["num_shards"] = kw.get("num_shards")
    if engine.name == "hl-index-basic":
        src = getattr(base, "keywords", kw)
        opts["cover_check"] = bool(src.get("cover_check", True))
    return opts


def _hlindex_segments(idx: HLIndex) -> List[Tuple[str, np.ndarray]]:
    # the three per-vertex label lists share row lengths (one (edge,
    # rank, s) triple per label), so one ptr array indexes all three;
    # likewise one dual ptr for the per-hyperedge (vertex, s) pairs
    lptr, ledge = _ragged(idx.labels_edge)
    _, lrank = _ragged(idx.labels_rank)
    _, lsval = _ragged(idx.labels_s)
    dptr, dvert = _ragged(idx.dual_u)
    _, dsval = _ragged(idx.dual_s)
    return [("idx.rank", idx.rank), ("idx.perm", idx.perm),
            ("labels.ptr", lptr), ("labels.edge", ledge),
            ("labels.rank", lrank), ("labels.s", lsval),
            ("dual.ptr", dptr), ("dual.u", dvert), ("dual.s", dsval)]


def save_index(path, engine, *, neighbors: Optional[NeighborCSR] = None) -> Dict:
    """Serialize ``engine`` (graph + resident index structure + enough
    metadata to reconstruct its update path) into one checkpoint file at
    ``path``.  Returns the written manifest.

    Payload kinds by backend:

    * ``hl-index`` / ``hl-index-basic`` — rank, perm, label and dual
      lists as ragged (ptr, values) segments (payload ``labels``);
    * ``closure`` — the dense W* matrix (payload ``closure``);
    * ``sharded`` — the label regime saves its HL-index; the closure
      regime saves the gathered, mesh-padding-trimmed W*; after
      ``snapshot()`` freed the closure, the padded snapshot tensors are
      saved instead (payload ``snapshot``) — the restart path the format
      exists for: load + ``DeviceSnapshot.to_mesh``.

    ``neighbors`` optionally embeds a ``NeighborCSR`` block (segments
    ``nbr.*``) so a restart can skip the neighbor-overlap precompute;
    read it back via ``load_segments``.  Other backends raise
    ``StoreUnsupported`` — they are index-free, so persisting them would
    persist nothing but the graph.
    """
    name = getattr(engine, "name", None)
    if name not in _STORABLE:
        raise StoreUnsupported(
            f"backend {name!r} has no serializable index structure; "
            f"storable backends: {list(_STORABLE)}")
    h = engine.h
    meta: Dict = {"backend": name, "engine_version": int(engine.version),
                  "n": int(h.n), "m": int(h.m)}
    segments: List[Tuple[str, np.ndarray]] = [
        ("h.e_ptr", h.e_ptr), ("h.e_idx", h.e_idx),
        ("h.v_ptr", h.v_ptr), ("h.v_idx", h.v_idx)]

    if name in ("hl-index", "hl-index-basic"):
        meta["payload"] = "labels"
        meta["engine_opts"] = _hlindex_opts(engine)
        meta["stats"] = _jsonable_stats(engine.idx.stats)
        segments += _hlindex_segments(engine.idx)
    elif name == "closure":
        meta["payload"] = "closure"
        meta["engine_opts"] = {"method": engine._method}
        segments.append(("w_star", np.asarray(engine.w_star)))
    else:                                              # sharded
        meta["engine_opts"] = {
            "schedule": engine.schedule, "axes": list(engine.axes),
            "rounds": engine.rounds, "workers": engine._workers,
            "num_shards": engine._num_shards,
            "minimize_labels": engine._minimizer is not None,
        }
        if engine._idx is not None:
            meta["payload"] = "labels"
            meta["stats"] = _jsonable_stats(engine._idx.stats)
            segments += _hlindex_segments(engine._idx)
            if neighbors is None:
                # persist the engine's own neighbor index by default, so
                # a restarted engine resumes 1-hop-patched scoped updates
                # without re-running the pair pass
                neighbors = engine._nbr
        elif engine._w_star is not None:
            # gather in slot order and trim the mesh padding: the saved
            # W* is mesh- and slot-layout-independent (edge-id order),
            # re-padded for whatever mesh loads it
            meta["payload"] = "closure"
            w = np.asarray(engine._w_star)
            segments.append(
                ("w_star", np.ascontiguousarray(
                    w[np.ix_(engine._slot_of, engine._slot_of)])))
        else:
            # snapshot() freed the closure; the resident snapshot IS the
            # serving structure now, so persist exactly it — plus the
            # slot map, which scoped updates on the restored engine need
            # to patch the right snapshot columns
            meta["payload"] = "snapshot"
            snap = engine.snapshot()
            segments += [("snap.ranks", np.asarray(snap.ranks)),
                         ("snap.svals", np.asarray(snap.svals)),
                         ("snap.lengths", np.asarray(snap.lengths)),
                         ("snap.slots", np.asarray(engine._slot_of))]

    if neighbors is not None:
        segments += [("nbr.ptr", neighbors.ptr), ("nbr.idx", neighbors.idx),
                     ("nbr.od", neighbors.od)]
    return _write_store_file(path, meta, segments)


# ---------------------------------------------------------------------------
# load
# ---------------------------------------------------------------------------

def _hlindex_builder(backend: str, opts: Dict):
    workers = opts.get("workers")
    num_shards = opts.get("num_shards")
    if backend == "hl-index-basic":
        base = functools.partial(build_basic,
                                 cover_check=opts.get("cover_check", True))
    else:
        base = build_fast
    if opts.get("construction") == "sharded":
        if backend == "hl-index-basic":
            return functools.partial(build_sharded, base=base,
                                     workers=workers, num_shards=num_shards)
        return functools.partial(build_sharded, workers=workers,
                                 num_shards=num_shards)
    return base


def _load_hlindex(h: Hypergraph, manifest: Dict, seg: Dict[str, np.ndarray]) -> HLIndex:
    lptr = seg["labels.ptr"]
    dptr = seg["dual.ptr"]
    return HLIndex(h=h, rank=seg["idx.rank"], perm=seg["idx.perm"],
                   labels_edge=_unragged(lptr, seg["labels.edge"]),
                   labels_rank=_unragged(lptr, seg["labels.rank"]),
                   labels_s=_unragged(lptr, seg["labels.s"]),
                   dual_u=_unragged(dptr, seg["dual.u"]),
                   dual_s=_unragged(dptr, seg["dual.s"]),
                   stats=dict(manifest.get("stats", {})))


def _load_sharded(h: Hypergraph, manifest: Dict, seg: Dict[str, np.ndarray],
                  mesh):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..core.distributed import (ShardedEngine, default_line_graph_mesh,
                                    pad_for_mesh)

    opts = manifest.get("engine_opts", {})
    axes = tuple(opts.get("axes") or ("data", "model"))
    if mesh is None:
        mesh = default_line_graph_mesh(axes)
    else:
        axes = tuple(mesh.axis_names[-2:])
    schedule = opts.get("schedule", "allgather")
    rounds = opts.get("rounds")
    workers = opts.get("workers")
    num_shards = opts.get("num_shards")
    payload = manifest["payload"]
    version = int(manifest["engine_version"])

    if payload == "labels":
        idx = _load_hlindex(h, manifest, seg)
        minimizer = minimize if opts.get("minimize_labels") else None
        nbr = (NeighborCSR(seg["nbr.ptr"], seg["nbr.idx"], seg["nbr.od"])
               if "nbr.ptr" in seg else None)
        eng = ShardedEngine(h, mesh, axes, schedule, None, h.m, rounds,
                            idx=idx, minimizer=minimizer, workers=workers,
                            num_shards=num_shards, neighbors=nbr)
    elif payload == "closure":
        # re-pad for the loading mesh (zeros are the (max, min)
        # annihilator, so padding is invariant under the closure) and
        # land it block-sharded — same layout build would have produced
        w = np.asarray(seg["w_star"])
        wp = pad_for_mesh(w, mesh, axes)
        w_dev = (jax.device_put(jnp.asarray(wp),
                                NamedSharding(mesh, P(*axes)))
                 if wp.size else jnp.zeros((0, 0), jnp.float32))
        eng = ShardedEngine(h, mesh, axes, schedule, w_dev, h.m, rounds,
                            workers=workers, num_shards=num_shards)
    elif payload == "snapshot":
        eng = ShardedEngine(h, mesh, axes, schedule, None, h.m, rounds,
                            workers=workers, num_shards=num_shards)
        snap = DeviceSnapshot.from_padded(
            np.asarray(seg["snap.ranks"]), np.asarray(seg["snap.svals"]),
            np.asarray(seg["snap.lengths"]), "sharded", version=version)
        if int(mesh.devices.size) > 1 and snap.ranks.size:
            snap = snap.to_mesh(mesh, axes)
        eng._snap = snap
        # restore the slot layout so scoped updates keep patching the
        # right columns; the padded width is the loaded snapshot's
        eng._m_padded = int(snap.ranks.shape[1])
        if "snap.slots" in seg:
            eng._slot_of = np.asarray(seg["snap.slots"], np.int64)
    else:
        raise CorruptStore(f"unknown sharded payload {payload!r}")
    eng.version = version
    return eng


def load_index(path, *, mesh=None, verify: bool = True,
               expect_backend: Optional[str] = None):
    """Load a checkpoint written by ``save_index`` back into a live
    engine.  Label/rank/CSR arrays are zero-copy read-only views into
    one ``np.memmap`` of the file — byte-identical to the saved engine's
    and paged in lazily — and ``engine.version`` resumes the saved
    lineage.  ``mesh`` re-lands sharded-backend structures over the
    given mesh (defaults to a mesh over all visible devices);
    ``expect_backend`` asserts the checkpoint's backend."""
    manifest, seg = load_segments(path, verify=verify)
    backend = manifest["backend"]
    if expect_backend is not None and backend != expect_backend:
        raise StoreError(
            f"{path} holds a {backend!r} checkpoint, not the requested "
            f"{expect_backend!r}")
    h = Hypergraph(n=int(manifest["n"]), m=int(manifest["m"]),
                   e_ptr=seg["h.e_ptr"], e_idx=seg["h.e_idx"],
                   v_ptr=seg["h.v_ptr"], v_idx=seg["h.v_idx"])
    if backend == "sharded":
        return _load_sharded(h, manifest, seg, mesh)
    opts = manifest.get("engine_opts", {})
    version = int(manifest["engine_version"])
    if backend == "closure":
        eng = ClosureEngine(h, seg["w_star"],
                            method=opts.get("method", "maxmin"))
    else:
        cls = HLIndexEngine if backend == "hl-index" else HLIndexBasicEngine
        idx = _load_hlindex(h, manifest, seg)
        minimizer = minimize if opts.get("minimize_labels") else None
        eng = cls(h, idx, builder=_hlindex_builder(backend, opts),
                  minimizer=minimizer)
        eng.construction = opts.get("construction", "serial")
    eng.version = version
    return eng
