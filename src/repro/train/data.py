"""Data pipeline: deterministic synthetic LM streams + the hypergraph
dedup/contamination stage (the paper's engine as a first-class
data-pipeline feature, DESIGN.md §4).

Dedup semantics: each document is a hyperedge over its k-gram shingle
vertices; two documents are "s-contaminated" iff they are s-reachable at
threshold ``s`` (share a chain of documents with ≥s common shingles —
transitive near-dup clusters, not just pairwise).  ``dedup_corpus`` keeps
one representative per s-component, which is exactly the hyperedge-level
s-reachability equivalence of the paper (Sec. II).
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.models.common import ArchConfig
from repro.core.hypergraph import Hypergraph, from_edge_lists
from repro.core.baselines import line_graph_edges, _DSU

__all__ = ["SyntheticStream", "make_batch", "shingle_hypergraph",
           "dedup_corpus"]


class SyntheticStream:
    """Infinite deterministic token stream.  Draws token sequences from a
    per-shard rng (host-sharded: pass ``shard``/``num_shards`` the process
    index on multi-host) with a mild Zipf skew so losses are non-trivially
    learnable."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, *,
                 seed: int = 0, shard: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.rng = np.random.default_rng(seed * num_shards + shard)
        # Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.p = p / p.sum()

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return make_batch(self.cfg, self.batch, self.seq, self.rng, self.p)


def make_batch(cfg: ArchConfig, batch: int, seq: int,
               rng: np.random.Generator,
               p: Optional[np.ndarray] = None) -> Dict[str, np.ndarray]:
    """One batch for any family (adds stub modality inputs as needed).
    tokens/labels are next-token shifted."""
    stream = rng.choice(cfg.vocab, size=(batch, seq + 1),
                        p=p) if p is not None else \
        rng.integers(0, cfg.vocab, (batch, seq + 1))
    out: Dict[str, np.ndarray] = {
        "tokens": stream[:, :-1].astype(np.int32),
        "labels": stream[:, 1:].astype(np.int32),
    }
    if cfg.family == "vlm":
        np_ = min(cfg.num_patches, seq // 2)
        out["tokens"] = out["tokens"][:, :seq - np_]
        out["patch_embeds"] = rng.normal(
            size=(batch, np_, cfg.vision_dim)).astype(np.float32)
    elif cfg.family == "encdec":
        out["frames"] = rng.normal(
            size=(batch, cfg.enc_frames, cfg.d_model)).astype(np.float32)
    return out


# ---------------------------------------------------------------------------
# hypergraph dedup stage
# ---------------------------------------------------------------------------

def shingle_hypergraph(docs: Sequence[np.ndarray], k: int = 4,
                       num_buckets: int = 1 << 20) -> Hypergraph:
    """documents (token id arrays) -> hypergraph: one hyperedge per doc
    over hashed k-gram shingle vertices."""
    edges: List[np.ndarray] = []
    mult = np.uint64(1000003)
    for doc in docs:
        d = np.asarray(doc, np.uint64)
        if d.size < k:
            h = d
        else:
            h = np.zeros(d.size - k + 1, np.uint64)
            for i in range(k):
                h = h * mult + d[i:d.size - k + 1 + i]
        edges.append(np.unique(h % np.uint64(num_buckets)).astype(np.int64))
    # re-index vertices densely
    all_v = np.unique(np.concatenate(edges)) if edges else np.empty(0, np.int64)
    remap = {int(v): i for i, v in enumerate(all_v)}
    dense = [np.array([remap[int(v)] for v in e], np.int64) for e in edges]
    return from_edge_lists(dense, n=len(all_v))


def dedup_corpus(docs: Sequence[np.ndarray], s: int, k: int = 4
                 ) -> Tuple[List[int], np.ndarray]:
    """Keep one representative per s-reachability component of the shingle
    hypergraph.  Returns (kept doc indices, component id per doc)."""
    h = shingle_hypergraph(docs, k)
    src, dst, od = line_graph_edges(h)
    dsu = _DSU(h.m)
    for a, b, w in zip(src, dst, od):
        if w >= s:
            dsu.union(int(a), int(b))
    comp = np.array([dsu.find(e) for e in range(h.m)], np.int64)
    kept: List[int] = []
    seen = set()
    for i, c in enumerate(comp):
        if int(c) not in seen:
            seen.add(int(c))
            kept.append(i)
    return kept, comp
