"""Training substrate: optimizer, train step, checkpointing, data,
fault tolerance."""
from .optimizer import (AdamConfig, adam_init, adam_update, lr_schedule,
                        quantize_blockwise, dequantize_blockwise,
                        zero1_specs, opt_state_specs, global_norm)
from .train_step import make_train_step, make_eval_step
from . import checkpoint
from .data import SyntheticStream, make_batch, shingle_hypergraph, dedup_corpus
from .fault_tolerance import SupervisorConfig, TrainSupervisor

__all__ = [
    "AdamConfig", "adam_init", "adam_update", "lr_schedule",
    "quantize_blockwise", "dequantize_blockwise", "zero1_specs",
    "opt_state_specs", "global_norm", "make_train_step", "make_eval_step",
    "checkpoint", "SyntheticStream", "make_batch", "shingle_hypergraph",
    "dedup_corpus", "SupervisorConfig", "TrainSupervisor",
]
