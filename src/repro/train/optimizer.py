"""Optimizers: AdamW (f32 moments) and blockwise-int8 Adam (8-bit moments
with per-block f32 absmax scales — the memory trick that lets the 480B
MoE's optimizer state fit a single pod, DESIGN.md §5).

Both are pure-pytree (no optax dependency) and compose with:
  * ZeRO-1: ``zero1_specs`` further shards the moment tensors over the
    ``data`` axis (params stay replicated across data — only the update
    math shards, which is exactly optimizer-state sharding).
  * cosine LR schedule with linear warmup, global-norm clipping,
    decoupled weight decay.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Params = Any

__all__ = ["AdamConfig", "adam_init", "adam_update", "lr_schedule",
           "global_norm", "clip_by_global_norm", "quantize_blockwise",
           "dequantize_blockwise", "zero1_specs", "opt_state_specs"]


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    use_8bit: bool = False
    q_block: int = 256


# ---------------------------------------------------------------------------
# blockwise int8 quantization
# ---------------------------------------------------------------------------

def quantize_blockwise(x: jax.Array, block: int = 256
                       ) -> Tuple[jax.Array, jax.Array]:
    """x (any shape) -> (int8 codes, f32 per-block scales).  Flattened
    absmax quantization; the pad tail quantizes zeros (harmless).
    (Wire-format variant — used by gradient compression.)"""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def dequantize_blockwise(codes: jax.Array, scale: jax.Array,
                         shape: Tuple[int, ...]) -> jax.Array:
    n = int(np.prod(shape))
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape)


def quantize_shaped(x: jax.Array, block: int = 256
                    ) -> Tuple[jax.Array, jax.Array]:
    """Shape-preserving blockwise int8 along the LAST dim:
    codes has x's shape (int8, last dim padded up to a block multiple);
    scales are [..., n_blocks] f32.  Because codes/scales keep the param's
    leading-dim layout, optimizer moments can shard EXACTLY like the param
    — the 8-bit Adam update stays elementwise under any (model, data)
    sharding with zero resharding (§Perf B: the flat layout forced GSPMD
    to all-gather 625 GB of dequantized moments per step on arctic-480b).
    """
    *lead, last = x.shape
    pad = (-last) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    nb = (last + pad) // block
    blocks = x.reshape(*lead, nb, block)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return (codes.reshape(*lead, last + pad),
            scale[..., 0].astype(jnp.float32))


def dequantize_shaped(codes: jax.Array, scale: jax.Array,
                      shape: Tuple[int, ...], block: int = 256) -> jax.Array:
    *lead, last_p = codes.shape
    nb = last_p // block
    x = codes.reshape(*lead, nb, block).astype(jnp.float32) * scale[..., None]
    return x.reshape(*lead, last_p)[..., :shape[-1]]


_V_FLOOR = 1e-24


def quantize_v_shaped(v: jax.Array, block: int = 256):
    """Second-moment quantization in the LOG domain: absmax-int8 on
    log(v) bounds the *relative* error of the Adam denominator (linear
    absmax flushes small v to 0 and the update explodes — measured, see
    EXPERIMENTS.md §Perf B iter 3)."""
    return quantize_shaped(jnp.log(v + _V_FLOOR), block)


def dequantize_v_shaped(codes: jax.Array, scale: jax.Array,
                        shape: Tuple[int, ...], block: int = 256) -> jax.Array:
    return jnp.exp(dequantize_shaped(codes, scale, shape, block))


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def adam_init(params: Params, cfg: AdamConfig) -> Dict[str, Any]:
    if cfg.use_8bit:
        def zeros8(p):
            codes, scale = quantize_shaped(
                jnp.zeros(p.shape if p.ndim else (1,), jnp.float32),
                cfg.q_block)
            return {"codes": codes, "scale": scale}

        def zeros8v(p):
            codes, scale = quantize_v_shaped(
                jnp.zeros(p.shape if p.ndim else (1,), jnp.float32),
                cfg.q_block)
            return {"codes": codes, "scale": scale}
        return {"m": jax.tree.map(zeros8, params),
                "v": jax.tree.map(zeros8v, params),
                "count": jnp.zeros((), jnp.int32)}
    return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32)}


def lr_schedule(cfg: AdamConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(np.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def clip_by_global_norm(tree: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def adam_update(params: Params, grads: Params, state: Dict[str, Any],
                cfg: AdamConfig) -> Tuple[Params, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  Returns (params, state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)
    c1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    if cfg.use_8bit:
        def upd(p, g, m8, v8):
            shape = p.shape if p.ndim else (1,)
            m = dequantize_shaped(m8["codes"], m8["scale"], shape,
                                  cfg.q_block).reshape(p.shape)
            v = dequantize_v_shaped(v8["codes"], v8["scale"], shape,
                                    cfg.q_block).reshape(p.shape)
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            step_ = lr * (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
            newp = p - step_ - lr * cfg.weight_decay * p
            mc, ms = quantize_shaped(m.reshape(shape), cfg.q_block)
            vc, vs = quantize_v_shaped(v.reshape(shape), cfg.q_block)
            return newp.astype(p.dtype), {"codes": mc, "scale": ms}, \
                {"codes": vc, "scale": vs}
        out = jax.tree.map(upd, params, grads, state["m"], state["v"],
                           is_leaf=lambda x: isinstance(x, jax.Array)
                           or hasattr(x, "shape") and not isinstance(x, dict))
        newp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        newm = jax.tree.map(lambda t: t[1], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        newv = jax.tree.map(lambda t: t[2], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"m": newm, "v": newv, "count": count}, \
            {"grad_norm": gnorm, "lr": lr}

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step_ = lr * (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        newp = p.astype(jnp.float32) - step_ - lr * cfg.weight_decay * p.astype(jnp.float32)
        return newp.astype(p.dtype), m, v

    newp_m_v = jax.tree.map(upd, params, grads, state["m"], state["v"])
    newp = jax.tree.map(lambda t: t[0], newp_m_v,
                        is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda t: t[1], newp_m_v,
                        is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda t: t[2], newp_m_v,
                        is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"m": newm, "v": newv, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# sharding of optimizer state (ZeRO-1)
# ---------------------------------------------------------------------------

def zero1_specs(spec: P, shape: Tuple[int, ...], data_size: int,
                axis: str = "data") -> P:
    """Extend a param spec with `data`-axis sharding on the first free,
    divisible dim — optimizer-state sharding a la ZeRO stage 1.  No-op if
    the spec already uses ``axis`` (e.g. model+data expert sharding)."""
    def uses(e):
        return e == axis or (isinstance(e, tuple) and axis in e)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    if any(uses(e) for e in entries):
        return P(*entries)
    for i, (e, s) in enumerate(zip(entries, shape)):
        if e is None and s % data_size == 0 and s >= data_size:
            entries[i] = axis
            return P(*entries)
    return P(*entries)


def opt_state_specs(param_specs: Params, params_shape: Params,
                    cfg: AdamConfig, data_size: int, zero1: bool = True
                    ) -> Dict[str, Any]:
    """Spec tree matching ``adam_init``'s state tree."""
    def mom_spec(spec, sds):
        if cfg.use_8bit:
            # shape-preserving codes shard EXACTLY like the param (plus
            # ZeRO on a free divisible dim); scales drop the last dim.
            shape = sds.shape if len(sds.shape) else (1,)
            sp = zero1_specs(spec, shape, data_size) if zero1 else \
                P(*(list(spec) + [None] * (len(shape) - len(spec))))
            entries = list(sp) + [None] * (len(shape) - len(sp))
            # codes keep the padded last dim; if padding changed it, the
            # original tiling may no longer divide — drop that axis entry
            last_pad = -(-shape[-1] // cfg.q_block) * cfg.q_block
            if last_pad != shape[-1] and entries[-1] is not None:
                entries[-1] = None
            codes_spec = P(*entries)
            scale_spec = P(*entries[:-1], None)
            return {"codes": codes_spec, "scale": scale_spec}
        return zero1_specs(spec, sds.shape, data_size) if zero1 else spec

    m = jax.tree.map(mom_spec, param_specs, params_shape,
                     is_leaf=lambda x: isinstance(x, P))
    return {"m": m, "v": jax.tree.map(lambda x: x, m), "count": P()}
