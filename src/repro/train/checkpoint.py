"""Checkpointing: atomic, keep-k, resumable, elastic.

Layout:  <dir>/step_<N>/arrays.npz + meta.json (tree structure, step,
mesh shape at save time).  Writes go to ``<dir>/.tmp_<N>`` then a single
atomic ``os.rename`` — a preempted writer never corrupts the latest
checkpoint.  ``AsyncCheckpointer`` moves serialization off the train loop
thread (device->host copy happens synchronously, as it must; file IO is
backgrounded).

Elastic restarts: arrays are saved *unsharded* (host-gathered numpy);
``restore`` returns numpy leaves the caller ``device_put``s with the
*current* mesh's NamedShardings — a checkpoint written on a (16,16) mesh
restores cleanly onto (2,16,16) or a degraded (15·16) donor mesh.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Params = Any

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]


def _flatten(tree: Params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _treedef_of(tree: Params):
    return jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, tree: Params,
         metadata: Optional[Dict] = None, keep: int = 3) -> str:
    """Atomic checkpoint write; prunes to the newest ``keep`` steps."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = dict(metadata or {}, step=step,
                keys=sorted(flat.keys()),
                treedef=str(_treedef_of(tree)))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    # prune
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like: Params, step: Optional[int] = None
            ) -> Tuple[int, Params, Dict]:
    """Restore into the structure of ``like`` (numpy leaves).  Shapes are
    validated; dtypes are cast to match ``like`` (supports bf16<->f32
    master-copy transitions)."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = arrays[key]
        want = getattr(leaf, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {want}")
        dt = getattr(leaf, "dtype", arr.dtype)
        leaves.append(arr.astype(dt))
    return step, jax.tree_util.tree_unflatten(treedef, leaves), meta


class AsyncCheckpointer:
    """Background-thread writer; ``wait()`` drains before exit/preemption."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, meta = item
            try:
                save(self.ckpt_dir, step, tree, meta, self.keep)
            except BaseException as e:       # surfaced on next wait()
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree: Params, metadata: Optional[Dict] = None):
        host_tree = jax.tree.map(np.asarray, tree)   # sync device->host copy
        self._q.put((step, host_tree, metadata))

    def wait(self):
        self._q.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join()
