"""Fault tolerance: supervised train loop with checkpoint/restart,
preemption handling, straggler detection, and elastic resume.

On a real cluster each host runs this supervisor around the pjit train
step; here the mechanisms are host-local but complete:

* **restart**: ``run`` resumes from the newest checkpoint (atomic writes
  guarantee a consistent one exists); a crashed/preempted run re-invoked
  with the same args continues exactly where the last checkpoint left off.
* **preemption**: SIGTERM flips a flag; the loop checkpoints at the next
  step boundary and exits cleanly (the standard TPU-maintenance dance).
* **stragglers**: per-step wall time is tracked with an EMA; steps slower
  than ``straggler_factor``× the EMA are logged as straggler events — on
  a cluster this signal feeds the scheduler (synchronous-skip / hot
  spares); here it feeds metrics and the test suite.
* **elastic**: checkpoints store only logical state (unsharded arrays +
  step).  ``run`` re-shards onto whatever mesh the caller built today, so
  a job can restart on a different device count (data-parallel rescale:
  the batch is re-split; model-parallel rescale: GSPMD resharding at
  device_put).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from . import checkpoint as ckpt

__all__ = ["SupervisorConfig", "TrainSupervisor"]


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_steps: int = 200
    straggler_factor: float = 3.0
    ema_decay: float = 0.9
    handle_sigterm: bool = True


class TrainSupervisor:
    def __init__(self, cfg: SupervisorConfig, train_step: Callable,
                 data_iter: Iterator, *, async_ckpt: bool = True):
        self.cfg = cfg
        self.train_step = train_step
        self.data = data_iter
        self.preempted = False
        self.straggler_events: List[int] = []
        self.metrics_log: List[Dict[str, float]] = []
        self._ckpt = (ckpt.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep)
                      if async_ckpt else None)
        if cfg.handle_sigterm:
            try:
                signal.signal(signal.SIGTERM, self._on_sigterm)
            except ValueError:
                pass                      # not on main thread (tests)

    def _on_sigterm(self, signum, frame):
        self.preempted = True

    def _save(self, step: int, params, opt_state):
        tree = {"params": params, "opt": opt_state}
        if self._ckpt is not None:
            self._ckpt.submit(step, tree, {"mesh_note": "logical-state-only"})
        else:
            ckpt.save(self.cfg.ckpt_dir, step, tree, keep=self.cfg.keep)

    def resume_or_init(self, params, opt_state):
        """Restore the latest checkpoint if one exists (elastic: the caller
        device_puts the returned host arrays with today's shardings)."""
        step = ckpt.latest_step(self.cfg.ckpt_dir)
        if step is None:
            return 0, params, opt_state
        like = {"params": jax.tree.map(np.asarray, params),
                "opt": jax.tree.map(np.asarray, opt_state)}
        step, tree, _ = ckpt.restore(self.cfg.ckpt_dir, like)
        return step, tree["params"], tree["opt"]

    def run(self, params, opt_state, *, start_step: int = 0,
            put: Optional[Callable] = None):
        """Run to max_steps (or preemption).  ``put`` optionally re-device-
        puts host arrays (elastic resume path).  Returns (step, params,
        opt_state, metrics_log)."""
        cfg = self.cfg
        step = start_step
        if put is not None:
            params, opt_state = put(params), put(opt_state)
        ema: Optional[float] = None
        while step < cfg.max_steps and not self.preempted:
            batch = next(self.data)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.train_step(params, opt_state,
                                                         batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if ema is not None and dt > cfg.straggler_factor * ema:
                self.straggler_events.append(step)
            ema = dt if ema is None else cfg.ema_decay * ema + (1 - cfg.ema_decay) * dt
            step += 1
            self.metrics_log.append(
                {"step": step, "loss": float(metrics["loss"]),
                 "grad_norm": float(metrics["grad_norm"]),
                 "lr": float(metrics["lr"]), "step_time_s": dt})
            if step % cfg.ckpt_every == 0 or step == cfg.max_steps:
                self._save(step, params, opt_state)
        if self.preempted:
            self._save(step, params, opt_state)   # graceful preemption save
        if self._ckpt is not None:
            self._ckpt.wait()
        return step, params, opt_state, self.metrics_log
