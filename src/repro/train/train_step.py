"""Train step: microbatched gradient accumulation + AdamW update.

The microbatch loop is a ``lax.scan`` whose per-step grads are produced
independently — under pjit this exposes the per-microbatch gradient
reductions as independent collectives that XLA's latency-hiding scheduler
overlaps with the next microbatch's compute (the compute/comm overlap
story; the dry-run HLO is checked for the independent reduce ops).
Grad accumulation is in f32 regardless of compute dtype.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from .optimizer import AdamConfig, adam_update

Params = Any

__all__ = ["make_train_step", "make_eval_step"]


def make_train_step(model, cfg: ArchConfig, opt_cfg: AdamConfig
                    ) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).  ``batch`` leaves are [B_global, ...];
    B must divide by cfg.microbatch."""

    nm = max(cfg.microbatch, 1)

    def train_step(params: Params, opt_state: Dict[str, Any],
                   batch: Dict[str, jax.Array]):
        from repro.models.layers import constrain

        def reshape(x):
            b = x.shape[0]
            assert b % nm == 0, (b, nm)
            y = x.reshape(nm, b // nm, *x.shape[1:])
            # keep the per-microbatch slices batch-sharded — without the
            # pin GSPMD falls back to "involuntary full rematerialization"
            # when slicing modality inputs out of the scan (vlm/whisper)
            return constrain(y, None, "batch", *([None] * (x.ndim - 1)))

        micro = jax.tree.map(reshape, batch)

        def micro_step(carry, mb):
            loss_acc, grads_acc = carry
            loss, grads = jax.value_and_grad(model.loss)(params, mb)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
            return (loss_acc + loss.astype(jnp.float32), grads_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(
            micro_step, (jnp.zeros((), jnp.float32), zeros), micro)
        grads = jax.tree.map(lambda g: g / nm, grads)
        loss = loss_sum / nm
        params, opt_state, metrics = adam_update(params, grads, opt_state,
                                                 opt_cfg)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        return model.loss(params, batch)
    return eval_step
