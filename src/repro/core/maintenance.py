"""HL-index maintenance under hyperedge updates (paper §V-D).

The paper sketches insert/delete maintenance but defers the algorithm;
we implement a **component-scoped label splice**: labels never cross
connected components of the line graph (a walk cannot leave a component),
so an insertion/deletion only invalidates labels whose *hub* lies in the
touched component(s).  ``_rebuild_scoped`` keeps every surviving label
(hub outside the affected set) from the old index and takes fresh labels
only for affected hubs.

Honesty note on cost: the *label content* is scoped, but the
*construction* is not — ``_rebuild_scoped`` currently calls
``build_fast`` on the **full** new graph and then discards the labels it
splices over.  Maintenance is therefore exactly equivalent to a full
rebuild in answers (asserted in tests) and in asymptotic build time; the
win is limited to preserving the untouched components' label arrays
(and their minimization state) byte-for-byte.  Running construction
restricted to the affected sub-line-graph — the actual speed-up — needs
subgraph extraction plus hub-rank remapping and is still open (see
ROADMAP.md).

Limitation (recorded): hyperedge importance is recomputed globally, so an
update that changes vertex degrees can reorder *other* components'
hyperedges; we keep the original order for untouched components (any
total order yields a correct index — order only affects minimality).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Set, Tuple

import numpy as np

from .hypergraph import Hypergraph, from_edge_lists
from .hlindex import HLIndex, build_fast
from .baselines import line_graph_edges, _DSU

__all__ = ["insert_hyperedge", "delete_hyperedge", "component_of"]


def component_of(h: Hypergraph, seeds: Sequence[int]) -> Set[int]:
    """Connected component(s) of the line graph containing ``seeds``."""
    seen: Set[int] = set(int(s) for s in seeds)
    stack = list(seen)
    while stack:
        e = stack.pop()
        nb, _ = h.neighbors_od(e)
        for e2 in nb:
            e2 = int(e2)
            if e2 not in seen:
                seen.add(e2)
                stack.append(e2)
    return seen


def _rebuild_scoped(new_h: Hypergraph, old_idx: Optional[HLIndex],
                    affected: Set[int], edge_map: dict) -> HLIndex:
    """Rebuild the index for ``affected`` hyperedges of ``new_h``; splice
    surviving labels (hub outside ``affected``) from ``old_idx`` via
    ``edge_map`` (old edge id -> new edge id, -1 = removed)."""
    sub_idx = build_fast(new_h)     # correct; scoped pruning below
    # Fast path: build_fast on the full graph already yields the right
    # answer; the *scoped* variant reuses old labels for untouched hubs.
    if old_idx is None:
        return sub_idx
    keep_hubs = {edge_map[e]: e for e in range(old_idx.h.m)
                 if edge_map.get(e, -1) >= 0 and edge_map[e] not in affected}
    le, lr, ls = [], [], []
    rank = sub_idx.rank
    for u in range(new_h.n):
        pairs = {}
        # surviving labels from the old index
        if u < old_idx.h.n:
            for e_old, s in zip(old_idx.labels_edge[u], old_idx.labels_s[u]):
                e_new = edge_map.get(int(e_old), -1)
                if e_new in keep_hubs:
                    pairs[e_new] = int(s)
        # fresh labels for affected hubs
        for e, s in zip(sub_idx.labels_edge[u], sub_idx.labels_s[u]):
            if int(e) in affected:
                pairs[int(e)] = int(s)
        if pairs:
            e_arr = np.fromiter(pairs.keys(), np.int64, len(pairs))
            s_arr = np.fromiter(pairs.values(), np.int64, len(pairs))
            order = np.argsort(rank[e_arr], kind="stable")
            e_arr, s_arr = e_arr[order], s_arr[order]
        else:
            e_arr = np.empty(0, np.int64)
            s_arr = np.empty(0, np.int64)
        le.append(e_arr)
        lr.append(rank[e_arr] if e_arr.size else np.empty(0, np.int64))
        ls.append(s_arr)
    dual_u: List[List[int]] = [[] for _ in range(new_h.m)]
    dual_s: List[List[int]] = [[] for _ in range(new_h.m)]
    for u in range(new_h.n):
        for e, s in zip(le[u], ls[u]):
            dual_u[int(e)].append(u)
            dual_s[int(e)].append(int(s))
    du = [np.array(a, np.int64) for a in dual_u]
    ds = [np.array(a, np.int64) for a in dual_s]
    return HLIndex(h=new_h, rank=rank, perm=sub_idx.perm, labels_edge=le,
                   labels_rank=lr, labels_s=ls, dual_u=du, dual_s=ds,
                   stats=dict(sub_idx.stats, maintenance_scope=len(affected)))


def insert_hyperedge(h: Hypergraph, idx: HLIndex,
                     vertices: Sequence[int]) -> Tuple[Hypergraph, HLIndex]:
    """Insert a hyperedge; returns (new graph, maintained index)."""
    n = max(int(max(vertices)) + 1, h.n)
    edges = [h.edge(e) for e in range(h.m)] + [np.asarray(vertices)]
    new_h = from_edge_lists(edges, n=n)
    new_id = new_h.m - 1
    affected = component_of(new_h, [new_id])
    edge_map = {e: e for e in range(h.m)}
    return new_h, _rebuild_scoped(new_h, idx, affected, edge_map)


def delete_hyperedge(h: Hypergraph, idx: HLIndex, edge_id: int
                     ) -> Tuple[Hypergraph, HLIndex]:
    """Delete a hyperedge; rebuilds every fragment of its old component."""
    nb, _ = h.neighbors_od(edge_id)
    edges = [h.edge(e) for e in range(h.m) if e != edge_id]
    new_h = from_edge_lists(edges, n=h.n)
    edge_map = {}
    j = 0
    for e in range(h.m):
        edge_map[e] = -1 if e == edge_id else j
        j += e != edge_id
    seeds = [edge_map[int(e)] for e in nb if edge_map[int(e)] >= 0]
    affected = component_of(new_h, seeds) if seeds else set()
    return new_h, _rebuild_scoped(new_h, idx, affected, edge_map)
