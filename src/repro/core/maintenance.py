"""HL-index maintenance under hyperedge updates (paper §V-D).

The paper sketches insert/delete maintenance but defers the algorithm;
we implement **component-scoped maintenance**: labels never cross
connected components of the line graph (a walk cannot leave a
component), so an insertion/deletion only invalidates labels whose hub
lies in the touched component(s).  Both the label *content* and the
label *construction* are scoped:

1. ``apply_edge_edits`` (hypergraph.py) applies the graph edit and
   reports the 1-hop touched hyperedges; ``component_of`` expands them
   to the affected line-graph component(s) of the new graph.
2. ``induced_subhypergraph`` extracts exactly those components and the
   construction algorithm (``build_fast`` by default) runs on the
   sub-hypergraph alone — the full graph is never re-traversed.
3. ``splice_rank`` (hlindex.py) composes a global importance rank —
   surviving out-of-scope hyperedges keep their old relative order,
   in-scope hyperedges follow in sub-index order — and the splice maps
   the sub-index's labels back into the global id space.  Vertices
   outside the scope keep their label arrays (and any minimization
   state) byte-for-byte; vertices inside get the fresh sub-labels.

Why the splice is exact: the scope is a union of whole components of
the *new* line graph.  Every fragment of a deleted hyperedge's old
component contains one of its old neighbors (take the last hyperedge
before the deleted one on any old path into the fragment), so seeding
the BFS with those neighbors covers all fragments; an inserted
hyperedge seeds its own merged component.  A vertex is incident either
only to in-scope or only to out-of-scope hyperedges (hyperedges sharing
a vertex are line-graph adjacent), so each label list is rebuilt whole
or kept whole — never mixed — and cross-group rank order is
unobservable by any query.

Limitation (recorded): hyperedge importance is recomputed only inside
the scope, so an update that changes vertex degrees can in principle
reorder *other* components' hyperedges; we keep the original order for
untouched components (any total order yields a correct index — order
only affects minimality).

``builder`` is any callable producing an ``HLIndex`` for the scope's
sub-hypergraph — ``build_fast`` (default), ``build_basic``, or the
component-sharded ``build_sharded`` (``repro.core.hlindex``), whose
output is byte-identical to ``build_fast`` so the splice composes with
shard-built indexes unchanged: ``splice_rank`` consumes the sub-index's
rank array as an opaque order (sharded construction reproduces the
serial one exactly), and the spliced label arrays are the sub-index's
own.  The engine layer wires this up via
``build_engine(h, "hl-index", construction="sharded")`` — updates then
reconstruct the affected component(s) with the same sharded builder.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .hypergraph import (Hypergraph, NeighborCSR, apply_edge_edits,
                         induced_subhypergraph)
from .hlindex import HLIndex, build_fast, splice_rank

__all__ = ["insert_hyperedge", "delete_hyperedge", "apply_updates",
           "component_of", "normalize_update_batch", "UpdateReport"]


def normalize_update_batch(h: Hypergraph, inserts: Sequence[Iterable[int]] = (),
                           deletes: Sequence[int] = ()
                           ) -> Tuple[List[List[int]], List[int]]:
    """Validate and canonicalize one update batch *before* it is applied
    (or journaled — the WAL layer calls this first so a rejected batch is
    never written durably).

    Mirrors ``apply_edge_edits`` exactly: deletes must name existing
    hyperedges of ``h`` (same ``IndexError``), inserts dedup-sort their
    members and drop empties (same ``IndexError`` on negative vertex
    ids), and non-empty inserts keep their argument order (their appended
    hyperedge ids depend on it).  Applying the canonical batch is
    byte-identical to applying the original, so a replayed WAL record
    reproduces the live update bit for bit.

    Returns ``(inserts, deletes)`` as plain nested int lists — directly
    JSON-serializable for the journal.
    """
    dels = sorted({int(d) for d in deletes})
    for d in dels:
        if not 0 <= d < h.m:
            raise IndexError(f"delete of hyperedge {d} out of range "
                             f"[0, {h.m})")
    ins: List[List[int]] = []
    for ed in inserts:
        arr = np.unique(np.asarray(list(ed), dtype=np.int64))
        if arr.size == 0:
            continue
        if arr.min() < 0:
            raise IndexError(f"insert with negative vertex id {arr.min()}")
        ins.append([int(x) for x in arr])
    return ins, dels


@dataclasses.dataclass(frozen=True)
class UpdateReport:
    """What a maintenance step touched — the dirty-rows contract consumed
    by snapshot caching (``engine.snapshot()`` re-derives only these label
    rows; the serving layer patches only these rows of a mesh-resident
    snapshot).

    * ``scope`` — hyperedges whose labels were rebuilt (the affected
      line-graph component(s)).
    * ``refreshed_vertices`` — sorted vertex ids whose ``(labels_rank,
      labels_s)`` arrays may differ from the pre-update index.  Every
      other vertex's label row is byte-identical (the splice keeps the
      arrays by reference and ``splice_rank`` preserves out-of-scope rank
      values), so a padded snapshot only needs these rows re-derived.
    * ``full_rebuild`` — True when the whole index was rebuilt (scope
      covered the graph, rank key space exhausted, or there was no old
      index); ``refreshed_vertices`` then covers every vertex.
    * ``neighbors`` — the 1-hop-patched ``NeighborCSR`` for the new
      graph, when the caller passed one in (``apply_updates(...,
      neighbors=)``); callers that keep a persistent neighbor index feed
      it back into the next update so no full O(Σd²) pair pass ever
      reruns.
    """

    scope: int
    refreshed_vertices: np.ndarray
    full_rebuild: bool
    neighbors: Optional[NeighborCSR] = None


def component_of(h: Hypergraph, seeds: Sequence[int],
                 neighbors: Optional[NeighborCSR] = None) -> Set[int]:
    """Connected component(s) of the line graph containing ``seeds``.
    With ``neighbors`` the BFS reads precomputed CSR rows instead of
    recomputing each neighborhood on the fly."""
    row = neighbors.row if neighbors is not None else h.neighbors_od
    seen: Set[int] = set(int(s) for s in seeds)
    stack = list(seen)
    while stack:
        e = stack.pop()
        nb, _ = row(e)
        for e2 in nb:
            e2 = int(e2)
            if e2 not in seen:
                seen.add(e2)
                stack.append(e2)
    return seen


def _splice(new_h: Hypergraph, old_idx: HLIndex, old_to_new: np.ndarray,
            scope: np.ndarray, refresh_vertices: np.ndarray,
            builder: Callable[[Hypergraph], HLIndex],
            minimizer: Optional[Callable[[HLIndex], HLIndex]],
            identity_map: bool,
            neighbors: Optional[NeighborCSR] = None
            ) -> Tuple[HLIndex, np.ndarray]:
    """Build the index for the ``scope`` hyperedges of ``new_h`` only and
    splice it over the surviving labels of ``old_idx``.  With
    ``identity_map`` (no deletions: hyperedge ids unshifted) untouched
    vertices share all three label arrays with the old index; rank
    values of out-of-scope hyperedges are preserved by ``splice_rank``,
    so ``labels_rank`` is shared in both cases.  ``neighbors`` (the
    patched CSR over ``new_h``) is restricted to the scope and handed to
    the builder, so scope construction never recomputes neighborhoods.
    Returns ``(new_idx, refreshed_vertices)`` — the rows whose label
    content changed."""
    if scope.size:
        sub_h, sub_verts = induced_subhypergraph(new_h, scope)
        sub_idx = (builder(sub_h, neighbors=neighbors.induced(scope))
                   if neighbors is not None else builder(sub_h))
        if minimizer is not None:
            sub_idx = minimizer(sub_idx)
        sub_rank = sub_idx.rank
        if sub_rank.shape[0] != sub_h.m:
            raise ValueError(
                f"builder returned an index over {sub_rank.shape[0]} "
                f"hyperedges for a scope of {sub_h.m} — the splice needs "
                f"one rank key per in-scope hyperedge")
    else:
        sub_h, sub_verts = None, np.empty(0, np.int64)
        sub_idx, sub_rank = None, np.empty(0, np.int64)

    rank = splice_rank(old_idx.rank, old_to_new, scope, sub_rank, new_h.m)
    perm = np.argsort(rank)

    refresh = np.zeros(new_h.n, bool)
    refresh[sub_verts] = True
    refresh[refresh_vertices[refresh_vertices < new_h.n]] = True
    local_of = np.full(new_h.n, -1, np.int64)
    local_of[sub_verts] = np.arange(sub_verts.size)

    # out-of-scope vertices share all label arrays with the old index
    # (never mutated; splice_rank preserved their hubs' rank values) —
    # only hyperedge ids need remapping, and only when deletions shifted
    # ids.  Start from whole-list copies and patch the refreshed rows.
    empty = np.empty(0, np.int64)
    pad = [empty] * (new_h.n - old_idx.h.n)
    le: List[np.ndarray] = list(old_idx.labels_edge) + pad
    lr: List[np.ndarray] = list(old_idx.labels_rank) + pad
    ls: List[np.ndarray] = list(old_idx.labels_s) + pad
    if not identity_map:
        for u in range(old_idx.h.n):
            if le[u].size and not refresh[u]:
                le[u] = old_to_new[le[u]]
    for u in np.nonzero(refresh)[0]:
        lu = int(local_of[u])
        if lu >= 0:
            e = scope[sub_idx.labels_edge[lu]]
            le[u] = e
            lr[u] = rank[e] if e.size else empty
            ls[u] = sub_idx.labels_s[lu]
        else:                           # lost its last hyperedge: no labels
            le[u] = lr[u] = ls[u] = empty

    # duals: vertex ids are never renumbered, so out-of-scope hyperedges
    # keep their (vertex, s) arrays; in-scope ones come from the sub-index
    if identity_map:
        du: List[np.ndarray] = list(old_idx.dual_u) + [empty] * (
            new_h.m - old_idx.h.m)
        ds: List[np.ndarray] = list(old_idx.dual_s) + [empty] * (
            new_h.m - old_idx.h.m)
    else:
        kept_old = np.nonzero(old_to_new >= 0)[0]
        du = [old_idx.dual_u[int(e)] for e in kept_old]
        ds = [old_idx.dual_s[int(e)] for e in kept_old]
        du += [empty] * (new_h.m - len(du))
        ds += [empty] * (new_h.m - len(ds))
    for loc, e in enumerate(scope):
        du[int(e)] = sub_verts[sub_idx.dual_u[loc]]
        ds[int(e)] = sub_idx.dual_s[loc]

    stats = dict(old_idx.stats)
    if sub_idx is not None:
        for key, val in sub_idx.stats.items():
            stats[f"sub_{key}"] = val
    stats["maintenance_scope"] = int(scope.size)
    stats["maintenance_subgraph_m"] = int(sub_h.m) if sub_h is not None else 0
    idx = HLIndex(h=new_h, rank=rank, perm=perm, labels_edge=le,
                  labels_rank=lr, labels_s=ls, dual_u=du, dual_s=ds,
                  stats=stats)
    # new vertices (n grew) are refreshed by construction: they either got
    # fresh sub-labels or start empty — both differ from "no row at all"
    refreshed = refresh.copy()
    refreshed[old_idx.h.n:] = True
    return idx, np.nonzero(refreshed)[0]


def apply_updates(h: Hypergraph, idx: Optional[HLIndex],
                  inserts: Sequence[Iterable[int]] = (),
                  deletes: Sequence[int] = (), *,
                  builder: Callable[[Hypergraph], HLIndex] = build_fast,
                  minimizer: Optional[Callable[[HLIndex], HLIndex]] = None,
                  neighbors: Optional[NeighborCSR] = None
                  ) -> Tuple[Hypergraph, HLIndex, UpdateReport]:
    """Apply a batch of hyperedge inserts/deletes and maintain the index.

    Returns ``(new_h, new_idx, report)``.  Construction runs only on the
    affected line-graph component(s) (``builder`` on the extracted
    sub-hypergraph, ``minimizer`` applied to the sub-index if given);
    everything else is spliced from ``idx``.  ``idx=None`` builds from
    scratch.  The ``UpdateReport`` names the vertex rows whose label
    content changed — the dirty-rows contract snapshot caching consumes.

    ``neighbors`` — a ``NeighborCSR`` over ``h``.  It is 1-hop patched to
    the new graph (``NeighborCSR.updated``), drives the component BFS and
    the scope builder, and comes back in ``report.neighbors`` so a
    persistent caller (the sharded engine) pays the full pair pass at
    most once, at build time.  Answers are exactly those of a full
    rebuild (asserted in tests/test_maintenance.py and
    tests/test_property.py).
    """
    new_h, old_to_new, touched = apply_edge_edits(h, inserts, deletes)
    nbr = (neighbors.updated(new_h, old_to_new, touched)
           if neighbors is not None else None)

    def rebuilt(scope_size: int) -> Tuple[Hypergraph, HLIndex, UpdateReport]:
        new_idx = (builder(new_h, neighbors=nbr) if nbr is not None
                   else builder(new_h))
        if minimizer is not None:
            new_idx = minimizer(new_idx)
        new_idx.stats["maintenance_scope"] = scope_size
        new_idx.stats["maintenance_subgraph_m"] = int(new_h.m)
        return new_h, new_idx, UpdateReport(
            scope=scope_size, refreshed_vertices=np.arange(new_h.n),
            full_rebuild=True, neighbors=nbr)

    if idx is None:
        return rebuilt(int(new_h.m))
    affected = (component_of(new_h, touched, neighbors=nbr)
                if touched.size else set())
    scope = np.fromiter(sorted(affected), np.int64, len(affected))
    # vertices of deleted hyperedges may have lost their last hyperedge
    # (degree 0 in new_h) without being incident to any in-scope edge —
    # their stale labels must be dropped, so force-refresh them
    refresh_extra = (np.unique(np.concatenate(
        [h.edge(int(d)) for d in deletes])) if len(deletes)
        else np.empty(0, np.int64))
    rank_headroom = (int(idx.rank.max()) if idx.rank.size else 0) < 2 ** 30
    if scope.size == new_h.m or not rank_headroom:
        # everything affected (or the sparse rank key space ran out after
        # ~2^30 cumulative scope edges): plain dense rebuild
        return rebuilt(int(scope.size))
    new_idx, refreshed = _splice(new_h, idx, old_to_new, scope,
                                 refresh_extra, builder, minimizer,
                                 identity_map=not len(deletes),
                                 neighbors=nbr)
    return new_h, new_idx, UpdateReport(scope=int(scope.size),
                                        refreshed_vertices=refreshed,
                                        full_rebuild=False, neighbors=nbr)


def insert_hyperedge(h: Hypergraph, idx: HLIndex,
                     vertices: Sequence[int]) -> Tuple[Hypergraph, HLIndex]:
    """Insert a hyperedge; returns (new graph, maintained index)."""
    new_h, new_idx, _ = apply_updates(h, idx, inserts=[vertices])
    return new_h, new_idx


def delete_hyperedge(h: Hypergraph, idx: HLIndex, edge_id: int
                     ) -> Tuple[Hypergraph, HLIndex]:
    """Delete a hyperedge; rebuilds every fragment of its old component."""
    new_h, new_idx, _ = apply_updates(h, idx, deletes=[edge_id])
    return new_h, new_idx
