"""Sparse frontier engine: batched s-reachability / MR on the CSR line
graph — the scalable counterpart to the dense closures.

The dense (max,min)/threshold closures (semiring.py, distributed.py) cost
O(m²) memory; beyond m ≈ 10⁵ the line graph no longer fits even sharded.
This engine keeps the line graph *sparse* (edge list with overlap
degrees) and answers batched queries with data-parallel frontier sweeps:

  * ``frontier_batched_s_reach``: [Q] query pairs × one threshold s —
    boolean frontier propagation, one scatter-max per round, O(rounds · E)
    work on [Q, m] lanes (VPU-friendly: the scatter is a segment-max).
  * ``frontier_batched_mr``: binary search over the threshold ladder —
    log₂|S| sweeps (the bisection idea from §Perf C applied to the sparse
    form).

(The unprefixed names ``batched_s_reach`` / ``batched_mr`` collided with
the label-join engine in query.py; the deprecated aliases introduced
when the collision was fixed have been removed — ``batched_mr`` is
query.py's label join, the frontier sweeps are the ``frontier_``-
prefixed functions here, and serving code routes through ``repro.api``.)

Rounds follow *linear* diameter (not the squaring closure's log₂), but
each round is O(E) instead of O(m²) — the standard sparse/dense trade.
Validated against the oracle in tests.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hypergraph import Hypergraph
from .baselines import line_graph_edges

__all__ = ["SparseLineGraph", "frontier_batched_s_reach",
           "frontier_batched_mr"]


class SparseLineGraph:
    """Symmetrized line-graph edge list on device.

    The unsymmetrized host COO half-list is kept (``_coo``) so hyperedge
    updates can patch the structure incrementally (``updated``) instead
    of re-walking every neighborhood.
    """

    def __init__(self, h: Hypergraph,
                 _coo: Optional[Tuple[np.ndarray, np.ndarray,
                                      np.ndarray]] = None):
        src, dst, od = line_graph_edges(h) if _coo is None else _coo
        self.h = h
        self._coo = (src, dst, od)
        self.src = jnp.asarray(np.concatenate([src, dst]), jnp.int32)
        self.dst = jnp.asarray(np.concatenate([dst, src]), jnp.int32)
        self.od = jnp.asarray(np.concatenate([od, od]), jnp.int32)
        self.sizes = jnp.asarray(h.edge_sizes, jnp.int32)
        self.thresholds = np.unique(np.concatenate(
            [np.asarray(od), np.asarray(h.edge_sizes)]))
        self.thresholds = self.thresholds[self.thresholds > 0]

    def updated(self, new_h: Hypergraph, old_to_new: np.ndarray,
                touched) -> "SparseLineGraph":
        """Line graph of the edited hypergraph, patched incrementally:
        pairs with both endpoints outside ``touched`` (new ids — see
        ``apply_edge_edits``) are kept with ids remapped; overlaps are
        recomputed only for the 1-hop touched set.  Overlap degrees of
        untouched pairs cannot have changed (both endpoint vertex sets
        are unchanged), so the splice is exact."""
        src, dst, od = self._coo
        if new_h.m == 0:                # graph emptied: no line graph left
            empty = np.empty(0, np.int64)
            return SparseLineGraph(new_h, _coo=(empty, empty, empty))
        s2 = old_to_new[src] if src.size else src
        d2 = old_to_new[dst] if dst.size else dst
        touched_mask = np.zeros(new_h.m, bool)
        touched_mask[np.asarray(touched, np.int64)] = True
        keep = (s2 >= 0) & (d2 >= 0)
        keep &= ~(touched_mask[np.clip(s2, 0, None)]
                  | touched_mask[np.clip(d2, 0, None)])
        srcs, dsts, ods = [s2[keep]], [d2[keep]], [od[keep]]
        for t in np.asarray(touched, np.int64):
            t = int(t)
            nb, w = new_h.neighbors_od(t)
            # pair (t, x): untouched x is only generated from t's side;
            # touched x is generated from both — keep the t < x copy
            sel = (~touched_mask[nb]) | (nb > t)
            srcs.append(np.full(int(sel.sum()), t, np.int64))
            dsts.append(nb[sel])
            ods.append(w[sel])
        return SparseLineGraph(new_h, _coo=(np.concatenate(srcs),
                                            np.concatenate(dsts),
                                            np.concatenate(ods)))

    def seed(self, vertices) -> jax.Array:
        """[Q, m] boolean: hyperedges incident to each query vertex."""
        h = self.h
        out = np.zeros((len(vertices), h.m), bool)
        for q, u in enumerate(vertices):
            out[q, h.edges_of(int(u))] = True
        return jnp.asarray(out)


@functools.partial(jax.jit, static_argnames=("rounds",))
def _sweep(src, dst, od, seeds_u, seeds_v, sizes, s, rounds: int):
    """[Q] bools: does any ≥s walk join a u-seed edge to a v-seed edge."""
    alive_edge = od >= s                              # line-graph edges kept
    alive_node = sizes >= s                           # |e| ≥ s for seeds
    reach = seeds_u & alive_node[None, :]

    def body(reach, _):
        contrib = reach[:, src] & alive_edge[None, :]     # [Q, E2]
        new = reach.at[:, dst].max(contrib)
        return new, None

    reach, _ = jax.lax.scan(body, reach, None, length=rounds)
    return (reach & seeds_v & alive_node[None, :]).any(axis=1)


def frontier_batched_s_reach(g: SparseLineGraph, us, vs, s: int,
                             rounds: Optional[int] = None) -> np.ndarray:
    """u ~s~> v for each query pair (boolean [Q])."""
    r = rounds if rounds is not None else g.h.m
    r = min(r, g.h.m)
    su = g.seed(us)
    sv = g.seed(vs)
    return np.asarray(_sweep(g.src, g.dst, g.od, su, sv, g.sizes,
                             jnp.int32(s), r))


def frontier_batched_mr(g: SparseLineGraph, us, vs,
                        rounds: Optional[int] = None) -> np.ndarray:
    """MR(u, v) per query pair via bisection over the threshold ladder
    (log₂|S| frontier sweeps total)."""
    thr = g.thresholds
    q = len(us)
    lo = np.zeros(q, np.int64)              # index into thr of best-known-true
    ok0 = frontier_batched_s_reach(g, us, vs, int(thr[0]), rounds) \
        if thr.size else np.zeros(q, bool)
    # lo/hi are ladder indices; answer = thr[best] where reachable
    best = np.full(q, -1, np.int64)
    best[ok0] = 0
    lo_i = np.zeros(q, np.int64)
    hi_i = np.full(q, thr.size - 1, np.int64)
    active = ok0.copy()
    # per-query bisection, batched: all active queries test their own mid
    # threshold — we group by distinct mid values per iteration
    for _ in range(int(np.ceil(np.log2(max(thr.size, 2)))) + 1):
        if not active.any():
            break
        mids = (lo_i + hi_i + 1) // 2
        for t_idx in np.unique(mids[active]):
            sel = active & (mids == t_idx)
            if not sel.any():
                continue
            ok = frontier_batched_s_reach(g, np.asarray(us)[sel],
                                          np.asarray(vs)[sel],
                                          int(thr[t_idx]), rounds)
            idx = np.nonzero(sel)[0]
            reach_idx = idx[ok]
            fail_idx = idx[~ok]
            lo_i[reach_idx] = mids[reach_idx]
            best[reach_idx] = mids[reach_idx]
            hi_i[fail_idx] = mids[fail_idx] - 1
        done = lo_i >= hi_i
        active &= ~done
    out = np.zeros(q, np.int64)
    mask = best >= 0
    out[mask] = thr[best[mask]]
    return out
