"""(max, min)-semiring formulation of max-reachability — the TPU-native
re-expression of the paper's problem, and the oracle for all tests.

Key identity (Section 2 of DESIGN.md): with ``W[i,j] = OD(e_i, e_j)``
(diagonal ``|e_i|``), the hyperedge-level max-reachability matrix is the
bottleneck-path closure ``W*`` under the (max, min) semiring, and

    MR(u, v) = max_{e_u ∋ u, e_v ∋ v} W*[e_u, e_v].

Two closure strategies:

* ``maxmin_closure`` — repeated squaring with the (max, min) matmul.
  Exact, O(log diam) rounds of an m³ VPU op (no MXU semiring support).
* ``threshold_closure_mr`` — re-expresses the same closure as a batch of
  *boolean* transitive closures over overlap thresholds, each computed
  with real bf16/f32 matmuls → MXU work.  ``MR[i,j] = max{s : reach_s}``.
  Exact when ``thresholds`` = all distinct OD values (the default).

Both consume the dense line graph; the framework's scalability story for
huge hypergraphs is the 2-D block-sharded version in ``distributed.py``.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .hypergraph import Hypergraph

__all__ = [
    "maxmin_matmul", "maxmin_closure", "boolean_closure",
    "threshold_closure_mr", "mr_matrix", "mr_oracle_dense",
    "vertex_mr_from_edge_mr", "distinct_thresholds",
]


def maxmin_matmul(a: jax.Array, b: jax.Array, *, block: int = 512) -> jax.Array:
    """C[i,j] = max_k min(A[i,k], B[k,j]) for non-negative inputs.

    Pure-jnp reference; the Pallas kernel (kernels/maxmin_matmul.py)
    implements the same contraction with explicit VMEM tiling.  Blocked
    over k to bound the [i,k,j] broadcast.  Zero is the (max, min)
    annihilator/identity pair on the non-negative domain, so zero padding
    of the contraction dim is exact.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    if k <= block:
        return jnp.minimum(a[:, :, None], b[None, :, :]).max(axis=1)
    pad = (-k) % block
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, pad), (0, 0)))

    def body(carry, kk):
        a_blk = jax.lax.dynamic_slice(a, (0, kk), (m, block))
        b_blk = jax.lax.dynamic_slice(b, (kk, 0), (block, n))
        c = jnp.minimum(a_blk[:, :, None], b_blk[None, :, :]).max(axis=1)
        return jnp.maximum(carry, c), None

    init = jnp.zeros((m, n), a.dtype)
    nblk = (k + pad) // block
    out, _ = jax.lax.scan(body, init, jnp.arange(nblk) * block)
    return out


def maxmin_closure(w: jax.Array, *, block: int = 512,
                   max_rounds: Optional[int] = None) -> jax.Array:
    """Bottleneck-path closure by repeated squaring:
    R ← max(R, R∘R) until fixpoint (≤ ⌈log2 m⌉ rounds)."""
    m = w.shape[0]
    rounds = max_rounds if max_rounds is not None else max(1, int(np.ceil(np.log2(max(m, 2)))))

    def step(r, _):
        r2 = jnp.maximum(r, maxmin_matmul(r, r, block=block))
        return r2, None

    out, _ = jax.lax.scan(step, w, None, length=rounds)
    return out


def boolean_closure(adj: jax.Array, *, rounds: Optional[int] = None) -> jax.Array:
    """Transitive closure of a boolean adjacency (float 0/1) via repeated
    squaring with *real* matmuls — the MXU-friendly primitive.
    adj must include self-loops for closure semantics."""
    m = adj.shape[-1]
    n_rounds = rounds if rounds is not None else max(1, int(np.ceil(np.log2(max(m, 2)))))

    def step(r, _):
        r2 = (r @ r > 0).astype(adj.dtype)
        return r2, None

    out, _ = jax.lax.scan(step, adj, None, length=n_rounds)
    return out


def closure_rounds_to_fixpoint(w: jax.Array, *, block: int = 512,
                               max_rounds: int = 64) -> int:
    """Squaring rounds until the bottleneck closure stops changing —
    ⌈log2(effective s-walk diameter)⌉, typically 3-6 on real hypergraphs
    vs the worst-case ⌈log2 m⌉ ladder.  The measured number drives the
    early-exit optimization in §Perf C (a host-side convergence check per
    round costs one [m,m] equality-reduce)."""
    r = w
    for i in range(1, max_rounds + 1):
        r2 = jnp.maximum(r, maxmin_matmul(r, r, block=block))
        if bool(jnp.array_equal(r2, r)):
            return i
        r = r2
    return max_rounds


def distinct_thresholds(w: np.ndarray) -> np.ndarray:
    """All distinct positive entries of the line graph (off-diagonal OD
    values and diagonal |e| values), ascending."""
    vals = np.unique(w)
    return vals[vals > 0]


@functools.partial(jax.jit, static_argnames=("rounds",))
def _threshold_batch_closure(w: jax.Array, thresholds: jax.Array,
                             rounds: int) -> jax.Array:
    """reach[s_idx, i, j] ∈ {0,1}: closure of (W ≥ t) per threshold.
    vmap over the threshold batch → one batched matmul per squaring round
    (a [S, m, m] × [S, m, m] batched contraction: pure MXU work)."""
    adj = (w[None, :, :] >= thresholds[:, None, None]).astype(jnp.float32)
    eye = jnp.eye(w.shape[0], dtype=jnp.float32)[None]
    adj = jnp.maximum(adj, eye)

    def step(r, _):
        return (jax.lax.batch_matmul(r, r) > 0).astype(jnp.float32), None

    out, _ = jax.lax.scan(step, adj, None, length=rounds)
    return out


def threshold_closure_mr(w: jax.Array, thresholds: Optional[np.ndarray] = None,
                         *, rounds: Optional[int] = None) -> jax.Array:
    """MR matrix via threshold-batched boolean closure.

    Exact iff ``thresholds`` covers every distinct positive value of W
    (default).  A coarser ladder gives a lower bound — the bucketized
    (approximate) mode used when δ is huge; see DESIGN.md §2.
    """
    if thresholds is None:
        thresholds = distinct_thresholds(np.asarray(w))
    thresholds = np.asarray(thresholds)
    if thresholds.size == 0:
        return jnp.zeros_like(w)
    m = w.shape[0]
    n_rounds = rounds if rounds is not None else max(1, int(np.ceil(np.log2(max(m, 2)))))
    reach = _threshold_batch_closure(jnp.asarray(w), jnp.asarray(thresholds),
                                     n_rounds)                     # [S, m, m]
    # MR[i,j] = largest threshold whose closure connects i and j.
    t = jnp.asarray(thresholds).astype(w.dtype)
    mr = (reach * t[:, None, None]).max(axis=0)
    # reach includes the trivial i==i at every threshold via self-loops; fix
    # the diagonal to the true single-walk value |e_i| = W[i,i].
    mr = mr.at[jnp.arange(m), jnp.arange(m)].set(jnp.diagonal(w))
    return mr


def mr_matrix(h: Hypergraph, *, method: str = "maxmin") -> np.ndarray:
    """Hyperedge-level MR matrix W* for a whole hypergraph."""
    if h.m == 0:                # no hyperedges: nothing is reachable
        return np.zeros((0, 0), np.int32)
    w = jnp.asarray(h.line_graph(np.int32))
    if method == "maxmin":
        return np.asarray(maxmin_closure(w))
    if method == "threshold":
        return np.asarray(threshold_closure_mr(w)).astype(np.int32)
    raise ValueError(method)


def vertex_mr_from_edge_mr(h: Hypergraph, w_star: np.ndarray,
                           us: Sequence[int], vs: Sequence[int]) -> np.ndarray:
    """MR(u, v) = max over incident hyperedge pairs of W*."""
    out = np.zeros(len(us), w_star.dtype)
    for q, (u, v) in enumerate(zip(us, vs)):
        eu = h.edges_of(int(u))
        ev = h.edges_of(int(v))
        if eu.size and ev.size:
            out[q] = w_star[np.ix_(eu, ev)].max()
    return out


def mr_oracle_dense(h: Hypergraph) -> np.ndarray:
    """Full vertex-level MR matrix [n, n] (tests on small graphs only)."""
    w_star = mr_matrix(h)
    out = np.zeros((h.n, h.n), w_star.dtype)
    for u in range(h.n):
        eu = h.edges_of(u)
        if not eu.size:
            continue
        rows = w_star[eu, :]                      # [deg(u), m]
        for v in range(h.n):
            ev = h.edges_of(v)
            if ev.size:
                out[u, v] = rows[:, ev].max()
    return out
