"""HL-index construction — Algorithms 2 (basic) and 3 (fast).

The HL-index is a vertex-to-hyperedge (VTE) labeling: ``L(u) = {(e, s)}``
meaning ``u ~s~> e``.  Construction processes hyperedges in descending
importance (w(e) = Σ_{v∈e}|E(v)|², ties by smaller id) and runs a pruned
bottleneck-Dijkstra from each root hyperedge.

Algorithm 3's two optimizations, implemented faithfully:

* **MCD** (maximum cover degree, Def. 8 / Lemmas 4-5): the transitive-cover
  check collapses to comparing the candidate step overlap with ``MCD(root)``
  — a scalar maintained for free as walks visit hyperedges.
* **neighbor-index M** (Lemma 6): ``N(e)`` is computed exactly once, stored
  sparsely, and entries proven redundant (``OD(e_u,e_v) ≤ WOD(walk to
  e_u)``) are evicted eagerly, keeping the peak size far below the full
  adjacency.

Implementation notes vs the pseudocode (documented deviations):
  * line 9 (``MCD(e_u) ← max(s, MCD(e_u))``) is skipped for the root pop —
    otherwise ``MCD(e) = |e|`` would prune the root's own traversal; the
    paper's text ("MCD(e) equals its lower bound when construction from e
    starts") implies the root's MCD is read once, before the loop.
  * pushes re-check ``O(e_v) > O(root)`` explicitly: ``M(e_u)`` may have
    been initialized under an earlier root with higher importance, so the
    line-17 exclusion alone does not cover the current root (Lemma 3 is
    the justification either way).
  * a stale-pop guard skips queue duplicates (first pop carries max s).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from .hypergraph import Hypergraph

__all__ = ["HLIndex", "build_basic", "build_fast", "pad_label_rows",
           "splice_rank"]


def splice_rank(old_rank: np.ndarray, old_to_new: np.ndarray,
                sub_edges: np.ndarray, sub_rank: np.ndarray,
                m_new: int) -> np.ndarray:
    """Compose a global importance rank for a graph after scoped
    maintenance: surviving hyperedges outside the rebuilt scope keep
    their old rank *values* unchanged, hyperedges inside the scope get
    fresh keys above every old value, ordered by sub-index importance.

    Keeping old values (rather than recompacting to ``0..m_new-1``)
    means the untouched vertices' ``labels_rank`` arrays stay valid
    byte-for-byte and are reused by the splice without a regather — rank
    is an opaque sort key everywhere it is consumed (merge-joins, padded
    snapshots, ``perm = argsort(rank)``), never a dense array index, so
    gaps are harmless.  Keys stay far below the int32 padding sentinel:
    each update raises the maximum by at most the scope size, and
    ``apply_updates`` falls back to a dense rebuild before ``2^30``.

    ``sub_edges`` [m_sub] maps local sub-index hyperedge ids to new
    global ids; ``sub_rank`` [m_sub] is the sub-index's own rank array.
    Requires the scope to be a union of whole line-graph components —
    then no label list ever mixes hubs from the two groups, so how the
    groups interleave cannot affect any merge-join (rank is only ever
    compared between hubs reachable from a common vertex), and any total
    order per group yields a correct index (order only affects
    minimality).
    """
    new_rank = np.full(m_new, -1, np.int64)
    old_ids = np.nonzero(old_to_new >= 0)[0]
    new_rank[old_to_new[old_ids]] = old_rank[old_ids]
    base = int(old_rank.max()) + 1 if old_rank.size else 0
    new_rank[sub_edges] = base + sub_rank
    if (new_rank < 0).any():
        raise ValueError("splice_rank: some hyperedge is neither a "
                         "surviving edge nor in the scope")
    return new_rank


def pad_label_rows(row_ranks, row_svals, pad_to=None):
    """Pack ragged per-vertex (rank, s) label rows into the padded dense
    form consumed by the batched query engine: one concatenate + fancy-
    index scatter, no per-row Python copies.

    Returns (ranks [n, Lmax] int32 ascending with INT32_MAX padding,
    svals [n, Lmax] int32 with 0 padding, lengths [n] int32).
    """
    n = len(row_ranks)
    lengths = np.array([a.size for a in row_svals], np.int32)
    lmax = int(pad_to if pad_to is not None else (lengths.max() if n else 0))
    ranks = np.full((n, lmax), np.iinfo(np.int32).max, np.int32)
    svals = np.zeros((n, lmax), np.int32)
    total = int(lengths.sum())
    if total and lmax:
        rows = np.repeat(np.arange(n), lengths)
        starts = np.cumsum(lengths, dtype=np.int64) - lengths
        cols = np.arange(total, dtype=np.int64) - np.repeat(starts, lengths)
        ranks[rows, cols] = np.concatenate(row_ranks)
        svals[rows, cols] = np.concatenate(row_svals)
    return ranks, svals, lengths


@dataclasses.dataclass
class HLIndex:
    """Per-vertex labels, sorted by hyperedge importance rank (ascending)."""

    h: Hypergraph
    rank: np.ndarray                  # [m] importance rank of each hyperedge
    perm: np.ndarray                  # [m] perm[rank] = hyperedge id
    labels_edge: List[np.ndarray]     # per vertex: hyperedge ids
    labels_rank: List[np.ndarray]     # per vertex: ranks (ascending — merge key)
    labels_s: List[np.ndarray]        # per vertex: s values
    dual_u: List[np.ndarray]          # per hyperedge: vertices (D(e))
    dual_s: List[np.ndarray]          # per hyperedge: s values (non-ascending)
    stats: Dict[str, float]

    @property
    def num_labels(self) -> int:
        return int(sum(a.size for a in self.labels_s))

    def label_dict(self, u: int) -> Dict[int, int]:
        return {int(e): int(s) for e, s in
                zip(self.labels_edge[u], self.labels_s[u])}

    def nbytes(self) -> int:
        """Index size: one (hyperedge id, s) pair per label, 4+4 bytes."""
        return self.num_labels * 8

    def as_padded(self, pad_to: Optional[int] = None):
        """Dense padded export for the JAX batched query engine.

        Returns (ranks [n, Lmax] int32 ascending with INT32_MAX padding,
        svals [n, Lmax] int32 with 0 padding, lengths [n]).
        """
        return pad_label_rows(self.labels_rank, self.labels_s, pad_to)


class _Builder:
    """Shared state for Algorithms 2/3."""

    def __init__(self, h: Hypergraph):
        self.h = h
        self.rank = h.importance_order()
        self.perm = np.argsort(self.rank)
        self.sizes = h.edge_sizes
        self.labels: List[List[Tuple[int, int]]] = [[] for _ in range(h.n)]
        self.dual: List[List[Tuple[int, int]]] = [[] for _ in range(h.m)]
        self.visited_v = np.full(h.n, -1, np.int64)
        self.visited_e = np.full(h.m, -1, np.int64)
        self.stats: Dict[str, float] = dict(pops=0, pushes=0, neighbor_inits=0,
                                            m_peak_entries=0, m_total_inserts=0,
                                            cover_checks=0)

    def add_labels(self, root: int, e_u: int, s: int) -> None:
        for u in self.h.edge(e_u):
            u = int(u)
            if self.visited_v[u] == root:
                continue
            self.visited_v[u] = root
            self.labels[u].append((root, s))
            self.dual[root].append((u, s))

    def finish(self) -> HLIndex:
        h, rank = self.h, self.rank
        le, lr, ls = [], [], []
        for u in range(h.n):
            if self.labels[u]:
                e = np.array([t[0] for t in self.labels[u]], np.int64)
                s = np.array([t[1] for t in self.labels[u]], np.int64)
            else:
                e = np.empty(0, np.int64)
                s = np.empty(0, np.int64)
            r = rank[e] if e.size else np.empty(0, np.int64)
            # construction visits roots in ascending rank, so r is sorted
            le.append(e)
            lr.append(r)
            ls.append(s)
        du, ds = [], []
        for e in range(h.m):
            if self.dual[e]:
                du.append(np.array([t[0] for t in self.dual[e]], np.int64))
                ds.append(np.array([t[1] for t in self.dual[e]], np.int64))
            else:
                du.append(np.empty(0, np.int64))
                ds.append(np.empty(0, np.int64))
        return HLIndex(h=h, rank=rank, perm=self.perm, labels_edge=le,
                       labels_rank=lr, labels_s=ls, dual_u=du, dual_s=ds,
                       stats=self.stats)


# ---------------------------------------------------------------------------
# Algorithm 2 — basic construction (online transitive-cover detection)
# ---------------------------------------------------------------------------

def _covered_by_higher(h: Hypergraph, b: _Builder, root: int, e_u: int,
                       s: int) -> bool:
    """Line 8 of Alg. 2: ∃ e_w with O(e_w) < O(root), e_w ~s~> root and
    e_w ~s~> e_u.  Both conditions hold iff the ≥s-threshold component of
    ``e_u`` (which contains ``root`` — the current walk has WOD = s)
    contains any hyperedge of higher importance.  BFS with early exit.
    """
    b.stats["cover_checks"] += 1
    root_rank = b.rank[root]
    seen = {e_u}
    stack = [e_u]
    while stack:
        e = stack.pop()
        if b.rank[e] < root_rank:
            return True
        nb, od = h.neighbors_od(e)
        for e2, w in zip(nb, od):
            e2 = int(e2)
            if int(w) >= s and e2 not in seen:
                seen.add(e2)
                stack.append(e2)
    return False


def build_basic(h: Hypergraph, cover_check: bool = True) -> HLIndex:
    """Algorithm 2.  ``cover_check=False`` degenerates to plain pruned
    labeling (needed by ablation benchmarks)."""
    b = _Builder(h)
    rank, sizes = b.rank, b.sizes
    for root in [int(x) for x in b.perm]:
        q: List[Tuple[int, int]] = [(-int(sizes[root]), root)]
        while q:
            neg_s, e_u = heapq.heappop(q)
            s = -neg_s
            if b.visited_e[e_u] == root:
                continue
            b.visited_e[e_u] = root
            b.stats["pops"] += 1
            if cover_check and _covered_by_higher(h, b, root, e_u, s):
                continue
            b.add_labels(root, e_u, s)
            nb, od = h.neighbors_od(e_u)
            for e_v, w in zip(nb, od):
                e_v, w = int(e_v), int(w)
                if rank[e_v] <= rank[root]:          # line 14 (Lemma 3)
                    continue
                if b.visited_e[e_v] == root:         # line 15
                    continue
                heapq.heappush(q, (-min(s, w), e_v))
                b.stats["pushes"] += 1
    return b.finish()


# ---------------------------------------------------------------------------
# Algorithm 3 — fast construction (MCD + neighbor-index M)
# ---------------------------------------------------------------------------

def build_fast(h: Hypergraph) -> HLIndex:
    b = _Builder(h)
    rank, sizes = b.rank, b.sizes
    mcd = np.zeros(h.m, np.int64)
    M: List[Optional[Dict[int, int]]] = [None] * h.m
    m_entries = 0

    for root in [int(x) for x in b.perm]:
        if mcd[root] == sizes[root]:                 # line 4
            continue
        mcd_root = int(mcd[root])                    # Lemma 5: lower bound is exact now
        q: List[Tuple[int, int]] = [(-int(sizes[root]), root)]
        while q:
            neg_s, e_u = heapq.heappop(q)
            s = -neg_s
            if b.visited_e[e_u] == root:
                continue
            b.visited_e[e_u] = root                  # line 8
            b.stats["pops"] += 1
            if e_u != root and s > mcd[e_u]:
                mcd[e_u] = s                         # line 9
            b.add_labels(root, e_u, s)               # lines 10-13
            if M[e_u] is None:                       # lines 14-18
                b.stats["neighbor_inits"] += 1
                entries: Dict[int, int] = {}
                nb, od = h.neighbors_od(e_u)
                for e_v, w in zip(nb, od):
                    e_v = int(e_v)
                    if rank[e_v] <= rank[root]:      # line 17 (Lemma 3)
                        continue
                    entries[e_v] = int(w)
                M[e_u] = entries
                m_entries += len(entries)
                b.stats["m_total_inserts"] += len(entries)
                b.stats["m_peak_entries"] = max(b.stats["m_peak_entries"], m_entries)
            evict: List[int] = []
            for e_v, w in M[e_u].items():            # lines 19-24
                if (w > mcd_root and b.visited_e[e_v] != root
                        and rank[e_v] > rank[root]):  # line 20 (+ explicit rank guard)
                    heapq.heappush(q, (-min(s, w), e_v))
                    b.stats["pushes"] += 1
                if w <= s:                           # lines 22-24 (Lemma 6)
                    evict.append(e_v)
            for e_v in evict:
                del M[e_u][e_v]
                m_entries -= 1
                other = M[e_v]
                if other is not None and e_u in other:
                    del other[e_u]
                    m_entries -= 1
    b.stats["m_final_entries"] = m_entries
    return b.finish()
