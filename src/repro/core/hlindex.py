"""HL-index construction — Algorithms 2 (basic) and 3 (fast).

The HL-index is a vertex-to-hyperedge (VTE) labeling: ``L(u) = {(e, s)}``
meaning ``u ~s~> e``.  Construction processes hyperedges in descending
importance (w(e) = Σ_{v∈e}|E(v)|², ties by smaller id) and runs a pruned
bottleneck-Dijkstra from each root hyperedge.

Algorithm 3's two optimizations, implemented faithfully:

* **MCD** (maximum cover degree, Def. 8 / Lemmas 4-5): the transitive-cover
  check collapses to comparing the candidate step overlap with ``MCD(root)``
  — a scalar maintained for free as walks visit hyperedges.
* **neighbor-index M** (Lemma 6): ``N(e)`` is computed exactly once, stored
  sparsely, and entries proven redundant (``OD(e_u,e_v) ≤ WOD(walk to
  e_u)``) are evicted eagerly, keeping the peak size far below the full
  adjacency.

Implementation notes vs the pseudocode (documented deviations):
  * line 9 (``MCD(e_u) ← max(s, MCD(e_u))``) is skipped for the root pop —
    otherwise ``MCD(e) = |e|`` would prune the root's own traversal; the
    paper's text ("MCD(e) equals its lower bound when construction from e
    starts") implies the root's MCD is read once, before the loop.
  * pushes re-check ``O(e_v) > O(root)`` explicitly: ``M(e_u)`` may have
    been initialized under an earlier root with higher importance, so the
    line-17 exclusion alone does not cover the current root (Lemma 3 is
    the justification either way).
  * a stale-pop guard skips queue duplicates (first pop carries max s).
"""
from __future__ import annotations

import dataclasses
import heapq
import multiprocessing
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .hypergraph import Hypergraph, NeighborCSR, induced_subhypergraph, \
    neighbor_csr

__all__ = ["HLIndex", "build_basic", "build_fast", "build_sharded",
           "pad_label_rows", "splice_rank", "CONSTRUCTION_MODES"]

# Safety valve for the fork-based shard pool: the window is *per shard
# result* (it restarts every time any shard completes), so a healthy
# long build keeps extending it and only a pool making no progress at
# all — e.g. a lock inherited across fork — is presumed wedged,
# terminated, and rerun inline (recorded as ``stats["pool_fallback"]``).
_WORKER_TIMEOUT_S = 300.0

# Offload the neighbor-overlap precompute to the device mesh only once
# the host's vectorized pair pass would materialize more than this many
# ordered co-incidence pairs (Σ_u d_u²) — below it, one numpy pass beats
# the device round-trip even on real accelerators.
_DEVICE_OVERLAP_PAIRS = 5e7
# ... and only while the dense [m, m] overlap matrix the device route
# materializes (f32 on device + int64 host copy, ~12 bytes/entry) stays
# affordable — past this, the sparse host pass is the only sane route
# regardless of how many pairs it walks.
_DEVICE_OVERLAP_DENSE_BUDGET = 4 * 2**30


def auto_device_overlaps(h: Hypergraph) -> bool:
    """Whether the neighbor-overlap precompute for ``h`` should run on a
    device mesh: the host pair pass would walk more than
    ``_DEVICE_OVERLAP_PAIRS`` ordered co-incidence pairs *and* the dense
    [m, m] footprint of the device route stays affordable.  Shared by
    ``build_sharded`` and the sharded engine's build-time
    ``NeighborCSR`` precompute so both pick the same route."""
    deg = h.vertex_degrees
    return bool(float((deg * deg).sum()) > _DEVICE_OVERLAP_PAIRS
                and 12.0 * h.m * h.m <= _DEVICE_OVERLAP_DENSE_BUDGET)

# When a multi-device mesh defaults the worker count (the engine's
# construction="auto" path), the fork pool only engages once the shared
# neighbor index carries at least this many entries — below it the
# per-shard traversals finish faster than the pool's fixed start +
# pickle cost.  An explicit ``workers=`` is always honored as given.
_POOL_MIN_NEIGHBOR_ENTRIES = 1_000_000


def splice_rank(old_rank: np.ndarray, old_to_new: np.ndarray,
                sub_edges: np.ndarray, sub_rank: np.ndarray,
                m_new: int) -> np.ndarray:
    """Compose a global importance rank for a graph after scoped
    maintenance: surviving hyperedges outside the rebuilt scope keep
    their old rank *values* unchanged, hyperedges inside the scope get
    fresh keys above every old value, ordered by sub-index importance.

    Keeping old values (rather than recompacting to ``0..m_new-1``)
    means the untouched vertices' ``labels_rank`` arrays stay valid
    byte-for-byte and are reused by the splice without a regather — rank
    is an opaque sort key everywhere it is consumed (merge-joins, padded
    snapshots, ``perm = argsort(rank)``), never a dense array index, so
    gaps are harmless.  Keys stay far below the int32 padding sentinel:
    each update raises the maximum by at most the scope size, and
    ``apply_updates`` falls back to a dense rebuild before ``2^30``.

    ``sub_edges`` [m_sub] maps local sub-index hyperedge ids to new
    global ids; ``sub_rank`` [m_sub] is the sub-index's own rank array.
    Requires the scope to be a union of whole line-graph components —
    then no label list ever mixes hubs from the two groups, so how the
    groups interleave cannot affect any merge-join (rank is only ever
    compared between hubs reachable from a common vertex), and any total
    order per group yields a correct index (order only affects
    minimality).
    """
    new_rank = np.full(m_new, -1, np.int64)
    old_ids = np.nonzero(old_to_new >= 0)[0]
    new_rank[old_to_new[old_ids]] = old_rank[old_ids]
    base = int(old_rank.max()) + 1 if old_rank.size else 0
    new_rank[sub_edges] = base + sub_rank
    if (new_rank < 0).any():
        raise ValueError("splice_rank: some hyperedge is neither a "
                         "surviving edge nor in the scope")
    return new_rank


def pad_label_rows(row_ranks, row_svals, pad_to=None):
    """Pack ragged per-vertex (rank, s) label rows into the padded dense
    form consumed by the batched query engine: one concatenate + fancy-
    index scatter, no per-row Python copies.

    Returns (ranks [n, Lmax] int32 ascending with INT32_MAX padding,
    svals [n, Lmax] int32 with 0 padding, lengths [n] int32).
    """
    n = len(row_ranks)
    lengths = np.array([a.size for a in row_svals], np.int32)
    lmax = int(pad_to if pad_to is not None else (lengths.max() if n else 0))
    ranks = np.full((n, lmax), np.iinfo(np.int32).max, np.int32)
    svals = np.zeros((n, lmax), np.int32)
    total = int(lengths.sum())
    if total and lmax:
        rows = np.repeat(np.arange(n), lengths)
        starts = np.cumsum(lengths, dtype=np.int64) - lengths
        cols = np.arange(total, dtype=np.int64) - np.repeat(starts, lengths)
        ranks[rows, cols] = np.concatenate(row_ranks)
        svals[rows, cols] = np.concatenate(row_svals)
    return ranks, svals, lengths


@dataclasses.dataclass
class HLIndex:
    """Per-vertex labels, sorted by hyperedge importance rank (ascending)."""

    h: Hypergraph
    rank: np.ndarray                  # [m] importance rank of each hyperedge
    perm: np.ndarray                  # [m] perm[rank] = hyperedge id
    labels_edge: List[np.ndarray]     # per vertex: hyperedge ids
    labels_rank: List[np.ndarray]     # per vertex: ranks (ascending — merge key)
    labels_s: List[np.ndarray]        # per vertex: s values
    dual_u: List[np.ndarray]          # per hyperedge: vertices (D(e))
    dual_s: List[np.ndarray]          # per hyperedge: s values (non-ascending)
    stats: Dict[str, float]

    @property
    def num_labels(self) -> int:
        return int(sum(a.size for a in self.labels_s))

    def label_dict(self, u: int) -> Dict[int, int]:
        return {int(e): int(s) for e, s in
                zip(self.labels_edge[u], self.labels_s[u])}

    def nbytes(self) -> int:
        """Index size: one (hyperedge id, s) pair per label, 4+4 bytes."""
        return self.num_labels * 8

    def as_padded(self, pad_to: Optional[int] = None):
        """Dense padded export for the JAX batched query engine.

        Returns (ranks [n, Lmax] int32 ascending with INT32_MAX padding,
        svals [n, Lmax] int32 with 0 padding, lengths [n]).
        """
        return pad_label_rows(self.labels_rank, self.labels_s, pad_to)


class _Builder:
    """Shared state for Algorithms 2/3."""

    def __init__(self, h: Hypergraph):
        self.h = h
        self.rank = h.importance_order()
        self.perm = np.argsort(self.rank)
        self.sizes = h.edge_sizes
        self.labels: List[List[Tuple[int, int]]] = [[] for _ in range(h.n)]
        self.dual: List[List[Tuple[int, int]]] = [[] for _ in range(h.m)]
        self.visited_v = np.full(h.n, -1, np.int64)
        self.visited_e = np.full(h.m, -1, np.int64)
        self.stats: Dict[str, float] = dict(pops=0, pushes=0, neighbor_inits=0,
                                            m_peak_entries=0, m_total_inserts=0,
                                            cover_checks=0)

    def add_labels(self, root: int, e_u: int, s: int) -> None:
        for u in self.h.edge(e_u):
            u = int(u)
            if self.visited_v[u] == root:
                continue
            self.visited_v[u] = root
            self.labels[u].append((root, s))
            self.dual[root].append((u, s))

    def finish(self) -> HLIndex:
        h, rank = self.h, self.rank
        le, lr, ls = [], [], []
        for u in range(h.n):
            if self.labels[u]:
                e = np.array([t[0] for t in self.labels[u]], np.int64)
                s = np.array([t[1] for t in self.labels[u]], np.int64)
            else:
                e = np.empty(0, np.int64)
                s = np.empty(0, np.int64)
            r = rank[e] if e.size else np.empty(0, np.int64)
            # construction visits roots in ascending rank, so r is sorted
            le.append(e)
            lr.append(r)
            ls.append(s)
        du, ds = [], []
        for e in range(h.m):
            if self.dual[e]:
                du.append(np.array([t[0] for t in self.dual[e]], np.int64))
                ds.append(np.array([t[1] for t in self.dual[e]], np.int64))
            else:
                du.append(np.empty(0, np.int64))
                ds.append(np.empty(0, np.int64))
        return HLIndex(h=h, rank=rank, perm=self.perm, labels_edge=le,
                       labels_rank=lr, labels_s=ls, dual_u=du, dual_s=ds,
                       stats=self.stats)


# ---------------------------------------------------------------------------
# Algorithm 2 — basic construction (online transitive-cover detection)
# ---------------------------------------------------------------------------

def _covered_by_higher(h: Hypergraph, b: _Builder, root: int, e_u: int,
                       s: int, neighbors: Optional[NeighborCSR]) -> bool:
    """Line 8 of Alg. 2: ∃ e_w with O(e_w) < O(root), e_w ~s~> root and
    e_w ~s~> e_u.  Both conditions hold iff the ≥s-threshold component of
    ``e_u`` (which contains ``root`` — the current walk has WOD = s)
    contains any hyperedge of higher importance.  BFS with early exit.
    """
    b.stats["cover_checks"] += 1
    root_rank = b.rank[root]
    seen = {e_u}
    stack = [e_u]
    while stack:
        e = stack.pop()
        if b.rank[e] < root_rank:
            return True
        nb, od = (neighbors.row(e) if neighbors is not None
                  else h.neighbors_od(e))
        for e2, w in zip(nb, od):
            e2 = int(e2)
            if int(w) >= s and e2 not in seen:
                seen.add(e2)
                stack.append(e2)
    return False


def build_basic(h: Hypergraph, cover_check: bool = True, *,
                neighbors: Optional[NeighborCSR] = None) -> HLIndex:
    """Algorithm 2.  ``cover_check=False`` degenerates to plain pruned
    labeling (needed by ablation benchmarks).  ``neighbors`` is an
    optional precomputed ``NeighborCSR`` — same traversal, no per-edge
    neighborhood recomputation (output is identical either way)."""
    b = _Builder(h)
    rank, sizes = b.rank, b.sizes
    for root in [int(x) for x in b.perm]:
        q: List[Tuple[int, int]] = [(-int(sizes[root]), root)]
        while q:
            neg_s, e_u = heapq.heappop(q)
            s = -neg_s
            if b.visited_e[e_u] == root:
                continue
            b.visited_e[e_u] = root
            b.stats["pops"] += 1
            if cover_check and _covered_by_higher(h, b, root, e_u, s,
                                                  neighbors):
                continue
            b.add_labels(root, e_u, s)
            nb, od = (neighbors.row(e_u) if neighbors is not None
                      else h.neighbors_od(e_u))
            for e_v, w in zip(nb, od):
                e_v, w = int(e_v), int(w)
                if rank[e_v] <= rank[root]:          # line 14 (Lemma 3)
                    continue
                if b.visited_e[e_v] == root:         # line 15
                    continue
                heapq.heappush(q, (-min(s, w), e_v))
                b.stats["pushes"] += 1
    return b.finish()


# ---------------------------------------------------------------------------
# Algorithm 3 — fast construction (MCD + neighbor-index M)
# ---------------------------------------------------------------------------

def build_fast(h: Hypergraph, *,
               neighbors: Optional[NeighborCSR] = None) -> HLIndex:
    """Algorithm 3.  ``neighbors`` is an optional precomputed
    ``NeighborCSR`` used for the one-shot M initialization (Lemma 6)
    instead of computing ``N(e)`` on the fly — the output is identical
    either way (the CSR rows are byte-equal to ``neighbors_od``)."""
    b = _Builder(h)
    rank, sizes = b.rank, b.sizes
    mcd = np.zeros(h.m, np.int64)
    M: List[Optional[Dict[int, int]]] = [None] * h.m
    m_entries = 0

    for root in [int(x) for x in b.perm]:
        if mcd[root] == sizes[root]:                 # line 4
            continue
        mcd_root = int(mcd[root])                    # Lemma 5: lower bound is exact now
        q: List[Tuple[int, int]] = [(-int(sizes[root]), root)]
        while q:
            neg_s, e_u = heapq.heappop(q)
            s = -neg_s
            if b.visited_e[e_u] == root:
                continue
            b.visited_e[e_u] = root                  # line 8
            b.stats["pops"] += 1
            if e_u != root and s > mcd[e_u]:
                mcd[e_u] = s                         # line 9
            b.add_labels(root, e_u, s)               # lines 10-13
            if M[e_u] is None:                       # lines 14-18
                b.stats["neighbor_inits"] += 1
                entries: Dict[int, int] = {}
                nb, od = (neighbors.row(e_u) if neighbors is not None
                          else h.neighbors_od(e_u))
                for e_v, w in zip(nb, od):
                    e_v = int(e_v)
                    if rank[e_v] <= rank[root]:      # line 17 (Lemma 3)
                        continue
                    entries[e_v] = int(w)
                M[e_u] = entries
                m_entries += len(entries)
                b.stats["m_total_inserts"] += len(entries)
                b.stats["m_peak_entries"] = max(b.stats["m_peak_entries"], m_entries)
            evict: List[int] = []
            for e_v, w in M[e_u].items():            # lines 19-24
                if (w > mcd_root and b.visited_e[e_v] != root
                        and rank[e_v] > rank[root]):  # line 20 (+ explicit rank guard)
                    heapq.heappush(q, (-min(s, w), e_v))
                    b.stats["pushes"] += 1
                if w <= s:                           # lines 22-24 (Lemma 6)
                    evict.append(e_v)
            for e_v in evict:
                del M[e_u][e_v]
                m_entries -= 1
                other = M[e_v]
                if other is not None and e_u in other:
                    del other[e_u]
                    m_entries -= 1
    b.stats["m_final_entries"] = m_entries
    return b.finish()


# ---------------------------------------------------------------------------
# Sharded construction — the multi-device build path
# ---------------------------------------------------------------------------

def _assign_shards(comp: np.ndarray, cost: np.ndarray,
                   num_shards: int) -> List[np.ndarray]:
    """Partition line-graph components into ``num_shards`` work shards,
    balanced by estimated traversal cost (greedy longest-processing-time:
    heaviest component to the least-loaded shard; ties resolved by lower
    component label / lower shard index, so the partition is
    deterministic).  Returns sorted global hyperedge-id arrays, empty
    shards dropped."""
    n_comp = int(comp.max()) + 1 if comp.size else 0
    k = max(1, min(int(num_shards), n_comp))
    order = np.lexsort((np.arange(n_comp), -cost))   # heaviest first
    load = np.zeros(k, np.float64)
    shard_of = np.zeros(n_comp, np.int64)
    for c in order:
        s = int(np.argmin(load))                     # first minimum on ties
        shard_of[c] = s
        load[s] += cost[c]
    shards = [np.nonzero(shard_of[comp] == s)[0] for s in range(k)]
    return [s for s in shards if s.size]


def _shard_worker(payload) -> HLIndex:
    """Build (and optionally minimize) one shard's sub-index.  Module
    level so the fork-based shard pool can pickle it; workers touch only
    numpy — never jax."""
    sub_h, sub_nbr, base, minimizer = payload
    idx = base(sub_h, neighbors=sub_nbr)
    if minimizer is not None:
        idx = minimizer(idx)
    return idx


def _shard_worker_indexed(indexed_payload):
    i, payload = indexed_payload
    return i, _shard_worker(payload)


def _run_shard_pool(payloads, workers: int) -> Optional[List[HLIndex]]:
    """Run shard builds in forked worker processes; ``None`` means the
    pool was unavailable, wedged, or errored and the caller should run
    inline.  Workers execute pure numpy code, so the usual fork-after-jax
    hazard (a child touching locks inherited mid-flight) does not apply —
    but a *progress* timeout still guards the pathological case: the
    window restarts on every completed shard, so a long healthy build
    keeps extending it and only a pool producing nothing at all is
    declared wedged.  On any failure the children are *terminated* (not
    abandoned) so the inline rerun never races live duplicates for CPU
    and memory."""
    try:
        ctx = multiprocessing.get_context("fork")
    except ValueError:                               # platform without fork
        return None
    try:
        # suppress only the fork-time jax RuntimeWarning (the children
        # never run jax, which is the case the warning is not about);
        # the block is kept to the Pool() call alone so warnings from
        # other threads during the (possibly long) result wait pass
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            pool = ctx.Pool(processes=min(int(workers), len(payloads)))
    except OSError:
        return None
    try:
        out: List[Optional[HLIndex]] = [None] * len(payloads)
        it = pool.imap_unordered(_shard_worker_indexed,
                                 list(enumerate(payloads)))
        for _ in range(len(payloads)):
            i, idx = it.next(timeout=_WORKER_TIMEOUT_S)
            out[i] = idx
    except Exception:
        # no progress inside the window, a worker error, or a broken
        # pool: kill the children and let the caller rerun inline (a
        # genuine shard bug reproduces there with a clean traceback)
        pool.terminate()
        pool.join()
        return None
    pool.close()
    pool.join()
    return out


def build_sharded(h: Hypergraph, *,
                  base: Callable[..., HLIndex] = build_fast,
                  minimizer: Optional[Callable[[HLIndex], HLIndex]] = None,
                  num_shards: Optional[int] = None,
                  workers: Optional[int] = None,
                  mesh=None,
                  device_overlaps: Optional[bool] = None,
                  neighbors: Optional[NeighborCSR] = None) -> HLIndex:
    """Parallel sharded HL-index construction — byte-identical output to
    ``base(h)`` (and, with ``minimizer``, to ``minimizer(base(h))``).

    The rank-ordered root sequence is partitioned into per-device work
    shards at **line-graph component boundaries** — the finest grain at
    which the construction state (the MCD array and the neighbor index M
    of Algorithm 3, Lemmas 4-6) provably never crosses a cut: a cover
    relation rides an s-overlap walk, which is a line-graph path, so no
    cover check, MCD update, or M entry can involve two components.
    Each shard therefore replays exactly the serial traversal restricted
    to its components, in the same relative root order:

    1. The shared neighbor index is precomputed once as a ``NeighborCSR``
       (on the device mesh when ``mesh`` has more than one device — see
       ``neighbor_csr``) instead of once per hyperedge on the fly.
    2. Components are balanced into shards (greedy LPT on estimated
       traversal cost) and each shard runs ``base`` (+ ``minimizer``) on
       its induced sub-hypergraph, optionally in ``workers`` forked
       processes.  Per-shard minimization is exact too: Algorithm 4's
       dual sets are hub-confined, hence component-confined.
    3. The merge is a deterministic cover-check reconciliation pass: it
       verifies each shard's scope is neighbor-closed
       (``NeighborCSR.induced`` — the condition under which per-shard
       MCD cover state equals the serial builder's) and that each
       shard's local importance order mirrors the global order restricted
       to it, then splices labels/duals back into global id and rank
       space.  Any violation raises instead of silently merging.

    Why byte-identical: a vertex's incident hyperedges all share that
    vertex pairwise, so they are line-graph adjacent and live in one
    component — every label row is produced whole by exactly one shard,
    in the serial root order.  ``induced_subhypergraph`` on whole
    components preserves vertex degrees, hence importance weights, and
    its sorted-id mapping preserves the tie-break, so per-shard
    traversals pop and push in exactly the serial order.

    Stats: the traversal counters (``pops``, ``pushes``,
    ``neighbor_inits``, ``m_total_inserts``, ``cover_checks``,
    ``m_final_entries``) sum to exactly the serial builder's values;
    ``m_peak_entries`` is the max over shards (≤ the serial peak, which
    interleaves components).  Extra keys: ``shards``, ``components``,
    ``construction``.

    ``num_shards`` defaults to ``workers``, else the mesh device count,
    else 1; shard counts that exceed the component count are clamped.
    ``workers=None`` with a multi-device ``mesh`` defaults to
    ``min(devices, cpu_count)`` forked workers, engaged only once the
    neighbor index is heavy enough to amortize the pool's fixed cost
    (``_POOL_MIN_NEIGHBOR_ENTRIES``); an explicit ``workers`` is always
    honored as given, and ``workers`` ≤ 1 runs shards inline
    (byte-identical either way).  ``device_overlaps`` controls where the
    neighbor precompute runs: ``None`` offloads to the mesh only when
    the host pair pass would materialize > ``_DEVICE_OVERLAP_PAIRS``
    ordered pairs *and* the dense [m, m] footprint stays affordable;
    ``True`` forces the mesh route (requires a multi-device ``mesh`` —
    raises otherwise), ``False`` forces the host pass.
    """
    devices = int(mesh.devices.size) if mesh is not None else 1
    if device_overlaps and devices <= 1:
        raise ValueError(
            "device_overlaps=True needs a multi-device mesh to offload "
            f"to; got {'no mesh' if mesh is None else f'{devices} device'}")
    auto_workers = workers is None
    if auto_workers and devices > 1:
        workers = min(devices, multiprocessing.cpu_count())
    if num_shards is None:
        num_shards = max(workers or 0, devices, 1)
    if h.m == 0:
        idx = base(h)
        if minimizer is not None:
            idx = minimizer(idx)
        idx.stats.update(shards=0, components=0, construction="sharded",
                         pool_fallback=0.0)
        return idx
    neighbor_reused = neighbors is not None
    if neighbors is not None:
        nbr = neighbors
    else:
        if device_overlaps is None:
            device_overlaps = auto_device_overlaps(h)
        nbr = neighbor_csr(h, mesh=mesh if device_overlaps else None)
    if auto_workers and nbr.idx.size < _POOL_MIN_NEIGHBOR_ENTRIES:
        workers = None          # defaulted pool would not amortize
    comp = nbr.components()
    row_len = np.diff(nbr.ptr).astype(np.float64)
    cost = np.bincount(comp, weights=row_len + 1.0,
                       minlength=int(comp.max()) + 1)
    shards = _assign_shards(comp, cost, num_shards)

    rank = h.importance_order()
    perm = np.argsort(rank)
    payloads, metas = [], []
    for ids in shards:
        sub_h, verts = induced_subhypergraph(h, ids)
        sub_nbr = nbr.induced(ids)      # raises unless neighbor-closed
        payloads.append((sub_h, sub_nbr, base, minimizer))
        metas.append((ids, verts))

    sub_idxs = None
    pool_fallback = False
    if workers and int(workers) > 1 and len(payloads) > 1:
        sub_idxs = _run_shard_pool(payloads, int(workers))
        pool_fallback = sub_idxs is None
        if pool_fallback:
            warnings.warn(
                "build_sharded: the shard worker pool made no progress "
                "(or errored) and was terminated; rerunning shards "
                "inline", RuntimeWarning, stacklevel=2)
    if sub_idxs is None:
        sub_idxs = [_shard_worker(p) for p in payloads]

    empty = np.empty(0, np.int64)
    le: List[np.ndarray] = [empty] * h.n
    lr: List[np.ndarray] = [empty] * h.n
    ls: List[np.ndarray] = [empty] * h.n
    du: List[np.ndarray] = [empty] * h.m
    ds: List[np.ndarray] = [empty] * h.m
    counters = ("pops", "pushes", "neighbor_inits", "m_total_inserts",
                "cover_checks", "m_final_entries")
    stats: Dict[str, float] = {k: 0.0 for k in counters}
    stats["m_peak_entries"] = 0.0
    for (ids, verts), sub in zip(metas, sub_idxs):
        # reconciliation: the shard's local rank order must mirror the
        # global order restricted to it (degrees — hence importance —
        # are preserved on whole components; anything else is a bug)
        if not np.array_equal(ids[sub.perm], ids[np.argsort(rank[ids])]):
            raise RuntimeError(
                "sharded construction: a shard's local importance order "
                "diverged from the global order — scope is not a union "
                "of whole line-graph components")
        for lu in range(sub.h.n):
            gu = int(verts[lu])
            e = ids[sub.labels_edge[lu]]
            le[gu] = e
            lr[gu] = rank[e]
            ls[gu] = sub.labels_s[lu]
        for lei in range(sub.h.m):
            ge = int(ids[lei])
            du[ge] = verts[sub.dual_u[lei]]
            ds[ge] = sub.dual_s[lei]
        for key in counters:
            stats[key] += float(sub.stats.get(key, 0))
        stats["m_peak_entries"] = max(stats["m_peak_entries"],
                                      float(sub.stats.get("m_peak_entries",
                                                          0)))
    stats.update(shards=len(shards), components=int(comp.max()) + 1,
                 construction="sharded", pool_fallback=float(pool_fallback),
                 neighbor_reused=float(neighbor_reused))
    return HLIndex(h=h, rank=rank, perm=perm, labels_edge=le,
                   labels_rank=lr, labels_s=ls, dual_u=du, dual_s=ds,
                   stats=stats)


# Construction-mode registry: the builder options `HLIndexEngine.build`
# (repro.core.engine) accepts for its `construction=` opt.  The table in
# docs/ARCHITECTURE.md is CI-checked against this (tools/check_docs.py
# check 5) — documenting a mode that does not exist, or adding one
# without documenting it, fails the build.
CONSTRUCTION_MODES: Dict[str, Callable[..., HLIndex]] = {
    "serial": build_fast,        # Algorithm 3, one host thread
    "sharded": build_sharded,    # component-sharded parallel construction
}
