"""Core library: the paper's contribution (HL-index max-reachability in
hypergraphs) plus its (max, min)-semiring TPU re-expression.

Naming note: ``batched_mr`` is the HL-index label-join engine
(query.py).  The sparse frontier-sweep engine exports
``frontier_batched_mr`` / ``frontier_batched_s_reach`` (frontier.py) —
historically the frontier one shadowed the label-join one under the
same name; the deprecated compatibility aliases are gone.  New code
should go through the unified facade in ``repro.api`` /
``repro.core.engine`` instead of either raw function.
"""
from .hypergraph import (Hypergraph, NeighborCSR, neighbor_csr,
                         from_edge_lists, compact,
                         induced_subhypergraph, apply_edge_edits,
                         random_hypergraph, planted_chain_hypergraph,
                         colocation_hypergraph, paper_figure1)
from .online import mr_online, precompute_neighbors, NeighborCache
from .hlindex import (HLIndex, build_basic, build_fast, build_sharded,
                      CONSTRUCTION_MODES)
from .minimal import minimize, exact_minimize
from .query import (mr_query, s_reach_query, mr_query_dicts, PaddedIndex,
                    batched_mr)
from .semiring import (maxmin_matmul, maxmin_closure, boolean_closure,
                       threshold_closure_mr, mr_matrix, mr_oracle_dense,
                       vertex_mr_from_edge_mr, distinct_thresholds)
from .baselines import (vtv_query, ETEIndex, build_ete,
                        ThresholdComponentIndex, MSTOracle, line_graph_edges,
                        brute_force_s_distance, brute_force_s_reach_k,
                        brute_force_witness, brute_force_mr_set,
                        brute_force_mr_from_set, brute_force_top_s)
from .maintenance import (insert_hyperedge, delete_hyperedge, apply_updates,
                          component_of)
from .frontier import (SparseLineGraph, frontier_batched_s_reach,
                       frontier_batched_mr)
from .engine import (ReachabilityEngine, DeviceSnapshot, SnapshotUnsupported,
                     UpdateUnsupported, WorkloadUnsupported, WORKLOAD_OPS,
                     register_backend, available_backends,
                     update_capabilities, workload_capabilities, plan_backend)
from .engine import build as build_engine

__all__ = [
    "Hypergraph", "NeighborCSR", "neighbor_csr",
    "from_edge_lists", "compact", "induced_subhypergraph",
    "apply_edge_edits", "random_hypergraph",
    "planted_chain_hypergraph", "colocation_hypergraph", "paper_figure1",
    "mr_online", "precompute_neighbors", "NeighborCache",
    "HLIndex", "build_basic", "build_fast", "build_sharded",
    "CONSTRUCTION_MODES", "minimize", "exact_minimize",
    "mr_query", "s_reach_query", "mr_query_dicts", "PaddedIndex", "batched_mr",
    "maxmin_matmul", "maxmin_closure", "boolean_closure",
    "threshold_closure_mr", "mr_matrix", "mr_oracle_dense",
    "vertex_mr_from_edge_mr", "distinct_thresholds",
    "vtv_query", "ETEIndex", "build_ete", "ThresholdComponentIndex",
    "MSTOracle", "line_graph_edges",
    "brute_force_s_distance", "brute_force_s_reach_k",
    "brute_force_witness", "brute_force_mr_set",
    "brute_force_mr_from_set", "brute_force_top_s",
    "insert_hyperedge", "delete_hyperedge", "apply_updates", "component_of",
    "SparseLineGraph", "frontier_batched_s_reach", "frontier_batched_mr",
    "ReachabilityEngine", "DeviceSnapshot", "SnapshotUnsupported",
    "UpdateUnsupported", "WorkloadUnsupported", "WORKLOAD_OPS",
    "register_backend", "available_backends",
    "update_capabilities", "workload_capabilities", "plan_backend",
    "build_engine",
]


