"""Distributed reachability: 2-D block-sharded semiring closures and the
``sharded`` engine backend that serves queries off them.

For hypergraphs whose line graph does not fit one device, the closure
operand R [m, m] is block-sharded over the production mesh axes
``(data, model)`` and each squaring round runs a SUMMA-style contraction
under ``jax.shard_map`` with explicit collectives:

* ``allgather`` schedule — device (i, j) gathers its row panel R[i, :]
  along ``model`` and its column panel R[:, j] along ``data``, then
  contracts locally.  Two all-gathers of m²/P elements per device per
  round; simple, and XLA can overlap the two gathers.
* ``ring`` schedule — the column panel circulates via
  ``jax.lax.ppermute`` while partial contractions accumulate, so each
  step's collective-permute overlaps the previous step's compute
  (the classic Cannon/SUMMA overlap trick).  Same total bytes, but peak
  working set drops from m·m/P_col to m/P_row·m/P_col per step and the
  link traffic is pipelined — this is the collective-bound optimization
  knob for §Perf.

The threshold-batched boolean closure shards its threshold dim over the
``pod`` axis (embarrassingly parallel — zero inter-pod traffic until the
final max-reduce), giving the multi-pod scaling story.

Meshes with unit axes degrade gracefully (the collectives become no-ops),
so the same code runs tests on 1-4 host devices and the 512-way dry-run.

``ShardedEngine`` (registered as backend ``"sharded"`` — see
``repro.core.engine``) wraps these closures in the ``ReachabilityEngine``
protocol: the closure is computed **once** at build time and kept
device-resident in its block-sharded layout; every query — scalar or
batch — is served off that resident structure through a mesh-sharded
``DeviceSnapshot``, never by re-running the closure.  Updates are
**scoped** in both regimes (capability ``"scoped"``): an edge edit
re-closes only the touched line-graph component block and patches the
resident W* / snapshot in place (closure regime), or routes the touched
components through ``build_sharded`` and splices (label regime) — the
full fixpoint and the full pair pass never rerun after build.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import make_mesh, shard_map
from .engine import (WORKLOAD_OPS, _EngineBase, register_backend,
                     validate_batch)
from .hlindex import (HLIndex, auto_device_overlaps, build_sharded,
                      pad_label_rows)
from .hypergraph import (NeighborCSR, apply_edge_edits,
                         induced_subhypergraph, neighbor_csr)
from .maintenance import apply_updates, component_of
from .minimal import minimize
from .query import DeviceSnapshot, mr_query, s_reach_query

__all__ = [
    "pad_for_mesh", "sharded_maxmin_round", "sharded_maxmin_closure",
    "sharded_threshold_closure_mr", "collective_bytes_of",
    "default_line_graph_mesh", "ShardedEngine",
]


def pad_for_mesh(w: np.ndarray, mesh: Mesh,
                 axes: Tuple[str, str] = ("data", "model")) -> np.ndarray:
    """Pad [m, m] (or [S, m, m]) so both block dims divide the mesh axes.
    Zero is the (max,min) annihilator and boolean-adjacency identity, so
    padding is exact for both closure flavors."""
    r, c = mesh.shape[axes[0]], mesh.shape[axes[1]]
    lcm = int(np.lcm(r, c))
    m = w.shape[-1]
    pad = (-m) % lcm
    if pad == 0:
        return w
    widths = [(0, 0)] * (w.ndim - 2) + [(0, pad), (0, pad)]
    return np.pad(w, widths)


def _local_maxmin(a: jax.Array, b: jax.Array, chunk: int = 128) -> jax.Array:
    """Blocked local (max,min) contraction (keeps the broadcast bounded)."""
    m, k = a.shape
    _, n = b.shape
    if k <= chunk:
        return jnp.minimum(a[:, :, None], b[None, :, :]).max(axis=1)
    pad = (-k) % chunk

    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, pad), (0, 0)))

    def body(carry, kk):
        a_blk = jax.lax.dynamic_slice(a, (0, kk), (m, chunk))
        b_blk = jax.lax.dynamic_slice(b, (kk, 0), (chunk, n))
        c = jnp.minimum(a_blk[:, :, None], b_blk[None, :, :]).max(axis=1)
        return jnp.maximum(carry, c), None

    # init derived from the operands (not a constant) so its device-varying
    # type matches the scan body's output under shard_map
    init = jnp.minimum(a[:, :1], b[:1, :]) * 0
    steps = (k + pad) // chunk
    out, _ = jax.lax.scan(body, init, jnp.arange(steps) * chunk)
    return out


def _local_contraction(use_kernels: bool):
    """The per-device (max,min) contraction inside a closure round:
    the scanned jnp broadcast (default), or the Pallas ``maxmin_matmul``
    kernel (compiled on TPU, interpret-mode elsewhere) when the engine
    was built with ``use_kernels=True``."""
    if not use_kernels:
        return _local_maxmin
    from ..kernels.maxmin_matmul import maxmin_matmul_pallas
    from ..kernels.ops import use_interpret
    interp = use_interpret()
    return functools.partial(maxmin_matmul_pallas, interpret=interp)


def sharded_maxmin_round(mesh: Mesh, *, schedule: str = "allgather",
                         axes: Tuple[str, str] = ("data", "model"),
                         use_kernels: bool = False):
    """Returns a jit-able fn R -> max(R, R∘R) for R sharded P(axes)."""
    row_ax, col_ax = axes
    n_row = mesh.shape[row_ax]
    n_col = mesh.shape[col_ax]
    spec = P(row_ax, col_ax)
    contract = _local_contraction(use_kernels)

    if schedule == "allgather":
        def round_fn(r):
            def body(blk):
                # blk: [m/nr, m/nc] local block at mesh position (i, j)
                row_panel = jax.lax.all_gather(blk, col_ax, axis=1, tiled=True)
                col_panel = jax.lax.all_gather(blk, row_ax, axis=0, tiled=True)
                return jnp.maximum(blk, contract(row_panel, col_panel))
            # pallas_call has no replication rule, so the kernel path
            # must skip the rep check (the body is rep-correct either way)
            return shard_map(body, mesh=mesh, in_specs=spec,
                                 out_specs=spec,
                                 check_vma=not use_kernels)(r)
        return round_fn

    if schedule == "ring":
        def round_fn(r):
            def body(blk):
                # Ring over the row axis: the column panel R[k, j] visits
                # every k; partials accumulate while the next panel is in
                # flight.  Row panel is gathered once along `model`.
                row_panel = jax.lax.all_gather(blk, col_ax, axis=1, tiled=True)
                my_row = jax.lax.axis_index(row_ax)
                perm = [(i, (i + 1) % n_row) for i in range(n_row)]
                block_rows = blk.shape[0]

                def step(carry, t):
                    acc, panel = carry
                    # panel currently holds R[(my_row - t) % n_row, j]
                    src = (my_row - t) % n_row
                    seg = jax.lax.dynamic_slice(
                        row_panel, (0, src * block_rows),
                        (block_rows, block_rows))
                    acc = jnp.maximum(acc, contract(seg, panel))
                    panel = jax.lax.ppermute(panel, row_ax, perm)
                    return (acc, panel), None

                (acc, _), _ = jax.lax.scan(step, (blk, blk),
                                           jnp.arange(n_row))
                return acc
            return shard_map(body, mesh=mesh, in_specs=spec,
                                 out_specs=spec,
                                 check_vma=not use_kernels)(r)
        return round_fn

    raise ValueError(schedule)


def sharded_maxmin_closure(w, mesh: Mesh, *, rounds: Optional[int] = None,
                           schedule: str = "allgather",
                           axes: Tuple[str, str] = ("data", "model"),
                           trim: bool = True, use_kernels: bool = False):
    """Bottleneck closure of a 2-D block-sharded line graph.

    ``w`` is the [m, m] line graph (host or device); the result is W*,
    the hyperedge-level max-reachability matrix.  With ``trim=True``
    (default) the mesh padding is sliced off and the result matches
    ``semiring.maxmin_closure`` exactly.  ``trim=False`` keeps the padded
    [mp, mp] array resident **in its block-sharded layout** — the form
    ``ShardedEngine`` serves queries from (padding entries are zero, the
    (max, min) annihilator, so they never contribute to an answer).
    """
    wp = pad_for_mesh(np.asarray(w), mesh, axes)
    m = wp.shape[0]
    n_rounds = rounds if rounds is not None else max(1, int(np.ceil(np.log2(max(m, 2)))))
    sharding = NamedSharding(mesh, P(*axes))
    r = jax.device_put(jnp.asarray(wp), sharding)
    round_fn = jax.jit(sharded_maxmin_round(mesh, schedule=schedule, axes=axes,
                                            use_kernels=use_kernels))
    for _ in range(n_rounds):
        r = round_fn(r)
    if not trim:
        return r
    return r[:np.asarray(w).shape[0], :np.asarray(w).shape[1]]


def sharded_threshold_closure_mr(w, thresholds, mesh: Mesh, *,
                                 rounds: Optional[int] = None,
                                 axes: Tuple[str, str, str] = ("pod", "data", "model")):
    """MR via threshold-batched boolean closure; thresholds shard over the
    pod axis, each [m, m] slice block-shards over (data, model).  The only
    cross-pod communication is the final max over the threshold dim."""
    pod_ax, row_ax, col_ax = axes
    wn = np.asarray(w)
    m_true = wn.shape[0]
    wp = pad_for_mesh(wn, mesh, (row_ax, col_ax))
    t = np.asarray(thresholds)
    pod = mesh.shape[pod_ax]
    tpad = (-t.size) % pod
    if tpad:
        # repeat the smallest threshold — duplicate slices are harmless
        t = np.concatenate([t, np.full(tpad, t.min(), t.dtype)])
    m = wp.shape[0]
    n_rounds = rounds if rounds is not None else max(1, int(np.ceil(np.log2(max(m, 2)))))

    batch_spec = P(pod_ax, row_ax, col_ax)
    sharding = NamedSharding(mesh, batch_spec)
    adj = (wp[None, :, :] >= t[:, None, None]).astype(np.float32)
    eye = np.eye(m, dtype=np.float32)[None]
    r = jax.device_put(jnp.asarray(np.maximum(adj, eye)), sharding)

    def round_body(blk):
        # blk: [S/pod, m/nr, m/nc]
        row_panel = jax.lax.all_gather(blk, col_ax, axis=2, tiled=True)
        col_panel = jax.lax.all_gather(blk, row_ax, axis=1, tiled=True)
        prod = jax.lax.batch_matmul(row_panel, col_panel)
        return (prod > 0).astype(blk.dtype)

    round_fn = jax.jit(shard_map(round_body, mesh=mesh,
                                     in_specs=batch_spec, out_specs=batch_spec))
    for _ in range(n_rounds):
        r = round_fn(r)
    tj = jnp.asarray(t).astype(jnp.float32)
    mr = (r * tj[:, None, None]).max(axis=0)        # cross-pod max-reduce
    mr = mr.at[jnp.arange(m), jnp.arange(m)].set(jnp.diagonal(jnp.asarray(wp)).astype(jnp.float32))
    return mr[:m_true, :m_true]


def collective_bytes_of(lowered_text: str) -> dict:
    """Sum operand bytes of collectives in an HLO dump — shared helper for
    the roofline harness (single source of truth lives here so both the
    reachability benches and the LM dry-run use identical accounting)."""
    import re
    ops = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
           "collective-permute")
    sizes = dict((k, 0) for k in ops)
    counts = dict((k, 0) for k in ops)
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                   "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    # one HLO instruction per line:  %x = <shape-or-tuple> <opcode>(...)
    line_re = re.compile(
        r"=\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(-start)?\(")
    for line in lowered_text.splitlines():
        mt = line_re.search(line)
        if not mt:
            continue
        shape_tok, op, _start = mt.groups()
        total = 0
        for d, dd in shape_re.findall(shape_tok):
            if d not in dtype_bytes:
                continue
            n = int(np.prod([int(x) for x in dd.split(",") if x])) if dd else 1
            total += n * dtype_bytes[d]
        sizes[op] += total
        counts[op] += 1
    return {"bytes": sizes, "counts": counts,
            "total_bytes": int(sum(sizes.values()))}


# ---------------------------------------------------------------------------
# The "sharded" engine backend
# ---------------------------------------------------------------------------

def default_line_graph_mesh(axes: Tuple[str, str] = ("data", "model")) -> Mesh:
    """2-D mesh over every visible device, rows × cols as near-square as
    the device count factors (4 -> 2×2, 2 -> 1×2, 1 -> 1×1, 6 -> 2×3).

    Near-square minimizes the allgather panel bytes per device per round
    (row panel m·m/c + column panel m·m/r is minimized at r ≈ c ≈ √P).
    """
    nd = jax.device_count()
    r = max(1, int(np.floor(np.sqrt(nd))))
    while nd % r:
        r -= 1
    return make_mesh((r, nd // r), axes)


def _round_up(x: int, k: int) -> int:
    return -(-x // k) * k


@functools.lru_cache(maxsize=None)
def _closure_patcher(sharding: NamedSharding, donate: bool):
    """Jitted in-place patch of the block-sharded W*: zero the freed
    slots' rows and columns, then scatter the re-closed scope block at
    its slots.  Buffer-donated off CPU, so the resident closure is
    patched without a second [mp, mp] allocation — the same donation
    path ``DeviceSnapshot.to_mesh(donate_base=)`` uses for snapshots."""
    def go(w, freed, slots, sub):
        if freed.shape[0]:
            w = w.at[freed, :].set(0.0)
            w = w.at[:, freed].set(0.0)
        if slots.shape[0]:
            w = w.at[slots[:, None], slots[None, :]].set(sub)
        return w
    return jax.jit(go, out_shardings=sharding,
                   donate_argnums=(0,) if donate else ())


@register_backend("sharded")
class ShardedEngine(_EngineBase):
    """Multi-device backend: W* block-sharded over a mesh, queries served
    off a mesh-sharded ``DeviceSnapshot``.

    Build runs ``sharded_maxmin_closure`` exactly once (allgather or ring
    schedule) and keeps the padded closure resident in its
    ``P(row_axis, col_axis)`` layout.  The snapshot derives the per-vertex
    label rows ``svals[u] = max_{e ∋ u} W*[e, :]`` on device (a scan of
    gathers, output sharded the same way), so label mass never funnels
    through one host round-trip and the snapshot survives across query
    batches.  Same exactness argument as the single-device ``closure``
    backend: every hyperedge is a hub, and the bottleneck triangle
    inequality makes the shared searchsorted join exact on these rows.

    Mesh handling: ``mesh=None`` builds a near-square 2-D mesh over all
    visible devices (``default_line_graph_mesh``); unit axes degrade to
    single-device execution (the collectives become no-ops), so the same
    engine runs on 1 host device and a 16×16 pod slice.

    ``build_labels=True`` switches the backend from the closure regime to
    the **label regime**: instead of keeping W* [m², O(m²/P) per device]
    resident, build runs sharded HL-index construction
    (``repro.core.hlindex.build_sharded`` — neighbor overlaps computed on
    this mesh, per-device component shards, byte-identical to
    ``build_fast``) and serves queries off the mesh-sharded **label**
    snapshot [n·Lmax ≪ m²].  Scalar queries answer through the paper's
    host merge-join.  This is the memory-lean serving shape for graphs
    whose closure no longer fits the mesh.

    **Scoped updates (capability "scoped"), both regimes.**  Labels and
    closure entries never cross line-graph components, so an edit only
    invalidates the component(s) containing its 1-hop touched set:

    * closure regime — hyperedges map to physical W* slots through
      ``_slot_of`` (deletes free slots, inserts take the lowest free
      ones, so W* is never permuted); the (max,min) fixpoint reruns over
      the touched components' sub-line-graph alone and the closed block
      is scattered into the resident W* at its slots (freed slots' rows/
      columns zeroed — every other entry between a scope and non-scope
      slot is already 0, the cross-component annihilator).  The cached
      snapshot is patched row-wise from the same sub-closure
      (``DeviceSnapshot.patch_rows``), so updates stay scoped even after
      ``snapshot()`` dropped W*.
    * label regime — ``apply_updates`` with the engine's persistent
      ``NeighborCSR`` (1-hop patched per edit, never recomputed) and
      ``build_sharded`` as the scope builder: the dirty components run
      LPT-sharded in parallel, then ``splice_rank`` composes exactly as
      serial maintenance — answers byte-identical to a fresh rebuild.

    Both paths report true ``refreshed_vertices`` through the dirty-rows
    contract, so ``ReplicaGroup`` fan-out patches rows instead of
    re-landing snapshots whole.
    """

    name = "sharded"
    update_capability = "scoped"
    # closure/label rows serve the label-row reductions; the host graph
    # is maintained under updates, so the traversal ops run too — same
    # capability shape as the single-device closure backend
    workload_capability = frozenset(WORKLOAD_OPS)
    _gate_hop_bounded = True

    def __init__(self, h, mesh: Mesh, axes: Tuple[str, str],
                 schedule: str, w_star_padded, m_true: int,
                 rounds: Optional[int] = None,
                 idx: Optional[HLIndex] = None,
                 minimizer=None, workers: Optional[int] = None,
                 num_shards: Optional[int] = None,
                 neighbors: Optional[NeighborCSR] = None):
        super().__init__(h)
        self.mesh = mesh
        self.axes = axes
        self.schedule = schedule
        self.rounds = rounds
        self._w_star = w_star_padded       # [mp, mp] sharded P(*axes)
        self._m_padded = (int(w_star_padded.shape[0])
                          if w_star_padded is not None else 0)
        self._m_true = m_true
        self._idx = idx                    # label regime (build_labels=True)
        self._minimizer = minimizer
        self._workers = workers
        self._num_shards = num_shards
        self._nbr = neighbors              # persistent line-graph CSR
        # hyperedge id -> physical W*/snapshot column; identity until a
        # scoped update frees/reuses slots
        self._slot_of = np.arange(m_true, dtype=np.int64)
        # (dirty_vertices, sval rows [d, mp], mp) staged by a scoped
        # closure update for the next snapshot() patch
        self._pending_rows: Optional[Tuple[np.ndarray, np.ndarray, int]] \
            = None
        self._snap: Optional[DeviceSnapshot] = None

    @property
    def build_labels(self) -> bool:
        """True when this engine serves labels instead of the closure."""
        return self._idx is not None

    @staticmethod
    def _closure_of(h, mesh, axes, schedule, rounds, use_kernels=False):
        """(padded sharded W*, m_true) for ``h`` — build and update share
        this so an updated engine is bit-identical to a rebuilt one."""
        if h.m == 0:
            return jnp.zeros((0, 0), jnp.float32), 0
        w = h.line_graph(np.int32).astype(np.float32)
        w_star = sharded_maxmin_closure(w, mesh, rounds=rounds,
                                        schedule=schedule, axes=axes,
                                        trim=False, use_kernels=use_kernels)
        return w_star, h.m

    @classmethod
    def build(cls, h, *, mesh: Optional[Mesh] = None,
              schedule: str = "allgather",
              axes: Optional[Tuple[str, str]] = None,
              rounds: Optional[int] = None,
              build_labels: bool = False,
              minimize_labels: bool = True,
              workers: Optional[int] = None,
              num_shards: Optional[int] = None,
              use_kernels: bool = False) -> "ShardedEngine":
        """``schedule`` ∈ {"allgather", "ring"} picks the collective plan
        (see module docstring); ``rounds`` caps the squaring ladder
        (None = ⌈log2 mp⌉, exact).  ``axes`` names the (row, column) mesh
        axes; None uses the mesh's own last two axis names (so any
        axis naming works), or ``("data", "model")`` when the mesh is
        built here.  ``build_labels=True`` builds the HL-index with
        sharded construction on this mesh instead of the resident
        closure (``minimize_labels`` / ``workers`` / ``num_shards``
        configure it); the closure knobs ``schedule`` / ``rounds`` are
        then unused.  ``use_kernels=True`` runs the per-device closure
        contraction through the Pallas ``maxmin_matmul`` kernel and
        batch queries through the Pallas label join (interpret-mode
        fallback off TPU; answers byte-identical, conformance-pinned)."""
        if axes is None:
            axes = (("data", "model") if mesh is None
                    else tuple(mesh.axis_names[-2:]))
        if mesh is None:
            mesh = default_line_graph_mesh(axes)
        if len(axes) < 2:
            raise ValueError(
                f"the sharded backend needs a mesh with >= 2 axes to 2-D "
                f"block-shard over; got axis names {mesh.axis_names}")
        if build_labels:
            minimizer = minimize if minimize_labels else None
            # the neighbor index is computed here (same host/mesh route
            # build_sharded would pick) and kept on the engine: scoped
            # updates 1-hop patch it instead of re-running the pair pass
            nbr = neighbor_csr(h, mesh=mesh if (auto_device_overlaps(h)
                               and int(mesh.devices.size) > 1) else None)
            idx = build_sharded(h, mesh=mesh, minimizer=minimizer,
                                workers=workers, num_shards=num_shards,
                                neighbors=nbr)
            eng = cls(h, mesh, axes, schedule, None, h.m, rounds,
                      idx=idx, minimizer=minimizer, workers=workers,
                      num_shards=num_shards, neighbors=nbr)
            eng.use_kernels = bool(use_kernels)
            return eng
        w_star, m_true = cls._closure_of(h, mesh, axes, schedule, rounds,
                                         use_kernels)
        eng = cls(h, mesh, axes, schedule, w_star, m_true, rounds)
        eng.use_kernels = bool(use_kernels)
        return eng

    def _apply_update(self, inserts=(), deletes=()) -> None:
        """Scoped maintenance on the same mesh (capability "scoped"):
        the label regime splices the touched components through the
        parallel sharded builder, the closure regime re-closes only the
        touched block of W* and patches the resident structures in
        place.  See the class docstring for the slot/patch mechanics."""
        if self._idx is not None:
            self._apply_label_update(inserts, deletes)
        else:
            self._apply_closure_update(inserts, deletes)

    def _apply_label_update(self, inserts, deletes) -> None:
        if self._nbr is None:
            # a restored engine lost the build-time neighbor index; pay
            # the pair pass once, then every update 1-hop patches it
            self._nbr = neighbor_csr(self.h)
        builder = functools.partial(build_sharded, workers=self._workers,
                                    num_shards=self._num_shards)
        new_h, self._idx, report = apply_updates(
            self.h, self._idx, inserts, deletes, builder=builder,
            minimizer=self._minimizer, neighbors=self._nbr)
        self._nbr = report.neighbors
        self._m_true = new_h.m
        self._graph_changed(new_h,
                            dirty_rows=(None if report.full_rebuild
                                        else report.refreshed_vertices))

    def _apply_closure_update(self, inserts, deletes) -> None:
        old_h = self.h
        new_h, old_to_new, touched = apply_edge_edits(old_h, inserts,
                                                      deletes)
        scope = (np.fromiter(sorted(component_of(new_h, touched)),
                             np.int64) if touched.size
                 else np.empty(0, np.int64))
        has_basis = self._w_star is not None or self._snap is not None
        if not has_basis or old_h.m == 0 or scope.size == new_h.m:
            # nothing resident to patch, or the edit reaches every
            # hyperedge: recompute whole (identical to a fresh build)
            self._w_star, self._m_true = self._closure_of(
                new_h, self.mesh, self.axes, self.schedule, self.rounds,
                self.use_kernels)
            self._m_padded = int(self._w_star.shape[0])
            self._slot_of = np.arange(new_h.m, dtype=np.int64)
            self._pending_rows = None
            self._graph_changed(new_h)
            return

        # -- slot bookkeeping: survivors keep their physical W* slots,
        # deletions free theirs, inserts take the lowest free slots (so
        # the resident [mp, mp] is never permuted, only patched)
        mp = self._m_padded
        del_ids = np.asarray(sorted({int(d) for d in deletes}), np.int64)
        freed = (self._slot_of[del_ids] if del_ids.size
                 else np.empty(0, np.int64))
        keep = np.nonzero(old_to_new >= 0)[0]
        slot_of = np.empty(new_h.m, np.int64)
        if keep.size:
            slot_of[old_to_new[keep]] = self._slot_of[keep]
        n_new_edges = new_h.m - keep.size
        if n_new_edges:
            used = self._slot_of[keep]
            free = np.setdiff1d(np.arange(mp, dtype=np.int64), used)
            if free.size < n_new_edges:
                lcm = int(np.lcm(self.mesh.shape[self.axes[0]],
                                 self.mesh.shape[self.axes[1]]))
                mp = _round_up(mp + n_new_edges - free.size, lcm)
                self._grow_w_padding(mp)
                free = np.setdiff1d(np.arange(mp, dtype=np.int64), used)
            slot_of[keep.size:] = free[:n_new_edges]
        self._slot_of = slot_of

        # -- re-close only the touched components' block.  Extracting
        # whole components preserves every overlap, and no (max,min)
        # walk crosses a component boundary, so the sub-closure equals
        # the full closure restricted to the scope.
        if scope.size:
            sub_h, sub_verts = induced_subhypergraph(new_h, scope)
            closed = np.asarray(sharded_maxmin_closure(
                sub_h.line_graph(np.int32).astype(np.float32), self.mesh,
                rounds=self.rounds, schedule=self.schedule,
                axes=self.axes, trim=True,
                use_kernels=self.use_kernels), dtype=np.float32)
        else:
            sub_h, sub_verts = None, np.empty(0, np.int64)
            closed = np.zeros((0, 0), np.float32)
        scope_slots = (slot_of[scope] if scope.size
                       else np.empty(0, np.int64))

        # -- patch the resident W* (if still held).  Old entries between
        # a scope slot and a surviving non-scope slot are already 0
        # (different components — insertions only merge components, and
        # every fragment of a deletion-split component contains a
        # surviving touched neighbor of the deleted hyperedge, putting
        # the whole fragment in scope), so zero-freed + scatter-scope is
        # the complete delta.
        if self._w_star is not None and (freed.size or scope.size):
            donate = all(d.platform != "cpu"
                         for d in self.mesh.devices.flat)
            patcher = _closure_patcher(
                NamedSharding(self.mesh, P(*self.axes)), donate)
            self._w_star = patcher(self._w_star,
                                   jnp.asarray(freed, jnp.int32),
                                   jnp.asarray(scope_slots, jnp.int32),
                                   jnp.asarray(closed))

        # -- stage the snapshot row patch: dirty vertices are exactly
        # the scope's vertices plus those of deleted hyperedges (which
        # may have lost their last hyperedge).  Their sval rows come
        # from the sub-closure alone; untouched rows already hold 0 at
        # every slot the patch could change (same confinement argument).
        if self._snap is not None:
            dirty = sub_verts
            if del_ids.size:
                dv = np.unique(np.concatenate(
                    [old_h.edge(int(d)) for d in del_ids]))
                dirty = np.union1d(dirty, dv)
            rows = np.zeros((dirty.size, mp), np.int32)
            if scope.size and sub_verts.size:
                block = np.zeros((sub_verts.size, scope.size), np.float32)
                rr = np.repeat(np.arange(sub_h.n), np.diff(sub_h.v_ptr))
                np.maximum.at(block, rr, closed[sub_h.v_idx])
                pos = np.searchsorted(dirty, sub_verts)
                rows[pos[:, None], scope_slots[None, :]] = \
                    block.astype(np.int32)
            self._merge_pending(dirty.astype(np.int64), rows, mp)
            self._m_true = new_h.m
            self._graph_changed(new_h, dirty_rows=dirty)
        else:
            self._pending_rows = None
            self._m_true = new_h.m
            self._graph_changed(new_h, dirty_rows=None)
            # the fresh W* patch is the whole resident state; the next
            # snapshot() derives from it whole
            self._snap = None

    def _grow_w_padding(self, mp_new: int) -> None:
        """Grow the padded slot space to ``mp_new`` (zero padding is the
        (max,min) annihilator, so growth never changes an answer)."""
        if self._w_star is not None:
            pad = mp_new - self._m_padded
            spec = NamedSharding(self.mesh, P(*self.axes))
            self._w_star = jax.jit(
                lambda w: jnp.pad(w, ((0, pad), (0, pad))),
                out_shardings=spec)(self._w_star)
        self._m_padded = mp_new

    def _merge_pending(self, dirty: np.ndarray, rows: np.ndarray,
                       mp: int) -> None:
        """Accumulate staged snapshot rows across updates between two
        ``snapshot()`` calls.  A previously staged row not re-dirtied by
        this update is still valid: any slot this update changed that
        could intersect it would have pulled its component into this
        update's scope (and hence re-dirtied it), so its value there was
        already 0 — only zero-padding to the grown width is needed."""
        prev = self._pending_rows
        if prev is not None:
            pd, prows, pmp = prev
            stale = ~np.isin(pd, dirty)
            if stale.any():
                old_rows = np.zeros((int(stale.sum()), mp), np.int32)
                old_rows[:, :pmp] = prows[stale]
                dirty = np.concatenate([dirty, pd[stale]])
                rows = np.concatenate([rows, old_rows])
                order = np.argsort(dirty)
                dirty, rows = dirty[order], rows[order]
        self._pending_rows = (dirty, rows, mp)

    # -- queries: everything routes through the resident snapshot (label
    # regime scalars short-circuit to the paper's host merge-join) -------

    def mr(self, u: int, v: int) -> int:
        if self._idx is not None:
            # the closure regime validates scalars through the batch
            # path; the label short-circuit rejects the same inputs
            self._check_vertex_ids(u, v)
            return mr_query(self._idx, int(u), int(v))
        return int(self.mr_batch(np.array([int(u)]), np.array([int(v)]))[0])

    def s_reach(self, u: int, v: int, s: int) -> bool:
        if self._idx is not None:
            self._check_vertex_ids(u, v)
            return s_reach_query(self._idx, int(u), int(v), int(s))
        return self.mr(u, v) >= int(s)

    def mr_batch(self, us, vs) -> np.ndarray:
        us, vs = validate_batch(us, vs, self.h.n)
        return np.asarray(self._query_snapshot().mr(us, vs)).astype(np.int64)

    def s_reach_batch(self, us, vs, s: int) -> np.ndarray:
        us, vs = validate_batch(us, vs, self.h.n)
        return np.asarray(self._query_snapshot().s_reach(us, vs, int(s)))

    def snapshot(self) -> DeviceSnapshot:
        """Current padded device form.  After a scoped update the stale
        snapshot is **patched**: only the dirty rows are re-derived (from
        the spliced labels, or from the staged sub-closure rows) and
        scattered over the old tensors.  Only a full re-derivation frees
        W*, and only while no WAL is attached — with an ``IndexStore`` in
        front, more updates are coming and the resident closure is what
        keeps them patchable in place, so it is retained."""
        if self._snapshot_current():
            return self._snap
        basis, dirty = self._snap, self._dirty_rows
        if self._idx is not None and basis is not None and dirty is not None:
            self._snap = self._patched_label_snapshot(basis, dirty)
            self.last_snapshot_refresh_rows = int(np.asarray(dirty).size)
        elif (basis is not None and dirty is not None
                and self._pending_rows is not None):
            self._snap = self._patched_closure_snapshot(basis)
            self.last_snapshot_refresh_rows = int(self._pending_rows[0].size)
        else:
            self._snap = self._build_snapshot()
            self.last_snapshot_refresh_rows = self.h.n
            if self._idx is None and self._wal is None:
                # static serving: every query path serves off the
                # snapshot from here on — free the closure so the
                # resident footprint is the snapshot alone (scoped
                # updates still work: they patch the snapshot directly)
                self._w_star = None
        self._pending_rows = None
        self._dirty_rows = np.empty(0, np.int64)
        return self._snap

    def _slot_ceiling(self) -> int:
        """Number of leading snapshot columns that can carry a live
        hyperedge (max occupied slot + 1) — the row ``lengths`` bound.
        Identity slots make this ``m_true``, matching a fresh build."""
        return int(self._slot_of.max()) + 1 if self._slot_of.size else 0

    def _patched_closure_snapshot(self, basis: DeviceSnapshot
                                  ) -> DeviceSnapshot:
        dirty, rows, mp = self._pending_rows
        cur_l = int(basis.ranks.shape[1])
        lmax = max(cur_l, mp)
        if rows.shape[1] < lmax:
            rows = np.pad(rows, ((0, 0), (0, lmax - rows.shape[1])))
        n_eff = max(int(basis.ranks.shape[0]),
                    _round_up(self.h.n, self.mesh.shape[self.axes[0]]))
        # rank space = slot id, dense ascending per row (same form the
        # full derivation materializes); untouched rows keep theirs
        row_ranks = np.broadcast_to(np.arange(lmax, dtype=np.int32),
                                    (dirty.size, lmax))
        row_lengths = np.full(dirty.size, self._slot_ceiling(), np.int32)
        return basis.patch_rows(dirty, row_ranks, rows, row_lengths,
                                n=n_eff, lmax=lmax, version=self.version,
                                backend=self.name)

    def _patched_label_snapshot(self, basis: DeviceSnapshot,
                                dirty) -> DeviceSnapshot:
        idx = self._idx
        dirty = np.asarray(dirty, np.int64)
        basis_len = np.asarray(basis.lengths)
        dirty_len = [idx.labels_s[int(u)].size for u in dirty]
        lmax = int(max(int(basis_len.max()) if basis_len.size else 0,
                       max(dirty_len, default=0)))
        row_ranks, row_svals, row_lengths = pad_label_rows(
            [idx.labels_rank[int(u)] for u in dirty],
            [idx.labels_s[int(u)] for u in dirty], pad_to=lmax)
        n_eff = max(int(basis.ranks.shape[0]), self.h.n)
        return basis.patch_rows(dirty, row_ranks, row_svals, row_lengths,
                                n=n_eff, lmax=lmax, version=self.version,
                                backend=self.name)

    def _build_snapshot(self) -> DeviceSnapshot:
        h, mesh = self.h, self.mesh
        row_ax, col_ax = self.axes
        if self._idx is not None:
            snap = DeviceSnapshot.from_hlindex(self._idx, self.name,
                                               version=self.version)
            if h.n == 0 or snap.lmax == 0:
                return snap            # nothing to shard over the mesh
            return snap.to_mesh(mesh, self.axes)
        if self._m_true == 0 or h.n == 0:
            z = np.zeros((h.n, 0), np.int32)
            return DeviceSnapshot.from_padded(z, z, np.zeros(h.n, np.int32),
                                              self.name, version=self.version)
        mp = self._m_padded
        n_pad = _round_up(h.n, mesh.shape[row_ax])
        deg = np.diff(h.v_ptr)
        d_max = max(int(deg.max()), 1)
        # padded incidence: inc[u, k] = k-th hyperedge of u, mp = phantom
        # all-zero row of the padded closure (annihilator => no-op);
        # one-shot scatter straight from the CSR arrays
        inc = np.full((n_pad, d_max), mp, np.int32)
        rows = np.repeat(np.arange(h.n), deg)
        cols = np.arange(h.nnz) - np.repeat(h.v_ptr[:-1], deg)
        inc[rows, cols] = self._slot_of[h.v_idx]   # edge id -> W* slot
        spec2d = NamedSharding(mesh, P(row_ax, col_ax))
        inc_dev = jax.device_put(inc, NamedSharding(mesh, P(row_ax, None)))

        @functools.partial(jax.jit, out_shardings=spec2d)
        def vertex_rows(w_star, inc):
            # svals[u] = max_{e in E(u)} W*[e, :], scanned over the degree
            # dim so the working set stays one [n_pad, mp] panel
            w1 = jnp.concatenate(
                [w_star, jnp.zeros((1, w_star.shape[1]), w_star.dtype)], 0)

            def body(acc, d):
                return jnp.maximum(acc, w1[jnp.take(inc, d, axis=1)]), None

            init = jnp.zeros((inc.shape[0], w_star.shape[1]), w_star.dtype)
            out, _ = jax.lax.scan(body, init, jnp.arange(inc.shape[1]))
            return out

        svals = vertex_rows(self._w_star, inc_dev).astype(jnp.int32)
        # rank space = hyperedge id (ascending per row by construction);
        # padded columns carry sval 0, which can never win the join max.
        # Materialized directly on device in the sharded layout — the
        # [n_pad, mp] broadcast never exists on the host.
        ranks = jax.jit(
            lambda: jnp.broadcast_to(jnp.arange(mp, dtype=jnp.int32),
                                     (n_pad, mp)),
            out_shardings=spec2d)()
        lengths = np.zeros(n_pad, np.int32)
        # every occupied slot must fall inside the row length; identity
        # slots make this m_true, same as before scoped maintenance
        lengths[:h.n] = self._slot_ceiling()
        lengths = jax.device_put(lengths, NamedSharding(mesh, P(row_ax)))
        return DeviceSnapshot.from_padded(ranks, svals, lengths, self.name,
                                          version=self.version)

    def block_until_built(self) -> None:
        if self._w_star is not None:
            jax.block_until_ready(self._w_star)

    def nbytes(self) -> int:
        total = 0
        if self._w_star is not None:
            total += self._m_padded * self._m_padded * 4
        if self._idx is not None:
            total += self._idx.nbytes()
        if self._nbr is not None:
            total += self._nbr.nbytes()
        if self._snap is not None:
            total += self._snap.nbytes()
        return total
