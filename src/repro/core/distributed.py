"""Distributed reachability engine: 2-D block-sharded semiring closures.

For hypergraphs whose line graph does not fit one device, the closure
operand R [m, m] is block-sharded over the production mesh axes
``(data, model)`` and each squaring round runs a SUMMA-style contraction
under ``jax.shard_map`` with explicit collectives:

* ``allgather`` schedule — device (i, j) gathers its row panel R[i, :]
  along ``model`` and its column panel R[:, j] along ``data``, then
  contracts locally.  Two all-gathers of m²/P elements per device per
  round; simple, and XLA can overlap the two gathers.
* ``ring`` schedule — the column panel circulates via
  ``jax.lax.ppermute`` while partial contractions accumulate, so each
  step's collective-permute overlaps the previous step's compute
  (the classic Cannon/SUMMA overlap trick).  Same total bytes, but peak
  working set drops from m·m/P_col to m/P_row·m/P_col per step and the
  link traffic is pipelined — this is the collective-bound optimization
  knob for §Perf.

The threshold-batched boolean closure shards its threshold dim over the
``pod`` axis (embarrassingly parallel — zero inter-pod traffic until the
final max-reduce), giving the multi-pod scaling story.

Meshes with unit axes degrade gracefully (the collectives become no-ops),
so the same code runs tests on 1-4 host devices and the 512-way dry-run.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

__all__ = [
    "pad_for_mesh", "sharded_maxmin_round", "sharded_maxmin_closure",
    "sharded_threshold_closure_mr", "collective_bytes_of",
]


def pad_for_mesh(w: np.ndarray, mesh: Mesh,
                 axes: Tuple[str, str] = ("data", "model")) -> np.ndarray:
    """Pad [m, m] (or [S, m, m]) so both block dims divide the mesh axes.
    Zero is the (max,min) annihilator and boolean-adjacency identity, so
    padding is exact for both closure flavors."""
    r, c = mesh.shape[axes[0]], mesh.shape[axes[1]]
    lcm = int(np.lcm(r, c))
    m = w.shape[-1]
    pad = (-m) % lcm
    if pad == 0:
        return w
    widths = [(0, 0)] * (w.ndim - 2) + [(0, pad), (0, pad)]
    return np.pad(w, widths)


def _local_maxmin(a: jax.Array, b: jax.Array, chunk: int = 128) -> jax.Array:
    """Blocked local (max,min) contraction (keeps the broadcast bounded)."""
    m, k = a.shape
    _, n = b.shape
    if k <= chunk:
        return jnp.minimum(a[:, :, None], b[None, :, :]).max(axis=1)
    pad = (-k) % chunk

    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, pad), (0, 0)))

    def body(carry, kk):
        a_blk = jax.lax.dynamic_slice(a, (0, kk), (m, chunk))
        b_blk = jax.lax.dynamic_slice(b, (kk, 0), (chunk, n))
        c = jnp.minimum(a_blk[:, :, None], b_blk[None, :, :]).max(axis=1)
        return jnp.maximum(carry, c), None

    # init derived from the operands (not a constant) so its device-varying
    # type matches the scan body's output under shard_map
    init = jnp.minimum(a[:, :1], b[:1, :]) * 0
    steps = (k + pad) // chunk
    out, _ = jax.lax.scan(body, init, jnp.arange(steps) * chunk)
    return out


def sharded_maxmin_round(mesh: Mesh, *, schedule: str = "allgather",
                         axes: Tuple[str, str] = ("data", "model")):
    """Returns a jit-able fn R -> max(R, R∘R) for R sharded P(axes)."""
    row_ax, col_ax = axes
    n_row = mesh.shape[row_ax]
    n_col = mesh.shape[col_ax]
    spec = P(row_ax, col_ax)

    if schedule == "allgather":
        def round_fn(r):
            def body(blk):
                # blk: [m/nr, m/nc] local block at mesh position (i, j)
                row_panel = jax.lax.all_gather(blk, col_ax, axis=1, tiled=True)
                col_panel = jax.lax.all_gather(blk, row_ax, axis=0, tiled=True)
                return jnp.maximum(blk, _local_maxmin(row_panel, col_panel))
            return shard_map(body, mesh=mesh, in_specs=spec,
                                 out_specs=spec)(r)
        return round_fn

    if schedule == "ring":
        def round_fn(r):
            def body(blk):
                # Ring over the row axis: the column panel R[k, j] visits
                # every k; partials accumulate while the next panel is in
                # flight.  Row panel is gathered once along `model`.
                row_panel = jax.lax.all_gather(blk, col_ax, axis=1, tiled=True)
                my_row = jax.lax.axis_index(row_ax)
                perm = [(i, (i + 1) % n_row) for i in range(n_row)]
                block_rows = blk.shape[0]

                def step(carry, t):
                    acc, panel = carry
                    # panel currently holds R[(my_row - t) % n_row, j]
                    src = (my_row - t) % n_row
                    seg = jax.lax.dynamic_slice(
                        row_panel, (0, src * block_rows),
                        (block_rows, block_rows))
                    acc = jnp.maximum(acc, _local_maxmin(seg, panel))
                    panel = jax.lax.ppermute(panel, row_ax, perm)
                    return (acc, panel), None

                (acc, _), _ = jax.lax.scan(step, (blk, blk),
                                           jnp.arange(n_row))
                return acc
            return shard_map(body, mesh=mesh, in_specs=spec,
                                 out_specs=spec)(r)
        return round_fn

    raise ValueError(schedule)


def sharded_maxmin_closure(w, mesh: Mesh, *, rounds: Optional[int] = None,
                           schedule: str = "allgather",
                           axes: Tuple[str, str] = ("data", "model")):
    """Bottleneck closure of a 2-D block-sharded line graph."""
    wp = pad_for_mesh(np.asarray(w), mesh, axes)
    m = wp.shape[0]
    n_rounds = rounds if rounds is not None else max(1, int(np.ceil(np.log2(max(m, 2)))))
    sharding = NamedSharding(mesh, P(*axes))
    r = jax.device_put(jnp.asarray(wp), sharding)
    round_fn = jax.jit(sharded_maxmin_round(mesh, schedule=schedule, axes=axes))
    for _ in range(n_rounds):
        r = round_fn(r)
    return r[:np.asarray(w).shape[0], :np.asarray(w).shape[1]]


def sharded_threshold_closure_mr(w, thresholds, mesh: Mesh, *,
                                 rounds: Optional[int] = None,
                                 axes: Tuple[str, str, str] = ("pod", "data", "model")):
    """MR via threshold-batched boolean closure; thresholds shard over the
    pod axis, each [m, m] slice block-shards over (data, model).  The only
    cross-pod communication is the final max over the threshold dim."""
    pod_ax, row_ax, col_ax = axes
    wn = np.asarray(w)
    m_true = wn.shape[0]
    wp = pad_for_mesh(wn, mesh, (row_ax, col_ax))
    t = np.asarray(thresholds)
    pod = mesh.shape[pod_ax]
    tpad = (-t.size) % pod
    if tpad:
        # repeat the smallest threshold — duplicate slices are harmless
        t = np.concatenate([t, np.full(tpad, t.min(), t.dtype)])
    m = wp.shape[0]
    n_rounds = rounds if rounds is not None else max(1, int(np.ceil(np.log2(max(m, 2)))))

    batch_spec = P(pod_ax, row_ax, col_ax)
    sharding = NamedSharding(mesh, batch_spec)
    adj = (wp[None, :, :] >= t[:, None, None]).astype(np.float32)
    eye = np.eye(m, dtype=np.float32)[None]
    r = jax.device_put(jnp.asarray(np.maximum(adj, eye)), sharding)

    def round_body(blk):
        # blk: [S/pod, m/nr, m/nc]
        row_panel = jax.lax.all_gather(blk, col_ax, axis=2, tiled=True)
        col_panel = jax.lax.all_gather(blk, row_ax, axis=1, tiled=True)
        prod = jax.lax.batch_matmul(row_panel, col_panel)
        return (prod > 0).astype(blk.dtype)

    round_fn = jax.jit(shard_map(round_body, mesh=mesh,
                                     in_specs=batch_spec, out_specs=batch_spec))
    for _ in range(n_rounds):
        r = round_fn(r)
    tj = jnp.asarray(t).astype(jnp.float32)
    mr = (r * tj[:, None, None]).max(axis=0)        # cross-pod max-reduce
    mr = mr.at[jnp.arange(m), jnp.arange(m)].set(jnp.diagonal(jnp.asarray(wp)).astype(jnp.float32))
    return mr[:m_true, :m_true]


def collective_bytes_of(lowered_text: str) -> dict:
    """Sum operand bytes of collectives in an HLO dump — shared helper for
    the roofline harness (single source of truth lives here so both the
    reachability benches and the LM dry-run use identical accounting)."""
    import re
    ops = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
           "collective-permute")
    sizes = dict((k, 0) for k in ops)
    counts = dict((k, 0) for k in ops)
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                   "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    # one HLO instruction per line:  %x = <shape-or-tuple> <opcode>(...)
    line_re = re.compile(
        r"=\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(-start)?\(")
    for line in lowered_text.splitlines():
        mt = line_re.search(line)
        if not mt:
            continue
        shape_tok, op, _start = mt.groups()
        total = 0
        for d, dd in shape_re.findall(shape_tok):
            if d not in dtype_bytes:
                continue
            n = int(np.prod([int(x) for x in dd.split(",") if x])) if dd else 1
            total += n * dtype_bytes[d]
        sizes[op] += total
        counts[op] += 1
    return {"bytes": sizes, "counts": counts,
            "total_bytes": int(sum(sizes.values()))}
