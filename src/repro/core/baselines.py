"""Baselines from Section IV / VII.

* ``vtv_query`` — the vertex-to-vertex 2-hop extension the paper shows to
  be *incorrect* (Example 5: it over-estimates because the hub vertex
  forgets which hyperedge each side used).
* ``ETEIndex`` — hyperedge-to-hyperedge labeling (correct, but query cost
  grows with |E(u)|·|E(v)| label mass; the paper's merge-sort variant is
  implemented).
* ``ThresholdComponentIndex`` — HypED-style per-threshold structure: for
  every candidate s, union-find components of the ≥s line graph.  Exact
  for MR, but storage is O(S·m) with S up to δ — reproducing the paper's
  observation that HypED-style oracles blow up when s ranges to tens of
  thousands (their OOM rows in Exp-1).
* ``MSTOracle`` — maximum-spanning-forest bottleneck oracle (classic
  maximin-path identity), an independent exact implementation used to
  cross-validate the semiring closure and the HL-index on larger graphs.
"""
from __future__ import annotations

import heapq
from typing import List, Optional, Tuple

import numpy as np

from .hypergraph import Hypergraph
from .hlindex import _Builder

__all__ = ["vtv_query", "ETEIndex", "build_ete", "ThresholdComponentIndex",
           "MSTOracle", "line_graph_edges",
           "brute_force_s_distance", "brute_force_s_reach_k",
           "brute_force_witness", "brute_force_mr_set",
           "brute_force_mr_from_set", "brute_force_top_s"]


# ---------------------------------------------------------------------------
# VTV (incorrect) — kept only to demonstrate the paper's Example 5 pitfall
# ---------------------------------------------------------------------------

def vtv_query(mr_vertex: np.ndarray, u: int, v: int,
              hubs: Optional[np.ndarray] = None) -> int:
    """Best-case VTV 2-hop answer: max_w min(MR(u,w), MR(w,v)) over hub
    vertices.  Even with *perfect* vertex-to-vertex values this
    over-estimates (the two legs may force incompatible hyperedge pairs at
    the hub), which is exactly the paper's Example 5 argument — so any
    realizable VTV index is unsound for MR.
    """
    w = np.arange(mr_vertex.shape[0]) if hubs is None else hubs
    legs = np.minimum(mr_vertex[u, w], mr_vertex[w, v])
    return int(legs.max()) if legs.size else 0


# ---------------------------------------------------------------------------
# ETE index
# ---------------------------------------------------------------------------

class ETEIndex:
    """Hyperedge-to-hyperedge 2-hop labels: Le(e) = [(hub_rank, hub, s)]."""

    def __init__(self, h: Hypergraph, rank: np.ndarray,
                 labels: List[List[Tuple[int, int]]]):
        self.h = h
        self.rank = rank
        self.labels_rank: List[np.ndarray] = []
        self.labels_s: List[np.ndarray] = []
        for e in range(h.m):
            if labels[e]:
                hub = np.array([t[0] for t in labels[e]], np.int64)
                s = np.array([t[1] for t in labels[e]], np.int64)
                r = rank[hub]
                order = np.argsort(r, kind="stable")
                self.labels_rank.append(r[order])
                self.labels_s.append(s[order])
            else:
                self.labels_rank.append(np.empty(0, np.int64))
                self.labels_s.append(np.empty(0, np.int64))

    @property
    def num_labels(self) -> int:
        return int(sum(a.size for a in self.labels_s))

    def nbytes(self) -> int:
        return self.num_labels * 8

    def _merged(self, edges: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Merge the label lists of a vertex's hyperedges, keeping the max s
        per hub (the paper's merge-sort-based de-duplication)."""
        if edges.size == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        ranks = np.concatenate([self.labels_rank[int(e)] for e in edges])
        svals = np.concatenate([self.labels_s[int(e)] for e in edges])
        if ranks.size == 0:
            return ranks, svals
        order = np.lexsort((-svals, ranks))
        ranks, svals = ranks[order], svals[order]
        keep = np.ones(ranks.size, bool)
        keep[1:] = ranks[1:] != ranks[:-1]
        return ranks[keep], svals[keep]

    def mr(self, u: int, v: int) -> int:
        ra, sa = self._merged(self.h.edges_of(u))
        rb, sb = self._merged(self.h.edges_of(v))
        i = j = 0
        k = 0
        while i < ra.size and j < rb.size:
            if sa[i] <= k or ra[i] < rb[j]:
                i += 1
            elif sb[j] <= k or ra[i] > rb[j]:
                j += 1
            else:
                k = int(min(sa[i], sb[j]))
                i += 1
                j += 1
        return k


def build_ete(h: Hypergraph) -> ETEIndex:
    """ETE labeling via the same MCD-pruned traversal as Algorithm 3, but
    recording hyperedge-level labels (root, s) for every popped hyperedge."""
    b = _Builder(h)
    rank, sizes = b.rank, b.sizes
    mcd = np.zeros(h.m, np.int64)
    labels: List[List[Tuple[int, int]]] = [[] for _ in range(h.m)]
    for root in [int(x) for x in b.perm]:
        if mcd[root] == sizes[root]:
            continue
        mcd_root = int(mcd[root])
        q: List[Tuple[int, int]] = [(-int(sizes[root]), root)]
        while q:
            neg_s, e_u = heapq.heappop(q)
            s = -neg_s
            if b.visited_e[e_u] == root:
                continue
            b.visited_e[e_u] = root
            if e_u != root and s > mcd[e_u]:
                mcd[e_u] = s
            labels[e_u].append((root, s))
            nb, od = h.neighbors_od(e_u)
            for e_v, w in zip(nb, od):
                e_v, w = int(e_v), int(w)
                if (w > mcd_root and rank[e_v] > rank[root]
                        and b.visited_e[e_v] != root):
                    heapq.heappush(q, (-min(s, w), e_v))
    return ETEIndex(h, rank, labels)


# ---------------------------------------------------------------------------
# HypED-style threshold-component index
# ---------------------------------------------------------------------------

def line_graph_edges(h: Hypergraph) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sparse line-graph edge list (i < j, OD > 0) built from incidence."""
    src: List[int] = []
    dst: List[int] = []
    ods: List[int] = []
    for e in range(h.m):
        nb, od = h.neighbors_od(e)
        for e2, w in zip(nb, od):
            if e < int(e2):
                src.append(e)
                dst.append(int(e2))
                ods.append(int(w))
    return (np.array(src, np.int64), np.array(dst, np.int64),
            np.array(ods, np.int64))


class _DSU:
    def __init__(self, n: int):
        self.p = np.arange(n, dtype=np.int64)

    def find(self, x: int) -> int:
        p = self.p
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return int(x)

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[rb] = ra


class ThresholdComponentIndex:
    """comp[s_idx, e] = component id of hyperedge e in the ≥s line graph.

    Built by one descending Kruskal sweep; MR(u,v) = largest threshold at
    which some pair of incident hyperedges share a component.  Storage
    O(S·m) — the HypED-style blow-up the paper contrasts against.
    """

    def __init__(self, h: Hypergraph, cap: Optional[int] = None):
        self.h = h
        src, dst, od = line_graph_edges(h)
        sizes = h.edge_sizes
        thresholds = np.unique(np.concatenate([od, sizes]))
        thresholds = thresholds[thresholds > 0][::-1]     # descending
        if cap is not None:
            thresholds = thresholds[:cap]
        self.thresholds = thresholds
        order = np.argsort(-od)
        src, dst, od = src[order], dst[order], od[order]
        dsu = _DSU(h.m)
        comp = np.empty((thresholds.size, h.m), np.int32)
        ei = 0
        for ti, t in enumerate(thresholds):
            while ei < od.size and od[ei] >= t:
                dsu.union(int(src[ei]), int(dst[ei]))
                ei += 1
            comp[ti] = [dsu.find(e) for e in range(h.m)]
        self.comp = comp

    def nbytes(self) -> int:
        return self.comp.nbytes

    def mr(self, u: int, v: int) -> int:
        eu = self.h.edges_of(u)
        ev = self.h.edges_of(v)
        if not eu.size or not ev.size:
            return 0
        sizes = self.h.edge_sizes
        for ti, t in enumerate(self.thresholds):
            cu = self.comp[ti, eu]
            cv = self.comp[ti, ev]
            # same component at threshold t: need both endpoints' hyperedges
            # alive at t (|e| ≥ t — a single-hyperedge walk has WOD |e|;
            # components only merge via OD ≥ t edges which imply |e| ≥ t).
            au = eu[sizes[eu] >= t]
            av = ev[sizes[ev] >= t]
            if au.size and av.size:
                cu = self.comp[ti, au]
                cv = self.comp[ti, av]
                if np.intersect1d(cu, cv).size:
                    return int(t)
        return 0


# ---------------------------------------------------------------------------
# MST bottleneck oracle (independent exact implementation)
# ---------------------------------------------------------------------------

class MSTOracle:
    """Maximin(e_i, e_j) equals the minimum edge on the maximum-spanning-
    forest path — an O(m α) build + O(m) per query independent oracle."""

    def __init__(self, h: Hypergraph):
        self.h = h
        src, dst, od = line_graph_edges(h)
        order = np.argsort(-od)
        dsu = _DSU(h.m)
        adj: List[List[Tuple[int, int]]] = [[] for _ in range(h.m)]
        for i in order:
            a, b_, w = int(src[i]), int(dst[i]), int(od[i])
            if dsu.find(a) != dsu.find(b_):
                dsu.union(a, b_)
                adj[a].append((b_, w))
                adj[b_].append((a, w))
        self.adj = adj

    def edge_mr(self, ei: int, ej: int) -> int:
        if ei == ej:
            return self.h.edge_size(ei)
        # BFS on the forest tracking the path bottleneck
        best = {ei: np.iinfo(np.int64).max}
        stack = [ei]
        while stack:
            x = stack.pop()
            for y, w in self.adj[x]:
                nb = min(best[x], w)
                if y not in best:
                    best[y] = nb
                    if y == ej:
                        return int(nb)
                    stack.append(y)
        return 0

    def mr(self, u: int, v: int) -> int:
        out = 0
        for eu in self.h.edges_of(u):
            for ev in self.h.edges_of(v):
                out = max(out, self.edge_mr(int(eu), int(ev)))
        return out


# ---------------------------------------------------------------------------
# Brute-force workload references.  The workload subsystem
# (src/repro/workloads/) is pinned cell-by-cell against exactly these in
# tests/test_conformance.py, so they are deliberately *independent*
# implementations: dense threshold sweeps and matrix-frontier expansion
# here vs the production hub-label / bounded-BFS / landmark paths there.
# ---------------------------------------------------------------------------

def brute_force_s_distance(h: Hypergraph, u: int, v: int, s: int) -> int:
    """Exact s-distance (fewest hyperedges in an s-walk; 0 = none) by
    dense boolean frontier expansion on the >= s line graph.  Shortest
    s-walks never repeat a hyperedge — a repeat collapses to a shorter
    walk, and the collapsed single-edge case is always valid because
    od >= s forces |e| >= s — so plain level expansion is exact."""
    m = h.m
    u, v, s = int(u), int(v), int(s)
    if m == 0:
        return 0
    eu = h.edges_of(u)
    ev = h.edges_of(v)
    if eu.size == 0 or ev.size == 0:
        return 0
    fu = np.zeros(m, bool)
    fu[eu] = True
    fv = np.zeros(m, bool)
    fv[ev] = True
    if bool((fu & fv & (h.edge_sizes >= s)).any()):
        return 1
    src, dst, od = line_graph_edges(h)
    keep = od >= s
    adj = np.zeros((m, m), bool)
    adj[src[keep], dst[keep]] = True
    adj |= adj.T
    reach = fu.copy()
    frontier = fu.copy()
    for t in range(2, m + 1):
        frontier = adj[frontier].any(axis=0) & ~reach
        if not frontier.any():
            return 0
        if bool((frontier & fv).any()):
            return t
        reach |= frontier
    return 0


def brute_force_s_reach_k(h: Hypergraph, u: int, v: int, s: int,
                          k: int) -> bool:
    """Hop-bounded s-reach: an s-walk of at most ``k`` hyperedges."""
    d = brute_force_s_distance(h, u, v, s)
    return 0 < d <= int(k)


def brute_force_witness(h: Hypergraph, u: int, v: int,
                        ) -> Tuple[int, Tuple[int, ...]]:
    """(MR(u, v), witness walk): descending threshold sweep to find the
    largest reachable s, then a parent-tracked BFS on the >= s line
    graph to recover one walk achieving it."""
    u, v = int(u), int(v)
    sizes = h.edge_sizes
    smax = int(sizes.max()) if h.m else 0
    k = 0
    for s in range(smax, 0, -1):
        if brute_force_s_distance(h, u, v, s) > 0:
            k = s
            break
    if k == 0:
        return 0, ()
    eu = sorted(int(e) for e in h.edges_of(u))
    ev_set = {int(e) for e in h.edges_of(v)}
    shared = [e for e in eu if e in ev_set and int(sizes[e]) >= k]
    if shared:
        return k, (shared[0],)
    parent = {e: -1 for e in eu}
    queue = list(eu)
    while queue:
        e = queue.pop(0)
        nbrs, ods = h.neighbors_od(e)
        for nb, w in zip(nbrs, ods):
            nb = int(nb)
            if int(w) >= k and nb not in parent:
                parent[nb] = e
                queue.append(nb)

    def backtrack(e: int) -> Tuple[int, ...]:
        out = [e]
        while parent[out[-1]] != -1:
            out.append(parent[out[-1]])
        return tuple(reversed(out))

    eu_set = set(eu)
    for t in sorted(ev_set):
        if t in parent and t not in eu_set:
            return k, backtrack(t)
    # remaining case: every reachable target is also an undersized seed
    # — the walk must *end* on a fresh edge adjacent to the tree
    for a in sorted(parent):
        nbrs, ods = h.neighbors_od(a)
        for nb, w in zip(nbrs, ods):
            if int(w) >= k and int(nb) in ev_set:
                return k, backtrack(a) + (int(nb),)
    raise AssertionError(
        f"threshold sweep said MR({u}, {v}) = {k} but no walk was found")


def brute_force_mr_set(h: Hypergraph, us, vs) -> int:
    """Set-to-set MR: max over the cross product, one oracle pair at a
    time."""
    oracle = MSTOracle(h)
    return max((oracle.mr(int(a), int(b)) for a in us for b in vs),
               default=0)


def brute_force_mr_from_set(h: Hypergraph, us, targets) -> np.ndarray:
    """Multi-source MR: per target, the best MR from any source."""
    oracle = MSTOracle(h)
    return np.array([max((oracle.mr(int(a), int(t)) for a in us),
                         default=0) for t in targets], np.int64)


def brute_force_top_s(h: Hypergraph, u: int, k: int,
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k strongest-s: every MR(u, .) via the oracle, ranked by
    (MR desc, vertex id asc), zeros and ``u`` itself dropped."""
    u = int(u)
    oracle = MSTOracle(h)
    scored = sorted((-oracle.mr(u, v), v) for v in range(h.n) if v != u)
    picked = [(v, -neg) for neg, v in scored if neg < 0][:int(k)]
    verts = np.array([v for v, _ in picked], np.int64)
    vals = np.array([s for _, s in picked], np.int64)
    return verts, vals
