"""Algorithm 4 — minimal HL-index generation.

Removes redundant labels from a complete HL-index: a label ``(e, s_u)`` of
``u`` is redundant iff for every vertex ``v`` reachable through hub ``e``
(the dual set ``D(e)``) some other hyperedge ``e'`` supports
``u ~> e ~> v`` with ``min(s'_u, s'_v) ≥ min(s_u, s_v)``.

Faithful structures: dual ``D``, inverted set ``I`` (Observation 1 filter),
non-redundant set ``NR`` (Lemma 7 co-marking), verification in
non-ascending ``s`` order.  Interpretation notes:

* ``NR`` tracks *unprocessed* vertices only; line 20's early exit fires
  when every remaining unverified entry is already marked, and line 21
  then keeps exactly those (processed survivors were kept at line 15).
* removals mutate ``L``/``D`` in place so later verifications (and later
  roots) see the shrunken index, matching the "iteratively identify and
  remove one at a time" semantics.

``exact_minimize`` is a beyond-paper post-pass that enforces *exact*
necessity by trial removal + query re-check; used by tests to measure how
close Algorithm 4 gets (see EXPERIMENTS.md §Minimality).
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple

import numpy as np

from .hlindex import HLIndex

__all__ = ["minimize", "exact_minimize"]


def _rebuild(idx: HLIndex, L: List[Dict[int, int]]) -> HLIndex:
    """Repack dict-of-dicts labels into a fresh HLIndex (rank-sorted)."""
    h, rank = idx.h, idx.rank
    le, lr, ls = [], [], []
    dual: List[List[Tuple[int, int]]] = [[] for _ in range(h.m)]
    for u in range(h.n):
        if L[u]:
            e = np.fromiter(L[u].keys(), np.int64, len(L[u]))
            s = np.fromiter(L[u].values(), np.int64, len(L[u]))
            order = np.argsort(rank[e], kind="stable")
            e, s = e[order], s[order]
        else:
            e = np.empty(0, np.int64)
            s = np.empty(0, np.int64)
        le.append(e)
        lr.append(rank[e] if e.size else np.empty(0, np.int64))
        ls.append(s)
        for ee, ss in zip(e, s):
            dual[int(ee)].append((u, int(ss)))
    du, ds = [], []
    for e in range(h.m):
        pairs = sorted(dual[e], key=lambda t: -t[1])
        du.append(np.array([p[0] for p in pairs], np.int64))
        ds.append(np.array([p[1] for p in pairs], np.int64))
    return HLIndex(h=h, rank=idx.rank, perm=idx.perm, labels_edge=le,
                   labels_rank=lr, labels_s=ls, dual_u=du, dual_s=ds,
                   stats=dict(idx.stats))


def minimize(idx: HLIndex) -> HLIndex:
    """Algorithm 4: produce a minimal HL-index L* from a complete one."""
    h = idx.h
    # L as dict-of-dicts (mutated in place), D as per-edge ordered entries
    L: List[Dict[int, int]] = [dict(zip(map(int, idx.labels_edge[u]),
                                        map(int, idx.labels_s[u])))
                               for u in range(h.n)]
    D: List[List[Tuple[int, int]]] = []
    for e in range(h.m):
        pairs = sorted(zip(map(int, idx.dual_u[e]), map(int, idx.dual_s[e])),
                       key=lambda t: -t[1])          # non-ascending s
        D.append(pairs)

    for root in [int(x) for x in idx.perm]:          # descending importance
        entries = D[root]
        if not entries:
            continue
        # lines 3-6: the paper's inverted set I over potential supporting
        # hubs (named `inv` here; `I` is an ambiguous identifier)
        inv: Dict[int, List[Tuple[int, int]]] = {}
        for v, s_v in entries:
            for e2, s2 in L[v].items():
                if e2 != root and s2 >= s_v:
                    inv.setdefault(e2, []).append((v, s_v))
        alive: Dict[int, int] = dict(entries)        # current V(D(root))
        NR: Set[int] = set()                         # unprocessed, pre-marked
        processed: Set[int] = set()
        for pos, (u, s_u) in enumerate(entries):     # line 7 (non-ascending s)
            pre_marked = u in NR
            NR.discard(u)
            # lines 9-13: support set S — computed even for pre-marked u,
            # since line 16's co-marking of unprocessed partners needs it
            # (a pair (u, w) supported only by `root` pins *both* labels).
            S: Set[int] = set()
            target = len(alive)
            complete = False
            for e2, s2u in L[u].items():
                if e2 == root:
                    continue
                for v, s_v in inv.get(e2, ()):
                    if v not in alive or s2u < s_v:
                        continue
                    S.add(v)
                    if len(S) == target:
                        complete = True
                        break
                if complete:
                    break
            processed.add(u)
            if not complete or pre_marked:           # line 14: keep
                for w in alive:                      # line 16
                    if w not in S and w not in processed:
                        NR.add(w)
            else:                                    # lines 18-19: remove
                del L[u][root]
                del alive[u]
            # line 20: all remaining unverified entries already marked
            remaining = [w for w, _ in entries[pos + 1:] if w in alive]
            if remaining and all(w in NR for w in remaining):
                break                                # line 21: keep them as-is
        D[root] = [(u, s) for u, s in entries if u in alive]
    return _rebuild(idx, L)


def exact_minimize(idx: HLIndex) -> HLIndex:
    """Beyond-paper exact-necessity post-pass: for every label, trial-remove
    and keep it only if some MR(u, v) over the hub's dual set changes.
    O(l · θ · l_v) — for tests/benchmarks, not the production path.
    """
    from .query import mr_query_dicts

    h = idx.h
    L: List[Dict[int, int]] = [dict(zip(map(int, idx.labels_edge[u]),
                                        map(int, idx.labels_s[u])))
                               for u in range(h.n)]
    rank = idx.rank
    # hub -> [(u, s)] view, kept in sync
    D: List[Dict[int, int]] = [dict() for _ in range(h.m)]
    for u in range(h.n):
        for e, s in L[u].items():
            D[e][u] = s
    for root in [int(x) for x in idx.perm]:
        for u, s_u in sorted(D[root].items(), key=lambda t: -t[1]):
            if root not in L[u]:
                continue
            del L[u][root]
            needed = False
            for v, s_v in D[root].items():
                if v == u or root not in L[v]:
                    continue
                if mr_query_dicts(L[u], L[v], rank) < min(s_u, s_v):
                    needed = True
                    break
            if needed:
                L[u][root] = s_u
            else:
                del D[root][u]
    return _rebuild(idx, L)
