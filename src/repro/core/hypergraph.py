"""Hypergraph data structure: CSR-style incidence, generators, compaction.

The hypergraph H = (V, E) is stored as a dual CSR pair:
  * edge -> vertices  (``e_ptr`` / ``e_idx``): hyperedge membership lists
  * vertex -> edges   (``v_ptr`` / ``v_idx``): incidence lists E(u)

Vertex ids are ``0..n-1``, hyperedge ids ``0..m-1``.  All arrays are numpy
int32/int64; this structure is the host-side substrate consumed by the
paper's construction algorithms (Alg. 1-4) and exported to JAX as a dense
incidence matrix / line graph for the TPU engine (see ``to_incidence`` and
``line_graph``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

__all__ = [
    "Hypergraph",
    "NeighborCSR",
    "neighbor_csr",
    "from_edge_lists",
    "compact",
    "induced_subhypergraph",
    "apply_edge_edits",
    "random_hypergraph",
    "planted_chain_hypergraph",
    "colocation_hypergraph",
    "paper_figure1",
]


@dataclasses.dataclass(frozen=True)
class Hypergraph:
    """Immutable CSR hypergraph."""

    n: int                 # |V|
    m: int                 # |E|
    e_ptr: np.ndarray      # [m+1]  offsets into e_idx
    e_idx: np.ndarray      # [nnz]  vertex ids, sorted within each hyperedge
    v_ptr: np.ndarray      # [n+1]  offsets into v_idx
    v_idx: np.ndarray      # [nnz]  hyperedge ids, sorted within each vertex

    # -- basic accessors ---------------------------------------------------
    def edge(self, e: int) -> np.ndarray:
        """Vertices of hyperedge ``e`` (sorted)."""
        return self.e_idx[self.e_ptr[e]:self.e_ptr[e + 1]]

    def edges_of(self, u: int) -> np.ndarray:
        """E(u): hyperedges containing vertex ``u`` (sorted)."""
        return self.v_idx[self.v_ptr[u]:self.v_ptr[u + 1]]

    def edge_size(self, e: int) -> int:
        return int(self.e_ptr[e + 1] - self.e_ptr[e])

    def degree(self, u: int) -> int:
        return int(self.v_ptr[u + 1] - self.v_ptr[u])

    @property
    def nnz(self) -> int:
        return int(self.e_idx.shape[0])

    @property
    def edge_sizes(self) -> np.ndarray:
        return np.diff(self.e_ptr)

    @property
    def vertex_degrees(self) -> np.ndarray:
        return np.diff(self.v_ptr)

    @property
    def delta(self) -> int:
        """δ = max hyperedge size."""
        return int(self.edge_sizes.max()) if self.m else 0

    @property
    def d_max(self) -> int:
        """d = max vertex degree."""
        return int(self.vertex_degrees.max()) if self.n else 0

    # -- neighbor computation (the expensive primitive the paper optimizes)
    def neighbors_od(self, e: int) -> Tuple[np.ndarray, np.ndarray]:
        """N(e) with overlap degrees, computed on the fly in O(δ·d).

        Returns (neighbor_edge_ids, overlap_degrees), excluding ``e``.
        """
        counts: Dict[int, int] = {}
        for u in self.edge(e):
            for e2 in self.edges_of(int(u)):
                e2 = int(e2)
                if e2 != e:
                    counts[e2] = counts.get(e2, 0) + 1
        if not counts:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        nbrs = np.fromiter(counts.keys(), dtype=np.int64, count=len(counts))
        ods = np.fromiter(counts.values(), dtype=np.int64, count=len(counts))
        order = np.argsort(nbrs)
        return nbrs[order], ods[order]

    def overlap(self, e1: int, e2: int) -> int:
        """OD(e1, e2) = |e1 ∩ e2| via sorted-list intersection."""
        return int(np.intersect1d(self.edge(e1), self.edge(e2),
                                  assume_unique=True).size)

    # -- hyperedge importance order (Section V-A) --------------------------
    def importance_order(self) -> np.ndarray:
        """Total order O over hyperedges: rank[e] = position (0 = most
        important).  Weight w(e) = Σ_{v∈e} |E(v)|², ties by smaller id.
        """
        deg2 = self.vertex_degrees.astype(np.float64) ** 2
        w = np.zeros(self.m, np.float64)
        np.add.at(w, np.repeat(np.arange(self.m), self.edge_sizes), deg2[self.e_idx])
        # descending weight, ascending id on ties -> lexsort on (-w, id)
        perm = np.lexsort((np.arange(self.m), -w))    # perm[rank] = edge id
        rank = np.empty(self.m, np.int64)
        rank[perm] = np.arange(self.m)
        return rank

    # -- dense exports for the TPU engine ----------------------------------
    def to_incidence(self, dtype=np.float32) -> np.ndarray:
        """Dense incidence matrix B [m, n], B[e, v] = 1 iff v ∈ e."""
        B = np.zeros((self.m, self.n), dtype=dtype)
        B[np.repeat(np.arange(self.m), self.edge_sizes), self.e_idx] = 1
        return B

    def line_graph(self, dtype=np.int32) -> np.ndarray:
        """W [m, m]: W[i,j] = OD(e_i, e_j) for i≠j; W[i,i] = |e_i|.

        The diagonal |e_i| encodes the single-hyperedge walk (WOD({e}) =
        |e|, Sec. II), making W the correct (max,min)-semiring seed.
        """
        B = self.to_incidence(np.float32)
        W = (B @ B.T).astype(dtype)
        np.fill_diagonal(W, self.edge_sizes.astype(dtype))
        return W

    def stats(self) -> Dict[str, float]:
        return dict(n=self.n, m=self.m, nnz=self.nnz,
                    eta_avg=float(self.vertex_degrees.mean()) if self.n else 0.0,
                    eta_max=self.d_max, delta=self.delta)


# ---------------------------------------------------------------------------
# shared neighbor index (line-graph adjacency as one read-only CSR)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NeighborCSR:
    """The full line-graph adjacency ``N(e)`` with overlap degrees, as one
    read-only CSR — the shared neighbor index consumed by sharded HL-index
    construction (``repro.core.hlindex.build_sharded``).

    Per row the content is exactly ``Hypergraph.neighbors_od(e)``:
    neighbor hyperedge ids ascending, overlap degrees aligned — so a
    traversal reading rows from here is step-for-step identical to one
    computing neighborhoods on the fly, just without the O(δ·d) Python
    dict pass per hyperedge.
    """

    ptr: np.ndarray       # [m+1] int64 offsets
    idx: np.ndarray       # [L]   int64 neighbor ids, ascending per row
    od: np.ndarray        # [L]   int64 overlap degrees

    @property
    def m(self) -> int:
        return int(self.ptr.size - 1)

    def row(self, e: int) -> Tuple[np.ndarray, np.ndarray]:
        """``(neighbors, overlap_degrees)`` of hyperedge ``e`` — same
        content and order as ``Hypergraph.neighbors_od(e)``."""
        lo, hi = self.ptr[e], self.ptr[e + 1]
        return self.idx[lo:hi], self.od[lo:hi]

    def nbytes(self) -> int:
        return int(self.ptr.nbytes + self.idx.nbytes + self.od.nbytes)

    def components(self) -> np.ndarray:
        """[m] int64 line-graph component label per hyperedge; labels are
        assigned in ascending order of each component's smallest id, so
        the labeling is deterministic.

        Vectorized min-label propagation with pointer jumping (labels
        always point at a smaller id inside the same component, so
        ``l[l]`` is a legal shortcut): O(log diameter) rounds of pure
        numpy over the CSR — this runs serially on the sharded build's
        critical path before any parallelism starts, so no interpreted
        per-entry loop."""
        m = self.m
        if m == 0:
            return np.empty(0, np.int64)
        rows = np.repeat(np.arange(m), np.diff(self.ptr))
        labels = np.arange(m)
        while True:
            nb_min = np.full(m, m, np.int64)
            np.minimum.at(nb_min, rows, labels[self.idx])
            new = np.minimum(labels, nb_min)
            new = np.minimum(new, new[new])          # pointer jumping
            if np.array_equal(new, labels):
                break
            labels = new
        # converged: labels[e] == smallest id in e's component; compact
        # to 0..C-1 in ascending-smallest-id order
        _, inv = np.unique(labels, return_inverse=True)
        return inv.astype(np.int64)

    def induced(self, edge_ids: np.ndarray) -> "NeighborCSR":
        """The CSR restricted to ``edge_ids`` (sorted), with neighbor ids
        remapped to local positions.  ``edge_ids`` must be neighbor-closed
        (a union of whole line-graph components) — a neighbor outside the
        set raises ``ValueError``, which is the cover-check reconciliation
        guard of sharded construction: cover relations ride s-overlap
        walks, i.e. line-graph paths, so closure here is exactly what
        keeps per-shard MCD state equal to the serial builder's."""
        ids = np.asarray(edge_ids, np.int64)
        local = np.full(self.m, -1, np.int64)
        local[ids] = np.arange(ids.size)
        sizes = self.ptr[ids + 1] - self.ptr[ids]
        total = int(sizes.sum())
        ptr = np.zeros(ids.size + 1, np.int64)
        np.cumsum(sizes, out=ptr[1:])
        if total == 0:
            return NeighborCSR(ptr, np.empty(0, np.int64),
                               np.empty(0, np.int64))
        take = (np.repeat(self.ptr[ids], sizes)
                + np.arange(total) - np.repeat(ptr[:-1], sizes))
        lidx = local[self.idx[take]]
        if (lidx < 0).any():
            bad = int(self.idx[take][lidx < 0][0])
            raise ValueError(
                f"edge_ids is not neighbor-closed: hyperedge {bad} is a "
                f"line-graph neighbor of the set but not in it")
        return NeighborCSR(ptr, lidx, self.od[take])

    def updated(self, new_h: Hypergraph, old_to_new: np.ndarray,
                touched: np.ndarray) -> "NeighborCSR":
        """The CSR for ``new_h`` after an ``apply_edge_edits`` step, built
        by a 1-hop patch instead of a fresh O(Σd²) pair pass.

        ``old_to_new``/``touched`` are the extra outputs of
        ``apply_edge_edits``.  An untouched surviving hyperedge has, by
        construction of the 1-hop set, no deleted or inserted neighbors
        and unchanged overlap degrees, so its row is the old row with ids
        remapped — and since ``old_to_new`` is monotone on survivors, the
        remap preserves the ascending neighbor order.  Touched rows are
        recomputed from ``new_h.neighbors_od``, which is what a fresh
        ``neighbor_csr(new_h)`` holds for them; the result is therefore
        byte-identical to a fresh build (asserted in tests).
        """
        m_new = new_h.m
        if m_new == 0:
            return NeighborCSR(np.zeros(1, np.int64),
                               np.empty(0, np.int64), np.empty(0, np.int64))
        touched = np.asarray(touched, np.int64)
        tmask = np.zeros(m_new, bool)
        tmask[touched] = True
        surv = np.nonzero(np.asarray(old_to_new, np.int64) >= 0)[0]
        keep_old = surv[~tmask[old_to_new[surv]]]
        fresh = [new_h.neighbors_od(int(t)) for t in touched]
        counts = np.zeros(m_new, np.int64)
        sizes = self.ptr[keep_old + 1] - self.ptr[keep_old]
        counts[old_to_new[keep_old]] = sizes
        counts[touched] = [nb.size for nb, _ in fresh]
        ptr = np.zeros(m_new + 1, np.int64)
        np.cumsum(counts, out=ptr[1:])
        idx = np.empty(int(ptr[-1]), np.int64)
        od = np.empty(int(ptr[-1]), np.int64)
        if keep_old.size and int(sizes.sum()):
            off = np.cumsum(sizes) - sizes
            span = np.arange(int(sizes.sum()))
            take = np.repeat(self.ptr[keep_old], sizes) + span \
                - np.repeat(off, sizes)
            dest = np.repeat(ptr[old_to_new[keep_old]], sizes) + span \
                - np.repeat(off, sizes)
            idx[dest] = old_to_new[self.idx[take]]
            od[dest] = self.od[take]
        for t, (nb, w) in zip(touched, fresh):
            lo = ptr[int(t)]
            idx[lo:lo + nb.size] = nb
            od[lo:lo + nb.size] = w
        return NeighborCSR(ptr, idx, od)


def _mesh_overlap_matrix(h: Hypergraph, mesh) -> np.ndarray:
    """Dense pairwise-overlap matrix |e_i ∩ e_j| computed on a device
    mesh: incidence rows block-sharded over every mesh axis, one sharded
    matmul, result pulled back for CSR extraction.  f32 products are
    exact (overlaps ≤ δ ≪ 2^24)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    nd = int(mesh.devices.size)
    b = h.to_incidence(np.float32)
    pad = (-h.m) % nd
    if pad:
        b = np.pad(b, ((0, pad), (0, 0)))
    spec = NamedSharding(mesh, PartitionSpec(tuple(mesh.axis_names), None))
    bd = jax.device_put(b, spec)
    w = jax.jit(lambda x: x @ x.T, out_shardings=spec)(bd)
    return np.asarray(w).astype(np.int64)[:h.m, :h.m]


def neighbor_csr(h: Hypergraph, *, mesh=None) -> NeighborCSR:
    """All line-graph neighborhoods at once, as a shared ``NeighborCSR``.

    Row content is byte-identical to ``h.neighbors_od(e)`` for every
    ``e`` (asserted in tests) — this is the precomputed neighbor index
    that lets HL-index construction drop its per-hyperedge O(δ·d) host
    dict pass (``repro.core.hlindex``, Lemma 6 regime).

    Two paths, same output:
      * host (default): every ordered co-incidence pair ``(e1, e2)``
        sharing a vertex is generated in one vectorized pass and
        deduplicated with counts — O(Σ d_u²) memory, no dense [m, m].
      * ``mesh`` with more than one device: the O(m²·n̄) overlap products
        run on the mesh (incidence rows sharded over every axis, one
        sharded matmul) and only the CSR extraction stays on host — the
        device-resident route sharded construction uses.
    """
    m = h.m
    empty = NeighborCSR(np.zeros(max(m, 0) + 1, np.int64),
                        np.empty(0, np.int64), np.empty(0, np.int64))
    if m == 0 or h.nnz == 0:
        return empty
    if mesh is not None and int(mesh.devices.size) > 1:
        w = _mesh_overlap_matrix(h, mesh)
        np.fill_diagonal(w, 0)
        rows, cols = np.nonzero(w)            # row-major: ascending per row
        od = w[rows, cols]
        counts = np.bincount(rows, minlength=m)
        ptr = np.zeros(m + 1, np.int64)
        np.cumsum(counts, out=ptr[1:])
        return NeighborCSR(ptr, cols.astype(np.int64), od.astype(np.int64))
    deg = h.vertex_degrees
    pair_counts = deg * deg
    total = int(pair_counts.sum())
    if total == 0:
        return empty
    # within vertex u's block of d² ordered pairs, entry k is
    # (E(u)[k // d], E(u)[k % d]); all blocks emitted in one shot
    starts = np.cumsum(pair_counts) - pair_counts
    pos = np.arange(total) - np.repeat(starts, pair_counts)
    du = np.repeat(deg, pair_counts)
    vstart = np.repeat(h.v_ptr[:-1], pair_counts)
    a = h.v_idx[vstart + pos // du]
    b = h.v_idx[vstart + pos % du]
    mask = a != b
    key = a[mask] * np.int64(m) + b[mask]
    uniq, counts = np.unique(key, return_counts=True)
    rows = uniq // m
    cols = uniq % m
    row_counts = np.bincount(rows, minlength=m)
    ptr = np.zeros(m + 1, np.int64)
    np.cumsum(row_counts, out=ptr[1:])
    return NeighborCSR(ptr, cols.astype(np.int64), counts.astype(np.int64))


# ---------------------------------------------------------------------------
# construction helpers
# ---------------------------------------------------------------------------

def from_edge_lists(edges: Sequence[Iterable[int]], n: int | None = None) -> Hypergraph:
    """Build a Hypergraph from an iterable of vertex iterables.

    Empty hyperedges are dropped; duplicate vertices within a hyperedge are
    deduplicated; vertex lists are sorted.
    """
    cleaned: List[np.ndarray] = []
    for ed in edges:
        arr = np.unique(np.asarray(list(ed), dtype=np.int64))
        if arr.size:
            cleaned.append(arr)
    m = len(cleaned)
    if n is None:
        n = int(max((a.max() for a in cleaned), default=-1)) + 1
    sizes = np.array([a.size for a in cleaned], np.int64)
    e_ptr = np.zeros(m + 1, np.int64)
    np.cumsum(sizes, out=e_ptr[1:])
    e_idx = (np.concatenate(cleaned) if m else np.empty(0, np.int64))

    # invert to vertex -> edges
    order = np.argsort(e_idx, kind="stable")
    v_sorted = e_idx[order]
    eid = np.repeat(np.arange(m, dtype=np.int64), sizes)[order]
    v_ptr = np.zeros(n + 1, np.int64)
    np.add.at(v_ptr, v_sorted + 1, 1)
    np.cumsum(v_ptr, out=v_ptr)
    return Hypergraph(n=n, m=m, e_ptr=e_ptr, e_idx=e_idx, v_ptr=v_ptr, v_idx=eid)


def compact(h: Hypergraph) -> Tuple[Hypergraph, np.ndarray]:
    """Graph compaction (paper Appendix B style): remove hyperedges that are
    exact duplicates of another hyperedge (identical vertex sets).  Duplicate
    hyperedges contribute no new reachability: OD(e, dup(e)) = |e| and both
    have identical neighborhoods, so any walk through the duplicate can be
    rerouted through the representative with equal WOD.

    Returns (compacted graph, representative_map [m] mapping old edge id to
    kept edge id in the *original* id space).
    """
    seen: Dict[bytes, int] = {}
    keep: List[int] = []
    rep = np.empty(h.m, np.int64)
    for e in range(h.m):
        key = h.edge(e).tobytes()
        if key in seen:
            rep[e] = seen[key]
        else:
            seen[key] = e
            rep[e] = e
            keep.append(e)
    if len(keep) == h.m:
        return h, rep
    g = from_edge_lists([h.edge(e) for e in keep], n=h.n)
    return g, rep


def induced_subhypergraph(h: Hypergraph, edge_ids: Sequence[int]
                          ) -> Tuple[Hypergraph, np.ndarray]:
    """Sub-hypergraph induced by ``edge_ids`` with compacted vertex ids.

    Local hyperedge ``i`` is global ``edge_ids[i]`` (callers should pass
    sorted ids so local order mirrors global order); local vertex ``j``
    is global ``verts[j]``.  Returns ``(sub, verts)``.

    When ``edge_ids`` is a union of whole line-graph components, every
    hyperedge incident to an extracted vertex is itself extracted, so
    vertex degrees — and therefore the importance order — inside the
    sub-hypergraph coincide with the global ones restricted to it.  This
    is the extraction primitive behind scoped index maintenance
    (``repro.core.maintenance``).
    """
    ids = np.asarray(list(edge_ids), np.int64)
    if ids.size == 0:
        return from_edge_lists([], n=0), np.empty(0, np.int64)
    sizes = h.e_ptr[ids + 1] - h.e_ptr[ids]
    flat = h.e_idx[np.concatenate([np.arange(h.e_ptr[e], h.e_ptr[e + 1])
                                   for e in ids])]
    verts, local = np.unique(flat, return_inverse=True)
    e_ptr = np.zeros(ids.size + 1, np.int64)
    np.cumsum(sizes, out=e_ptr[1:])
    edges = [local[e_ptr[i]:e_ptr[i + 1]] for i in range(ids.size)]
    return from_edge_lists(edges, n=int(verts.size)), verts


def apply_edge_edits(h: Hypergraph, inserts: Sequence[Iterable[int]] = (),
                     deletes: Sequence[int] = ()
                     ) -> Tuple[Hypergraph, np.ndarray, np.ndarray]:
    """Apply hyperedge deletions then insertions; the pure graph edit
    shared by index maintenance and every engine's ``update`` path.

    Surviving hyperedges keep their relative order (ids compacted),
    inserted hyperedges are appended in argument order.  Vertex ids are
    never renumbered; inserting vertices beyond ``h.n`` grows ``n``.

    Returns ``(new_h, old_to_new, touched)``:
      * ``old_to_new`` [m_old] int64 — new id of each old hyperedge,
        -1 for deleted ones;
      * ``touched`` — sorted new ids of hyperedges whose line-graph
        neighborhood may have changed: the inserted hyperedges, their
        neighbors, and the surviving neighbors of deleted hyperedges.
        (Adjacency caches only need refreshing on this 1-hop set; index
        maintenance expands it to whole components.)

    Cost is O(nnz) vectorized: surviving hyperedges are already clean
    (sorted, deduplicated), so the edited CSR is assembled by masked
    copies — no per-hyperedge re-cleaning.
    """
    del_set = {int(d) for d in deletes}
    for d in del_set:
        if not 0 <= d < h.m:
            raise IndexError(f"delete of hyperedge {d} out of range "
                             f"[0, {h.m})")
    cleaned_inserts: List[np.ndarray] = []
    for ed in inserts:
        arr = np.unique(np.asarray(list(ed), dtype=np.int64))
        if arr.size == 0:
            continue                       # empty hyperedges never exist
        if arr.min() < 0:
            raise IndexError(f"insert with negative vertex id {arr.min()}")
        cleaned_inserts.append(arr)

    keep_mask = np.ones(h.m, bool)
    keep_mask[list(del_set)] = False
    old_to_new = np.where(keep_mask, np.cumsum(keep_mask) - 1, -1)
    sizes = h.edge_sizes
    kept_sizes = sizes[keep_mask]
    kept_idx = h.e_idx[np.repeat(keep_mask, sizes)]
    first_insert_id = int(kept_sizes.size)

    ins_sizes = np.array([a.size for a in cleaned_inserts], np.int64)
    all_sizes = np.concatenate([kept_sizes, ins_sizes])
    m_new = int(all_sizes.size)
    e_ptr = np.zeros(m_new + 1, np.int64)
    np.cumsum(all_sizes, out=e_ptr[1:])
    e_idx = np.concatenate([kept_idx] + cleaned_inserts) \
        if m_new else np.empty(0, np.int64)
    n_new = h.n
    if cleaned_inserts:
        n_new = max(n_new, int(max(a.max() for a in cleaned_inserts)) + 1)
    # invert to vertex -> edges (same construction as from_edge_lists)
    order = np.argsort(e_idx, kind="stable")
    v_sorted = e_idx[order]
    eid = np.repeat(np.arange(m_new, dtype=np.int64), all_sizes)[order]
    v_ptr = np.zeros(n_new + 1, np.int64)
    np.add.at(v_ptr, v_sorted + 1, 1)
    np.cumsum(v_ptr, out=v_ptr)
    new_h = Hypergraph(n=n_new, m=m_new, e_ptr=e_ptr, e_idx=e_idx,
                       v_ptr=v_ptr, v_idx=eid)

    touched = set(range(first_insert_id, new_h.m))
    for t in list(touched):
        nb, _ = new_h.neighbors_od(t)
        touched.update(int(e) for e in nb)
    for d in del_set:
        nb, _ = h.neighbors_od(d)
        for e in nb:
            e_new = int(old_to_new[int(e)])
            if e_new >= 0:
                touched.add(e_new)
    return new_h, old_to_new, np.fromiter(sorted(touched), np.int64,
                                          len(touched))


# ---------------------------------------------------------------------------
# generators (tests / benchmarks / case study)
# ---------------------------------------------------------------------------

def random_hypergraph(n: int, m: int, *, min_size: int = 2, max_size: int = 6,
                      seed: int = 0) -> Hypergraph:
    """Uniform random hypergraph: each hyperedge samples its size then its
    vertices without replacement.  Mirrors the paper's synthetic workloads.
    """
    rng = np.random.default_rng(seed)
    edges = []
    for _ in range(m):
        k = int(rng.integers(min_size, max_size + 1))
        k = min(k, n)
        edges.append(rng.choice(n, size=k, replace=False))
    return from_edge_lists(edges, n=n)


def planted_chain_hypergraph(n_chains: int, chain_len: int, overlap: int,
                             extra_size: int = 2, seed: int = 0) -> Hypergraph:
    """Chains of hyperedges with a planted overlap s — ground-truth MR along
    each chain is exactly ``overlap`` (plus |e| on the diagonal), used by
    property tests to pin known answers.
    """
    rng = np.random.default_rng(seed)
    edges = []
    base = 0
    for _ in range(n_chains):
        prev = [base + i for i in range(overlap + extra_size)]
        base += len(prev)
        edges.append(list(prev))
        for _ in range(chain_len - 1):
            shared = prev[-overlap:]
            fresh = [base + i for i in range(extra_size)]
            base += extra_size
            cur = shared + fresh
            edges.append(cur)
            prev = cur
    _ = rng  # reserved for future noise injection
    return from_edge_lists(edges)


def colocation_hypergraph(n_people: int, n_places: int, n_days: int,
                          p_checkin: float = 0.02, seed: int = 0) -> Hypergraph:
    """BrightKite-style co-location hypergraph for the epidemic case study
    (Exp-5): one hyperedge per (place, day) = set of people checked in.
    """
    rng = np.random.default_rng(seed)
    edges = []
    for _ in range(n_places * n_days):
        mask = rng.random(n_people) < p_checkin
        people = np.nonzero(mask)[0]
        if people.size >= 2:
            edges.append(people)
    return from_edge_lists(edges, n=n_people)


def paper_figure1() -> Hypergraph:
    """The running example of the paper (Figure 1).

    Reconstructed to satisfy every worked example in the text:
      * e2 and e5 share {v5, v6}; e5 ∩ e3 = {v10}               (Example 2)
      * {e2, e6} is a 2-walk joining v5 and v9; no 3-walk        (Example 1)
      * v1 reaches v10 via {e7, e2, e5} with WOD 2               (Example 3)
      * OD(e7, e4) = 2, |e7| = 3, |e4| = 4, |e1| = 2             (Examples 4/5)
      * Table II: |e2| = 6, (v9: e3@3, e6@3), (v10: e5@3, e3@3),
        OD(e2,e6) = 2, OD(e2,e4) = 2, OD(e2,e1) = 2, OD(e2,e7) = 3 …

    Vertex ids are v1..v12 -> 0..11; hyperedge ids e1..e7 -> 0..6.
    """
    e = {
        1: [1, 2],                  # e1 = {v1, v2}
        2: [3, 4, 5, 6, 7, 8],      # e2 = {v3..v8}
        3: [9, 10, 12],             # e3 = {v9, v10, v12}
        4: [3, 4, 11, 12],          # e4 = {v3, v4, v11, v12}
        5: [5, 6, 10],              # e5 = {v5, v6, v10}
        6: [7, 8, 9],               # e6 = {v7, v8, v9}
        7: [1, 3, 4],               # e7 = {v1, v3, v4}
    }
    return from_edge_lists([[v - 1 for v in e[i]] for i in range(1, 8)], n=12)
