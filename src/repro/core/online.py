"""Algorithm 1: online bidirectional priority-based max-reachability search.

Faithful to the paper's pseudocode: two max-priority queues seeded with
(e, |e|) for hyperedges incident to each endpoint (Corollary 1), phase
alternation via ``switch``, meeting-point result update, and the two
pruning rules (line 10: dominated revisit; line 16: OD ≤ current result).

``Base`` computes neighbors on the fly (O(δd) each); ``Base*`` (the paper's
starred variant) reuses a precomputed neighbor adjacency.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from .hypergraph import Hypergraph

__all__ = ["mr_online", "precompute_neighbors", "NeighborCache"]


class NeighborCache:
    """Optional precomputed neighbor lists (the paper's Base* / adjacency N).

    Memory O(Σ|N(e)|) — the expensive structure the neighbor-index M of
    Alg. 3 is designed to avoid during construction; for *queries* it is a
    straightforward time/space trade.
    """

    def __init__(self, h: Hypergraph,
                 _lists: Optional[Tuple[List[np.ndarray],
                                        List[np.ndarray]]] = None):
        if _lists is not None:
            self.nbrs, self.ods = _lists
            return
        self.nbrs: List[np.ndarray] = []
        self.ods: List[np.ndarray] = []
        for e in range(h.m):
            nb, od = h.neighbors_od(e)
            self.nbrs.append(nb)
            self.ods.append(od)

    def __call__(self, e: int) -> Tuple[np.ndarray, np.ndarray]:
        return self.nbrs[e], self.ods[e]

    def updated(self, new_h: Hypergraph, old_to_new: np.ndarray,
                touched) -> "NeighborCache":
        """Cache for the edited graph: only hyperedges in ``touched`` (new
        ids — see ``apply_edge_edits``) recompute their neighbor lists;
        every other surviving hyperedge keeps its lists with ids remapped.
        An untouched hyperedge never neighbors a deleted one (neighbors of
        deleted hyperedges are by definition touched), so the remap is
        total on kept lists."""
        touched_set = {int(t) for t in touched}
        old_of = np.full(new_h.m, -1, np.int64)
        kept = np.nonzero(old_to_new >= 0)[0]
        old_of[old_to_new[kept]] = kept
        nbrs: List[np.ndarray] = []
        ods: List[np.ndarray] = []
        for e in range(new_h.m):
            e_old = int(old_of[e])
            if e in touched_set or e_old < 0:
                nb, od = new_h.neighbors_od(e)
            else:
                # old_to_new is strictly increasing on survivors, so the
                # remapped list keeps the sorted-id invariant as-is
                nb = old_to_new[self.nbrs[e_old]]
                od = self.ods[e_old]
            nbrs.append(nb)
            ods.append(od)
        return NeighborCache(new_h, _lists=(nbrs, ods))

    def nbytes(self) -> int:
        return sum(a.nbytes + b.nbytes for a, b in zip(self.nbrs, self.ods))


def precompute_neighbors(h: Hypergraph) -> NeighborCache:
    return NeighborCache(h)


def mr_online(h: Hypergraph, u: int, v: int,
              neighbors: Optional[NeighborCache] = None) -> int:
    """MR(u, v) via Algorithm 1.  Returns 0 if not reachable."""
    get_nbrs = neighbors if neighbors is not None else h.neighbors_od

    visit_in: Dict[int, int] = {}
    visit_out: Dict[int, int] = {}
    q_in: List[Tuple[int, int]] = []   # max-heap via negated s
    q_out: List[Tuple[int, int]] = []
    result = 0

    for e in h.edges_of(u):
        heapq.heappush(q_out, (-h.edge_size(int(e)), int(e)))
    for e in h.edges_of(v):
        heapq.heappush(q_in, (-h.edge_size(int(e)), int(e)))

    def run_phase(q_same, visit_same, visit_other) -> int:
        """Process one phase (current queue contents) of one direction."""
        nonlocal result
        for _ in range(len(q_same)):
            if not q_same:
                break
            neg_s, e = heapq.heappop(q_same)
            s = -neg_s
            if s <= visit_same.get(e, -1):           # line 10
                continue
            visit_same[e] = s                        # line 11
            so = visit_other.get(e, -1)
            if so > result:                          # lines 12-14
                result = max(result, min(s, so))
                continue
            nb, od = get_nbrs(e)
            for e2, w in zip(nb, od):                # lines 15-17
                w = int(w)
                if w <= result:                      # line 16
                    continue
                ns = min(s, w)
                e2 = int(e2)
                if ns <= visit_same.get(e2, -1):
                    continue
                heapq.heappush(q_same, (-ns, e2))
        return result

    switch = 0
    while q_in or q_out:
        if switch == 0:
            run_phase(q_in, visit_in, visit_out)
            switch = 1
        else:
            run_phase(q_out, visit_out, visit_in)
            switch = 0
    return result
