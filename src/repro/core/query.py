"""Query processing (Section VI): Algorithm 5 + the JAX batched engine.

``mr_query`` is the faithful merge-join (labels sorted ascending by
importance rank; advance the pointer holding the more-important hub; skip
entries whose s cannot improve the running answer).

``batched_mr`` is the TPU-native serving path: labels exported as padded
dense tensors (``HLIndex.as_padded``), queries answered by a vectorized
``searchsorted`` join — every query costs O(Lmax log Lmax) of pure VPU
work with no host pointer chasing, and a [Q]-sized batch is one fused XLA
program.  This is the engine the paper's Exp-1 (1,000-query workload)
maps onto; it serves millions of queries per batch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hlindex import HLIndex

__all__ = ["mr_query", "s_reach_query", "mr_query_dicts", "DeviceSnapshot",
           "KernelSnapshot", "PaddedIndex", "batched_mr"]


def mr_query(idx: HLIndex, u: int, v: int) -> int:
    """Algorithm 5: MR(u, v) from two sorted label lists."""
    ru, su = idx.labels_rank[u], idx.labels_s[u]
    rv, sv = idx.labels_rank[v], idx.labels_s[v]
    i = j = 0
    k = 0
    while i < ru.size and j < rv.size:
        if su[i] <= k or ru[i] < rv[j]:      # line 5
            i += 1
        elif sv[j] <= k or ru[i] > rv[j]:    # line 6
            j += 1
        else:                                # line 7: common hub, both s > k
            k = int(min(su[i], sv[j]))
            i += 1
            j += 1
    return k


def s_reach_query(idx: HLIndex, u: int, v: int, s: int) -> bool:
    """Problem 1 via the Section-VI modification: seed k = s-1; true on the
    first common-hub hit (early exit)."""
    ru, su = idx.labels_rank[u], idx.labels_s[u]
    rv, sv = idx.labels_rank[v], idx.labels_s[v]
    i = j = 0
    k = s - 1
    while i < ru.size and j < rv.size:
        if su[i] <= k or ru[i] < rv[j]:
            i += 1
        elif sv[j] <= k or ru[i] > rv[j]:
            j += 1
        else:
            return True
    return False


def mr_query_dicts(lu: Dict[int, int], lv: Dict[int, int],
                   rank: np.ndarray) -> int:
    """MR from dict-form labels (used by the minimization passes)."""
    if len(lu) > len(lv):
        lu, lv = lv, lu
    best = 0
    for e, s in lu.items():
        s2 = lv.get(e)
        if s2 is not None:
            m = min(s, s2)
            if m > best:
                best = m
    return best


# ---------------------------------------------------------------------------
# JAX batched engine
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _mesh_row_scatter(spec2d, spec1d, donate: bool):
    """Jitted dirty-row scatter for ``to_mesh(base=, dirty_rows=)``,
    cached per (sharding pair, donation) so periodic same-shaped
    snapshot refreshes reuse one compiled program instead of re-tracing
    every time (``NamedSharding`` is hashable, so the shardings are the
    cache key; shapes key jax's own jit cache underneath).  With
    ``donate`` the base tensors are donated to XLA, so the patch updates
    in place instead of allocating a second full label-mass copy."""
    @functools.partial(jax.jit, out_shardings=(spec2d, spec2d, spec1d),
                       donate_argnums=(0, 1, 2) if donate else ())
    def scatter(ranks, svals, lengths, idx, new_r, new_s, new_l):
        return (ranks.at[idx].set(new_r),
                svals.at[idx].set(new_s),
                lengths.at[idx].set(new_l))
    return scatter


@dataclasses.dataclass(eq=False)    # identity equality/hash: fields are arrays
class DeviceSnapshot:
    """Padded per-vertex label tensors on device, served by ``batched_mr``.

    Tensor layout and sentinel conventions:

    * ``ranks`` [n, Lmax] int32 — per-row **ascending** hub keys; rows
      shorter than Lmax are padded with ``INT32_MAX`` (2^31 - 1).  The
      padding sentinel can never equal a real hub key, so a padding slot
      only ever "matches" another padding slot — and then contributes
      ``min(0, 0) = 0`` to the join max, i.e. nothing.
    * ``svals`` [n, Lmax] int32 — the s-value carried by each label;
      padding slots hold 0 (0 = "no s-walk", the identity of the max).
    * ``lengths`` [n] int32 — true label counts per row (metadata for
      size accounting; the join itself relies only on the sentinels).

    The row key space only needs to be consistent across rows (hub
    importance rank for the HL-index/ETE backends, raw hyperedge id for
    the dense/sharded closures) — this is the one device-resident serving
    form every label-shaped backend of ``repro.core.engine`` exports.

    ``to_mesh`` re-lands the same tensors sharded over a device mesh via
    ``NamedSharding``, so one snapshot can outlive (and serve) any number
    of query batches on a multi-device topology.

    ``version`` records the engine version the snapshot was derived from
    (see ``ReachabilityEngine.update``): after an update, the engine's
    ``snapshot()`` re-derives a fresh snapshot with the bumped version,
    while previously handed-out snapshots keep their old version — a
    snapshot with ``snap.version != engine.version`` is stale.
    ``to_mesh`` propagates the version, so resharded copies stay
    comparable.

    Snapshots are immutable; incremental refresh produces *new* snapshots
    that reuse the old tensors: ``patch_rows`` replaces only the label
    rows a scoped update touched (the ``UpdateReport.refreshed_vertices``
    contract from ``repro.core.maintenance``), and ``to_mesh(base=...,
    dirty_rows=...)`` re-lands only those rows into an already
    mesh-resident copy instead of re-transferring the whole label mass.
    """

    ranks: jnp.ndarray
    svals: jnp.ndarray
    lengths: jnp.ndarray
    backend: str = "hl-index"
    version: int = 0

    @classmethod
    def from_padded(cls, ranks, svals, lengths, backend: str,
                    version: int = 0) -> "DeviceSnapshot":
        return cls(ranks=jnp.asarray(ranks), svals=jnp.asarray(svals),
                   lengths=jnp.asarray(lengths), backend=backend,
                   version=version)

    @classmethod
    def from_hlindex(cls, idx: HLIndex, backend: str = "hl-index",
                     version: int = 0) -> "DeviceSnapshot":
        ranks, svals, lengths = idx.as_padded()
        return cls.from_padded(ranks, svals, lengths, backend, version)

    def to_mesh(self, mesh, axes: Optional[Tuple[str, str]] = None, *,
                base: Optional["DeviceSnapshot"] = None,
                dirty_rows=None,
                donate_base: bool = False) -> "DeviceSnapshot":
        """Return this snapshot sharded over ``mesh`` via ``NamedSharding``:
        vertex rows split along ``axes[0]``, label columns along
        ``axes[1]`` (``lengths`` along ``axes[0]`` only).  ``axes=None``
        uses the mesh's last two axis names, so any axis naming works.

        Rows/columns are padded up to mesh-divisible sizes with the usual
        sentinels (ranks ``INT32_MAX``, svals 0), which are inert under
        the join — so the sharded snapshot answers identically.  The
        returned snapshot is committed to the mesh's devices and persists
        there across query batches; ``batched_mr`` consumes it directly
        (GSPMD partitions the gather + join).

        ``base`` + ``dirty_rows`` is the incremental re-land path used by
        the serving layer after a scoped update: when ``base`` is a
        previously ``to_mesh``-ed copy whose padded geometry matches this
        snapshot's, only the ``dirty_rows`` label rows are transferred and
        scattered into the resident tensors (everything else of ``base``
        is byte-identical by the ``UpdateReport`` contract).  On a
        geometry change (label width or vertex count re-padded
        differently) it falls back to a full re-land — answers are
        identical either way, only the transfer volume differs.
        ``donate_base`` additionally donates ``base``'s buffers to the
        scatter so the patch is in place (no transient second copy of
        the label mass) — ``base`` must not be used afterwards.  Ignored
        on CPU devices, where XLA cannot donate.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P
        if axes is None:
            axes = tuple(mesh.axis_names[-2:])
        if len(axes) < 2:
            raise ValueError(
                f"to_mesh needs two mesh axes (rows, label columns); the "
                f"mesh has axis names {mesh.axis_names}")
        row_ax, col_ax = axes
        r, c = mesh.shape[row_ax], mesh.shape[col_ax]
        n, lmax = self.ranks.shape
        n_pad = -(-n // r) * r if n else 0
        l_pad = -(-lmax // c) * c if lmax else 0
        spec2d = NamedSharding(mesh, P(row_ax, col_ax))
        spec1d = NamedSharding(mesh, P(row_ax))
        if (base is not None and dirty_rows is not None
                and tuple(base.ranks.shape) == (n_pad, l_pad)):
            rows = np.asarray(dirty_rows, np.int64)
            pr = np.full((rows.size, l_pad), np.iinfo(np.int32).max,
                         np.int32)
            ps = np.zeros((rows.size, l_pad), np.int32)
            pl = np.zeros(rows.size, np.int32)
            pr[:, :lmax] = np.asarray(self.ranks)[rows]
            ps[:, :lmax] = np.asarray(self.svals)[rows]
            pl[:] = np.asarray(self.lengths)[rows]
            donate = donate_base and all(
                d.platform != "cpu" for d in mesh.devices.flat)
            ranks, svals, lengths = _mesh_row_scatter(spec2d, spec1d,
                                                      donate)(
                base.ranks, base.svals, base.lengths,
                jnp.asarray(rows, jnp.int32), pr, ps, pl)
            return DeviceSnapshot(ranks=ranks, svals=svals, lengths=lengths,
                                  backend=self.backend, version=self.version)
        ranks = np.full((n_pad, l_pad), np.iinfo(np.int32).max, np.int32)
        svals = np.zeros((n_pad, l_pad), np.int32)
        lengths = np.zeros(n_pad, np.int32)
        ranks[:n, :lmax] = np.asarray(self.ranks)
        svals[:n, :lmax] = np.asarray(self.svals)
        lengths[:n] = np.asarray(self.lengths)
        return DeviceSnapshot(
            ranks=jax.device_put(ranks, spec2d),
            svals=jax.device_put(svals, spec2d),
            lengths=jax.device_put(lengths, spec1d),
            backend=self.backend, version=self.version)

    def patch_rows(self, rows, row_ranks, row_svals, row_lengths, *,
                   n: Optional[int] = None, lmax: Optional[int] = None,
                   version: Optional[int] = None,
                   backend: Optional[str] = None) -> "DeviceSnapshot":
        """A new snapshot with only ``rows`` replaced — the label-row
        re-derivation primitive behind snapshot caching across updates.

        ``row_ranks`` / ``row_svals`` are [len(rows), lmax] padded rows
        (``pad_label_rows(..., pad_to=lmax)`` form), ``row_lengths`` the
        true counts.  ``n`` / ``lmax`` resize the tensors first (rows
        appended with empty sentinel rows, columns padded with sentinels
        or sliced off) — legal because a clean row's content never
        exceeds the new ``lmax`` by the dirty-rows contract, so resizing
        touches only inert padding.  The result is byte-identical to a
        from-scratch derivation in which only ``rows`` changed; every
        untouched row is reused from this snapshot's device tensors
        without re-transfer.
        """
        ranks, svals, lengths = self.ranks, self.svals, self.lengths
        cur_n, cur_l = ranks.shape
        n = cur_n if n is None else int(n)
        lmax = cur_l if lmax is None else int(lmax)
        sentinel = np.iinfo(np.int32).max
        if lmax > cur_l:
            ranks = jnp.pad(ranks, ((0, 0), (0, lmax - cur_l)),
                            constant_values=sentinel)
            svals = jnp.pad(svals, ((0, 0), (0, lmax - cur_l)))
        elif lmax < cur_l:
            ranks = ranks[:, :lmax]
            svals = svals[:, :lmax]
        if n > cur_n:
            ranks = jnp.pad(ranks, ((0, n - cur_n), (0, 0)),
                            constant_values=sentinel)
            svals = jnp.pad(svals, ((0, n - cur_n), (0, 0)))
            lengths = jnp.pad(lengths, (0, n - cur_n))
        rows = jnp.asarray(np.asarray(rows, np.int64), jnp.int32)
        if rows.size:
            ranks = ranks.at[rows].set(jnp.asarray(row_ranks, jnp.int32))
            svals = svals.at[rows].set(jnp.asarray(row_svals, jnp.int32))
            lengths = lengths.at[rows].set(
                jnp.asarray(row_lengths, jnp.int32))
        return DeviceSnapshot(
            ranks=ranks, svals=svals, lengths=lengths,
            backend=self.backend if backend is None else backend,
            version=self.version if version is None else int(version))

    @property
    def lmax(self) -> int:
        return int(self.ranks.shape[1])

    def nbytes(self) -> int:
        return int(self.ranks.nbytes + self.svals.nbytes
                   + self.lengths.nbytes)

    def mr(self, us, vs) -> jnp.ndarray:
        us = jnp.asarray(us)
        if self.lmax == 0:          # no labels anywhere: nothing is reachable
            return jnp.zeros(us.shape, jnp.int32)
        return batched_mr(self.ranks, self.svals, us, jnp.asarray(vs))

    def s_reach(self, us, vs, s: int) -> jnp.ndarray:
        return self.mr(us, vs) >= s


@functools.partial(jax.jit, donate_argnums=())
def _gather_rows(ranks, svals, us, vs):
    return ranks[us], svals[us], ranks[vs], svals[vs]


class KernelSnapshot:
    """Kernel-path query view over a ``DeviceSnapshot``.

    Answers ``mr`` / ``s_reach`` batches through the Pallas
    ``label_join`` kernel instead of the host merge-join or the XLA
    ``batched_mr`` program: query rows are gathered from the resident
    label tensors on device, the batch is padded up to a power-of-two
    bucket (the same admission-bucket policy ``ReachabilityService``
    uses, so serving traffic compiles one kernel program per bucket
    shape, not per batch size), and the [bucket, Lmax] rows feed
    ``label_join_pallas``.  Memory stays label-mass: the view holds no
    tensors of its own beyond the wrapped snapshot.

    The wrapped ``base`` snapshot keeps its identity — patch/re-land
    plumbing (``patch_rows``, ``to_mesh(base=...)``) operates on the
    underlying ``DeviceSnapshot`` and the view is rebuilt around the
    result, which is why this is composition rather than subclassing.

    ``interpret=None`` resolves the Pallas execution mode from the host
    (``use_interpret()``): compiled on TPU, interpreter elsewhere —
    the automatic fallback behind the ``use_kernels=`` engine flag.
    Construction validates the rank key space against the kernel's
    padding sentinels once (``validate_ranks``), so per-batch calls
    don't pay the check.
    """

    def __init__(self, base: DeviceSnapshot, *, bq: int = 128,
                 bl: int = 256, min_bucket: int = 8,
                 interpret: Optional[bool] = None):
        from ..kernels.label_join import label_join_pallas, validate_ranks
        from ..kernels.ops import use_interpret
        validate_ranks(base.ranks)
        self.base = base
        self._join = label_join_pallas
        self._bq = int(bq)
        self._bl = int(bl)
        self._min_bucket = max(1, int(min_bucket))
        self.interpret = use_interpret() if interpret is None else bool(
            interpret)

    # geometry / identity delegate to the wrapped snapshot
    @property
    def backend(self) -> str:
        return self.base.backend

    @property
    def version(self) -> int:
        return self.base.version

    @property
    def lmax(self) -> int:
        return self.base.lmax

    def nbytes(self) -> int:
        return self.base.nbytes()

    def _bucket(self, q: int) -> int:
        b = self._min_bucket
        while b < q:
            b *= 2
        return b

    def mr(self, us, vs) -> jnp.ndarray:
        us = np.asarray(us, np.int32).ravel()
        vs = np.asarray(vs, np.int32).ravel()
        q = us.size
        if q == 0 or self.base.lmax == 0:
            return jnp.zeros((q,), jnp.int32)
        bucket = self._bucket(q)
        if bucket > q:
            # pad with a repeat of the first pair: always in range, and
            # the padded answers are sliced off below
            us = np.concatenate([us, np.full(bucket - q, us[0], np.int32)])
            vs = np.concatenate([vs, np.full(bucket - q, vs[0], np.int32)])
        ru, su, rv, sv = _gather_rows(self.base.ranks, self.base.svals,
                                      jnp.asarray(us), jnp.asarray(vs))
        if len(self.base.ranks.devices()) > 1:
            # mesh-sharded base: the interpreter path runs the kernel on
            # one device, so collapse the gathered query rows (bucket ×
            # Lmax, not the label mass) onto a single addressable device
            dev = next(iter(sorted(self.base.ranks.devices(),
                                   key=lambda d: d.id)))
            ru, su, rv, sv = (jax.device_put(t, dev)
                              for t in (ru, su, rv, sv))
        out = self._join(ru, su, rv, sv, bq=min(self._bq, bucket),
                         bl=self._bl, interpret=self.interpret)
        return out[:q]

    def s_reach(self, us, vs, s: int) -> jnp.ndarray:
        return self.mr(us, vs) >= s


class PaddedIndex(DeviceSnapshot):
    """Back-compat constructor: the padded device form built straight from
    an ``HLIndex``.  New code should use ``DeviceSnapshot.from_hlindex``
    (or ``engine.snapshot()`` through ``repro.api``)."""

    def __init__(self, idx: HLIndex):
        ranks, svals, lengths = idx.as_padded()
        super().__init__(ranks=jnp.asarray(ranks), svals=jnp.asarray(svals),
                         lengths=jnp.asarray(lengths), backend="hl-index")


@functools.partial(jax.jit, donate_argnums=())
def batched_mr(ranks: jax.Array, svals: jax.Array,
               us: jax.Array, vs: jax.Array) -> jax.Array:
    """MR(u, v) for a batch of query pairs.

    For each label (e, s_u) of u, locate e in v's sorted rank list via
    searchsorted; a hit contributes min(s_u, s_v).  Padding (INT32_MAX)
    never matches a real rank.  Equivalent to Algorithm 5's merge-join —
    the data-parallel formulation trades the O(L) sequential scan for
    O(L log L) independent lane work, which is the right trade on a VPU.
    """
    ru = ranks[us]            # [Q, L]
    su = svals[us]
    rv = ranks[vs]
    sv = svals[vs]
    pos = jax.vmap(jnp.searchsorted)(rv, ru)          # [Q, L]
    pos = jnp.minimum(pos, rv.shape[1] - 1)
    hit = jnp.take_along_axis(rv, pos, axis=1) == ru  # [Q, L]
    sv_at = jnp.take_along_axis(sv, pos, axis=1)
    cand = jnp.where(hit, jnp.minimum(su, sv_at), 0)
    return cand.max(axis=1)
