"""Unified reachability engine API: one query surface, pluggable backends.

The repo ships several ways to answer the paper's two query problems —
``MR(u, v)`` (Problem 2, Algorithm 5) and ``u ~s~> v`` (Problem 1) — each
grown with its own build/query signature: the HL-index merge-join
(query.py), the padded JAX batch engine (``PaddedIndex``), the sparse
line-graph frontier sweeps (frontier.py), the online bidirectional search
(online.py), and the baseline oracles (baselines.py).  This module folds
them all behind one protocol:

    engine = build(h, backend="hl-index")     # or "auto"
    engine.mr(u, v)                           # scalar MR
    engine.s_reach(u, v, s)                   # scalar s-reachability
    engine.mr_batch(us, vs)                   # [Q] MR, vectorized
    engine.s_reach_batch(us, vs, s)           # [Q] bool
    engine.snapshot()                         # device-resident padded form

Backends register themselves under a string key (``register_backend``);
``build(h, backend="auto")`` consults a planner that picks a backend from
the graph size, the label mass, the expected query batch shape, and —
when a ``mesh`` is passed — the device topology (a multi-device mesh
whose line-graph closure exceeds the single-device budget routes to the
``sharded`` backend).  Adding a new structure (a HypED-style threshold
oracle, a sharded device engine, ...) is one registry entry — not a new
public API.  The full backend catalogue, the planner policy, and the
data-flow picture live in ``docs/ARCHITECTURE.md``.

``DeviceSnapshot`` generalizes ``HLIndex.as_padded``: any backend that can
express its structure as per-vertex sorted (hub, s) label rows exports the
same padded tensors, and every snapshot is served by the same fused
``batched_mr`` join.  Backends with no label form (online search, frontier
sweeps, union-find components, the MST forest) raise
``SnapshotUnsupported`` — their batch paths run through their own engines.

Hyperedge updates go through the same protocol: ``engine.update(inserts,
deletes)`` mutates the engine in place to serve the edited graph.  Each
backend declares how via its ``update_capability`` class attribute
(surfaced by ``update_capabilities()`` and CI-checked against the table
in docs/ARCHITECTURE.md):

* ``"scoped"`` — construction reruns only on the affected line-graph
  component(s) and is spliced into the surviving structure
  (``hl-index``, ``hl-index-basic`` via ``repro.core.maintenance``);
* ``"incremental"`` — adjacency caches are patched on the 1-hop touched
  set, no construction at all (``online``, ``frontier``);
* ``"rebuild"`` — the structure is recomputed whole, but through the
  same call so serving code never special-cases it (``closure``;
  ``sharded`` graduated to "scoped" — its closure regime re-closes only
  the touched component block of W*, its label regime splices through
  the parallel sharded builder);
* ``"unsupported"`` — ``update`` raises ``UpdateUnsupported`` (the
  static baselines: ``ete``, ``threshold``, ``mst-oracle``).

Every successful update bumps ``engine.version`` and invalidates the
cached ``DeviceSnapshot`` — snapshots carry the version they were
derived from, so staleness is detectable even after ``to_mesh``.

Snapshot *caching* rides on the same versioning: engines keep the stale
snapshot as a patch basis and track the dirty label rows each update
touched (``dirty_rows()``; fed by the scoped-maintenance
``UpdateReport`` for the HL-index backends), so ``snapshot()`` after a
scoped update re-derives only the changed rows via
``DeviceSnapshot.patch_rows`` — byte-identical to a from-scratch
derivation, asserted in tests.  ``last_snapshot_refresh_rows`` records
how many rows the most recent ``snapshot()`` actually re-derived.  The
request-based serving layer (``repro.serve.reach_service``) consumes
exactly this contract to swap snapshots between micro-batches.
"""
from __future__ import annotations

import functools
import os
from typing import (TYPE_CHECKING, Callable, Dict, FrozenSet, List,
                    Optional, Protocol, Tuple, runtime_checkable)

import numpy as np

if TYPE_CHECKING:                      # annotation-only; the workload
    # package imports this module, so the runtime imports are lazy
    from repro.workloads.base import Witness
    from repro.workloads.oracle import DistanceOracle

from .hypergraph import Hypergraph, apply_edge_edits
from .hlindex import (CONSTRUCTION_MODES, HLIndex, build_basic, build_fast,
                      build_sharded, pad_label_rows)
from .minimal import minimize
from .maintenance import apply_updates, normalize_update_batch
from .query import DeviceSnapshot, KernelSnapshot, mr_query, s_reach_query
from .online import NeighborCache, mr_online
from .frontier import (SparseLineGraph, frontier_batched_mr,
                       frontier_batched_s_reach)
from .baselines import (ETEIndex, MSTOracle, ThresholdComponentIndex,
                        build_ete)
from .semiring import mr_matrix, vertex_mr_from_edge_mr

__all__ = [
    "ReachabilityEngine", "DeviceSnapshot", "KernelSnapshot",
    "SnapshotUnsupported",
    "UpdateUnsupported", "WorkloadUnsupported", "WORKLOAD_OPS",
    "register_backend", "available_backends",
    "update_capabilities", "workload_capabilities", "plan_backend",
    "build", "validate_batch",
    "HLIndexEngine", "OnlineEngine", "FrontierEngine", "ETEEngine",
    "ThresholdEngine", "MSTOracleEngine", "ClosureEngine",
    "SINGLE_DEVICE_CLOSURE_BUDGET", "CONSTRUCTION_MODES",
]


def validate_batch(us, vs, n: int):
    """Shared input validation for every backend's ``mr_batch`` /
    ``s_reach_batch`` (and the serving layer's admission path): ``us`` /
    ``vs`` must be equal-length 1-D integer sequences of in-range vertex
    ids.  Returns them as int64 numpy arrays.  Before this helper,
    malformed input failed differently per backend (silent wraparound,
    shape broadcast errors deep inside jitted code, ...); now every
    entry point raises the same clear error.
    """
    us = np.asarray(us)
    vs = np.asarray(vs)
    if us.ndim != 1 or vs.ndim != 1:
        raise ValueError(
            f"query batch must be 1-D sequences of vertex ids; got shapes "
            f"us{us.shape} vs{vs.shape}")
    if us.shape[0] != vs.shape[0]:
        raise ValueError(
            f"query batch length mismatch: len(us)={us.shape[0]} != "
            f"len(vs)={vs.shape[0]}")
    for name, a in (("us", us), ("vs", vs)):
        if a.size and not np.issubdtype(a.dtype, np.integer):
            raise ValueError(
                f"query batch {name} must have an integer dtype; got "
                f"{a.dtype}")
    us = us.astype(np.int64)
    vs = vs.astype(np.int64)
    for name, a in (("us", us), ("vs", vs)):
        if a.size and (int(a.min()) < 0 or int(a.max()) >= n):
            bad = int(a.min()) if int(a.min()) < 0 else int(a.max())
            raise IndexError(
                f"query batch {name} contains vertex id {bad}, out of "
                f"range [0, {n})")
    return us, vs

# Per-device byte budget for the dense closure working set (operand plus
# the two gathered panels, f32).  When a multi-device mesh is passed and
# 12·m² exceeds this, the auto planner routes to the "sharded" backend.
SINGLE_DEVICE_CLOSURE_BUDGET = 256 * 2**20

class SnapshotUnsupported(NotImplementedError):
    """Raised by backends whose structure has no padded label form."""


class UpdateUnsupported(NotImplementedError):
    """Raised by backends whose structure cannot absorb hyperedge
    updates (``update_capability == "unsupported"``) — rebuild the
    engine via ``build`` instead."""


class WorkloadUnsupported(NotImplementedError):
    """Raised by backends that do not serve a workload op (witness /
    s_reach_k / mr_set / top_s / s_distance) — see
    ``workload_capabilities()`` and the capability table in
    docs/ARCHITECTURE.md."""


# canonical workload-op order; docs check 9 and the conformance matrix
# both pin their tables against exactly this tuple
WORKLOAD_OPS: Tuple[str, ...] = ("witness", "s_reach_k", "mr_set",
                                 "top_s", "s_distance")

# capability rule (per backend below): the label-row reductions —
# witness (hub named by the label join), mr_set, top_s — need a
# snapshot-capable label/closure form; the traversal ops — s_reach_k,
# s_distance — need a graph the backend keeps live under updates.  The
# static Section IV/VII baselines (threshold, mst-oracle) serve the
# paper's two problems only.
_LABEL_OPS = frozenset({"witness", "mr_set", "top_s"})
_TRAVERSAL_OPS = frozenset({"s_reach_k", "s_distance"})


# ---------------------------------------------------------------------------
# Protocol + shared scaffolding
# ---------------------------------------------------------------------------

@runtime_checkable
class ReachabilityEngine(Protocol):
    """The one query surface every backend serves.

    Semantics (fixed across backends, cross-validated against the
    ``mst-oracle`` reference in tests and benchmarks):

    * ``mr(u, v)`` — Problem 2: the largest ``s`` such that an s-walk
      joins vertices ``u`` and ``v``.  0 means unreachable at every
      ``s >= 1``; for ``u == v`` it is the max incident hyperedge size
      (a vertex trivially reaches itself through any incident edge).
      Vertices with no incident hyperedge answer 0 everywhere.
    * ``s_reach(u, v, s)`` — Problem 1: is there an s-walk joining
      ``u`` and ``v``?  Always equals ``mr(u, v) >= s``.
    * ``mr_batch(us, vs) -> int array [Q]`` / ``s_reach_batch(us, vs, s)
      -> bool array [Q]`` — vectorized forms; ``us``/``vs`` are equal
      length sequences of vertex ids.
    * ``snapshot() -> DeviceSnapshot`` — the padded device-resident label
      form (see ``repro.core.query``), or raises ``SnapshotUnsupported``
      for structures with no label form (online search, frontier sweeps,
      union-find components, the MST forest).
    * ``update(inserts, deletes)`` — mutate the engine in place so it
      serves the edited hypergraph (semantics identical to rebuilding
      from scratch, asserted in tests), or raise ``UpdateUnsupported``.
      ``update_capability`` ∈ {"scoped", "incremental", "rebuild",
      "unsupported"} declares how; ``version`` counts successful updates
      so snapshot staleness is detectable.

    Workload ops (``src/repro/workloads/``) — each gated by
    ``workload_capability`` (the set of ``WORKLOAD_OPS`` the backend
    serves; anything else raises ``WorkloadUnsupported``), each pinned
    against the brute-force references in ``core.baselines``:

    * ``mr_witness(u, v) -> Witness`` — the MR answer plus the
      hyperedge walk achieving it.
    * ``s_reach_k(u, v, s, k) -> bool`` — an s-walk of at most ``k``
      hyperedges exists.
    * ``mr_set(us, vs) -> int`` / ``mr_from_set(us, targets) ->
      int array`` — set-to-set / multi-source MR reductions.
    * ``top_s(u, k) -> (vertices, mr values)`` — the k strongest
      targets of ``u``, ranked (MR desc, id asc), zeros dropped.
    * ``s_distance(u, v, s) -> int`` — certified upper bound on the
      s-distance in hyperedges (0 = provably no s-walk), served off the
      cached per-``s`` ``distance_oracle(s)`` landmark structure.
    """

    name: str
    update_capability: str
    workload_capability: FrozenSet[str]

    def mr(self, u: int, v: int) -> int: ...
    def s_reach(self, u: int, v: int, s: int) -> bool: ...
    def mr_batch(self, us, vs) -> np.ndarray: ...
    def s_reach_batch(self, us, vs, s: int) -> np.ndarray: ...
    def snapshot(self) -> DeviceSnapshot: ...
    def update(self, inserts=(), deletes=()) -> None: ...
    def mr_witness(self, u: int, v: int) -> "Witness": ...
    def s_reach_k(self, u: int, v: int, s: int, k: int) -> bool: ...
    def mr_set(self, us, vs) -> int: ...
    def mr_from_set(self, us, targets) -> np.ndarray: ...
    def top_s(self, u: int, k: int) -> Tuple[np.ndarray, np.ndarray]: ...
    def s_distance(self, u: int, v: int, s: int) -> int: ...


class _EngineBase:
    """Default implementations: scalar fallbacks and mr-derived s-reach.

    Backends override whichever paths their structure accelerates; the
    semantics (``s_reach(u, v, s) == (mr(u, v) >= s)``) are fixed here so
    every backend answers identically.
    """

    name = "base"
    update_capability = "unsupported"
    # which WORKLOAD_OPS this backend serves (see the rule above the
    # registry); empty = the paper's two problems only
    workload_capability: FrozenSet[str] = frozenset()
    # index lookups cheap enough that s_reach_k pre-gates the bounded
    # BFS on an unbounded reachability answer (label join / closure
    # row); False where s_reach is itself a traversal
    _gate_hop_bounded = False

    def __init__(self, h: Hypergraph):
        self.h = h
        self.version = 0
        # label rows changed since the cached snapshot was derived:
        # empty = snapshot current / patchable as-is, None = all rows
        # (unknown or whole-structure rebuild)
        self._dirty_rows: Optional[np.ndarray] = np.empty(0, np.int64)
        self.last_snapshot_refresh_rows = 0
        # write-ahead sink (repro.store): None = updates are not journaled
        self._wal = None
        # kernel-path batch queries (Pallas label join); flipped by the
        # snapshot-serving backends' ``build(use_kernels=True)``
        self.use_kernels = False
        self._kernel_view: Optional[KernelSnapshot] = None
        # per-(s, extra_landmarks) DistanceOracle cache; invalidated on
        # every graph change (_graph_changed)
        self._distance_oracles: Dict[Tuple[int, int], "DistanceOracle"] = {}

    @classmethod
    def build(cls, h: Hypergraph, **opts) -> "ReachabilityEngine":
        raise NotImplementedError

    def mr(self, u: int, v: int) -> int:
        raise NotImplementedError

    def _check_vertex_ids(self, *ids) -> None:
        """Scalar-path counterpart of ``validate_batch``: backends whose
        ``mr`` / ``s_reach`` index host structures directly call this
        first, so an out-of-range id raises the same ``IndexError`` as
        the batch paths instead of a Python negative index silently
        answering from the wrong row."""
        for x in ids:
            if not 0 <= int(x) < self.h.n:
                raise IndexError(
                    f"vertex id {int(x)} out of range [0, {self.h.n})")

    def update(self, inserts=(), deletes=()) -> None:
        """Template method every backend shares: gate on capability,
        validate + canonicalize the batch, journal it durably (when a
        WAL sink is attached — fsync *before* the in-memory structure
        changes), then hand the canonical batch to the backend's
        ``_apply_update``.  Ordering matters: a batch that would be
        rejected is never journaled, and a journaled batch is replayed
        byte-identically on restart (``repro.store``)."""
        if self.update_capability == "unsupported":
            raise UpdateUnsupported(
                f"backend {self.name!r} does not maintain its structure "
                f"under hyperedge updates; build a fresh engine instead")
        ins, dels = normalize_update_batch(self.h, inserts, deletes)
        wal = self._wal
        if wal is not None:
            wal.append(self.version + 1, ins, dels)
        self._apply_update(ins, dels)
        if wal is not None:
            wal.committed(self)

    def _apply_update(self, inserts, deletes) -> None:
        """Backend hook behind ``update``: mutate the structure in place
        for an already-validated, canonical batch and call
        ``_graph_changed``.  Only backends whose ``update_capability``
        is not ``"unsupported"`` are ever called here."""
        raise UpdateUnsupported(
            f"backend {self.name!r} declares update_capability="
            f"{self.update_capability!r} but implements no _apply_update")

    def attach_wal(self, sink) -> None:
        """Journal every subsequent ``update`` through ``sink`` — any
        object with ``append(version, inserts, deletes)`` (durable,
        called before the apply) and ``committed(engine)`` (called
        after); ``repro.store.WriteAheadLog`` and ``IndexStore`` both
        qualify."""
        self._wal = sink

    def detach_wal(self):
        """Stop journaling; returns the detached sink (the store's
        replay path detaches around ``update`` so replayed records are
        not re-journaled)."""
        sink, self._wal = self._wal, None
        return sink

    def _graph_changed(self, new_h: Hypergraph, dirty_rows=None) -> None:
        """Install the edited graph and bump ``version``.  ``dirty_rows``
        names the label rows the update changed (accumulated across
        updates): the cached snapshot becomes stale but is *kept* as the
        patch basis for the next ``snapshot()``.  ``None`` means all
        rows — the next derivation is full anyway, so the stale snapshot
        is dropped immediately rather than held through the rebuild
        (rebuild-capability backends and the full-rebuild fallbacks of
        scoped ones are the memory-bound regime; holding an unusable
        snapshot across ``update`` would raise peak memory for
        nothing)."""
        self.h = new_h
        self.version += 1
        self._distance_oracles.clear()   # landmark BFS trees are per-graph
        if dirty_rows is None:
            self._dirty_rows = None
            if getattr(self, "_snap", None) is not None:
                self._snap = None
        elif self._dirty_rows is not None:
            self._dirty_rows = np.union1d(
                self._dirty_rows, np.asarray(dirty_rows, np.int64))

    def dirty_rows(self) -> Optional[np.ndarray]:
        """Vertex rows whose padded label content may differ between the
        cached (stale) snapshot — ``snapshot_cache()`` — and the one the
        next ``snapshot()`` call returns; ``None`` = all rows / unknown.
        Resets to empty once ``snapshot()`` re-derives.  The serving
        layer reads this *before* refreshing to patch mesh-resident
        snapshot copies row-wise; the delta is only meaningful relative
        to ``snapshot_cache()``, so consumers holding an older copy must
        check identity against it first."""
        return self._dirty_rows

    def snapshot_cache(self) -> Optional[DeviceSnapshot]:
        """The currently cached snapshot object (possibly stale), or
        ``None``.  ``dirty_rows()`` is the row delta between exactly
        this object and the next ``snapshot()`` result — consumers that
        patch their own resident copies row-wise must confirm their copy
        derives from this object before trusting the delta."""
        return getattr(self, "_snap", None)

    def _snapshot_current(self) -> bool:
        snap = getattr(self, "_snap", None)
        return snap is not None and snap.version == self.version

    def snapshot_delta(self, basis: Optional[DeviceSnapshot] = None,
                       ) -> Tuple[DeviceSnapshot, Optional[np.ndarray]]:
        """The snapshot fan-out hook: one call returning ``(fresh
        snapshot, dirty-row delta relative to basis)`` — what a consumer
        holding device-resident copies landed from ``basis`` needs to
        bring *all* of them current with row-wise patches instead of
        full re-lands (``to_mesh(base=, dirty_rows=)``).

        ``basis`` is the host snapshot the caller's copies derive from.
        The delta is ``None`` (re-land in full) when it is unknowable:
        no basis, the basis is not the engine's cached snapshot object
        (another consumer re-derived in between, resetting the delta),
        or the update was a whole-structure rebuild.  The dirty set must
        be captured *before* ``snapshot()`` re-derives and resets it,
        which is exactly the ordering this method encapsulates — the
        serving layer and ``ReplicaGroup`` both build on it.  Raises
        ``SnapshotUnsupported`` for backends with no snapshot form."""
        dirty = (self.dirty_rows()
                 if basis is not None and self.snapshot_cache() is basis
                 else None)
        snap = self.snapshot()
        if snap is basis:
            dirty = np.empty(0, np.int64)      # already current: patch nothing
        return snap, dirty

    def _query_snapshot(self):
        """The snapshot view batch queries run through: the plain
        ``DeviceSnapshot`` (XLA ``batched_mr``), or — with
        ``use_kernels`` — a cached ``KernelSnapshot`` wrapper that
        answers through the Pallas label-join kernel.  The wrapper is
        rebuilt whenever ``snapshot()`` hands back a different object
        (update / patch / re-derivation), so it can never serve stale
        label rows."""
        snap = self.snapshot()
        if not self.use_kernels:
            return snap
        kv = self._kernel_view
        if kv is None or kv.base is not snap:
            kv = KernelSnapshot(snap)
            self._kernel_view = kv
        return kv

    def s_reach(self, u: int, v: int, s: int) -> bool:
        return self.mr(u, v) >= s

    def mr_batch(self, us, vs) -> np.ndarray:
        us, vs = validate_batch(us, vs, self.h.n)
        return np.array([self.mr(int(u), int(v)) for u, v in zip(us, vs)],
                        np.int64)

    def s_reach_batch(self, us, vs, s: int) -> np.ndarray:
        return self.mr_batch(us, vs) >= s

    def snapshot(self) -> DeviceSnapshot:
        raise SnapshotUnsupported(
            f"backend {self.name!r} has no padded device form; query it "
            f"through mr_batch / s_reach_batch instead")

    # -- workload ops (src/repro/workloads/) -------------------------------

    def _require_workload(self, op: str) -> None:
        if op not in self.workload_capability:
            raise WorkloadUnsupported(
                f"backend {self.name!r} does not serve workload op "
                f"{op!r}; see workload_capabilities()")

    def _witness_hub(self, u: int, v: int, k: int) -> Optional[int]:
        """The hyperedge the label join met at, when the backend's
        structure names one (HL-index labels); None lets the extractor
        meet wherever the frontiers touch (closure backends, where
        every hyperedge is a hub)."""
        return None

    def mr_witness(self, u: int, v: int) -> "Witness":
        """MR(u, v) plus the hyperedge walk achieving it (hub-anchored
        meet-in-the-middle reconstruction; ``verify_witness`` checks
        the result from the hypergraph alone)."""
        self._require_workload("witness")
        from repro.workloads.base import Witness
        from repro.workloads.witness import extract_witness
        self._check_vertex_ids(u, v)
        u, v = int(u), int(v)
        k = int(self.mr(u, v))
        walk = (extract_witness(self.h, u, v, k,
                                hub=self._witness_hub(u, v, k))
                if k > 0 else ())
        return Witness(u=u, v=v, s=k, walk=tuple(int(e) for e in walk))

    def s_reach_k(self, u: int, v: int, s: int, k: int) -> bool:
        """Hop-bounded s-reach: an s-walk of at most ``k`` hyperedges.
        Index-backed engines pre-gate the bounded search: unbounded
        unreachable rejects immediately, and ``k >= m`` accepts
        immediately (shortest s-walks never repeat a hyperedge)."""
        self._require_workload("s_reach_k")
        self._check_vertex_ids(u, v)
        u, v, s, k = int(u), int(v), int(s), int(k)
        if s < 1:
            raise ValueError(f"s-reachability needs s >= 1; got {s}")
        if k < 1:
            raise ValueError(f"hop bound needs k >= 1; got {k}")
        if self._gate_hop_bounded:
            if not self.s_reach(u, v, s):
                return False             # early-reject: no walk at all
            if k >= self.h.m:
                return True              # early-accept: m edges suffice
        return self._bounded_s_reach(u, v, s, k)

    def _bounded_s_reach(self, u: int, v: int, s: int, k: int) -> bool:
        """Backend hook behind the gate: host bounded BFS by default;
        the frontier backend swaps in its jitted sweep."""
        from repro.workloads.hop_bounded import hop_bounded_s_reach
        return bool(hop_bounded_s_reach(self.h, u, v, s, k))

    def mr_set(self, us, vs) -> int:
        """Set-to-set MR: ``max over U x V of MR(u, v)``, answered as
        one cross-product batch through ``mr_batch`` — the vectorized
        snapshot join, kernel-path eligible like any other batch."""
        self._require_workload("mr_set")
        from repro.workloads.setops import cross_pairs, normalize_vertex_set
        sources = normalize_vertex_set(us, self.h.n, "mr_set source set")
        targets = normalize_vertex_set(vs, self.h.n, "mr_set target set")
        qu, qv = cross_pairs(sources, targets)
        return int(np.asarray(self.mr_batch(qu, qv)).max())

    def mr_from_set(self, us, targets) -> np.ndarray:
        """Multi-source MR: per target, the best MR from any source
        (``targets`` keeps caller order and duplicates)."""
        self._require_workload("mr_set")
        from repro.workloads.setops import cross_pairs, normalize_vertex_set
        sources = normalize_vertex_set(us, self.h.n, "mr_from_set sources")
        tgt, _ = validate_batch(targets, targets, self.h.n)
        qu, qv = cross_pairs(sources, tgt)
        flat = np.asarray(self.mr_batch(qu, qv), np.int64)
        return flat.reshape(len(sources), len(tgt)).max(axis=0)

    def top_s(self, u: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k strongest-s ranking: the (up to) k vertices with the
        largest MR(u, .), from one full label-row sweep.  Returns
        (vertices, mr values) ranked (MR desc, id asc); zeros and ``u``
        itself never appear."""
        self._require_workload("top_s")
        from repro.workloads.topk import select_top_s
        self._check_vertex_ids(u)
        if int(k) < 1:
            raise ValueError(f"top_s needs k >= 1; got {k}")
        n = self.h.n
        row = self.mr_batch(np.full(n, int(u), np.int64),
                            np.arange(n, dtype=np.int64))
        return select_top_s(np.asarray(row), int(u), int(k))

    def s_distance(self, u: int, v: int, s: int) -> int:
        """Certified upper bound on the s-distance in hyperedges
        (0 = provably no s-walk), served off the cached landmark
        oracle for this ``s``."""
        self._require_workload("s_distance")
        self._check_vertex_ids(u, v)
        return int(self.distance_oracle(int(s)).distance(int(u), int(v)))

    def distance_oracle(self, s: int, *, extra_landmarks: int = 4,
                        ) -> "DistanceOracle":
        """The per-``s`` landmark oracle (built on first use, cached
        until the graph changes)."""
        self._require_workload("s_distance")
        if int(s) < 1:
            raise ValueError(f"s-distance needs s >= 1; got {s}")
        key = (int(s), int(extra_landmarks))
        oracle = self._distance_oracles.get(key)
        if oracle is None:
            from repro.workloads.oracle import DistanceOracle
            oracle = DistanceOracle(self.h, int(s),
                                    extra_landmarks=int(extra_landmarks))
            self._distance_oracles[key] = oracle
        return oracle

    def block_until_built(self) -> None:
        """Block until any device work dispatched by ``build`` is resident
        (jax dispatch is asynchronous).  Backends whose build is host-side
        (or already synchronous) inherit this no-op; async-building
        backends (e.g. ``sharded``) override it so build timing and
        serving hand-off are well-defined."""

    def nbytes(self) -> Optional[int]:
        """Resident index size in bytes, if the backend tracks one."""
        return None


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable] = {}


def register_backend(name: str, builder: Optional[Callable] = None):
    """Register ``builder`` (a class with ``.build(h, **opts)``) under
    ``name``.  Usable as a decorator: ``@register_backend("hl-index")``."""
    def deco(cls):
        _REGISTRY[name] = cls
        return cls
    if builder is not None:
        return deco(builder)
    return deco


def available_backends() -> List[str]:
    """Sorted registry keys (excludes the virtual ``"auto"``)."""
    return sorted(_REGISTRY)


def update_capabilities() -> Dict[str, str]:
    """Registry key -> declared ``update(inserts, deletes)`` capability
    ("scoped" | "incremental" | "rebuild" | "unsupported").  The table in
    docs/ARCHITECTURE.md is CI-checked against this (tools/check_docs.py).
    """
    return {name: getattr(cls, "update_capability", "unsupported")
            for name, cls in sorted(_REGISTRY.items())}


def workload_capabilities() -> Dict[str, Dict[str, bool]]:
    """Registry key -> {workload op -> served?} in ``WORKLOAD_OPS``
    order.  The workload-capability table in docs/ARCHITECTURE.md is
    CI-checked against this both ways (tools/check_docs.py check 9),
    and the conformance matrix derives its supported/unsupported cells
    from it."""
    caps: Dict[str, Dict[str, bool]] = {}
    for name, cls in sorted(_REGISTRY.items()):
        served = getattr(cls, "workload_capability", frozenset())
        caps[name] = {op: op in served for op in WORKLOAD_OPS}
    return caps


def plan_backend(h: Hypergraph, batch_hint: Optional[int] = None, *,
                 mesh=None, device_budget_bytes: Optional[int] = None) -> str:
    """Pick a backend from graph size, label mass, query batch shape, and
    (optionally) the device topology.

    Args:
      h: the hypergraph to serve.
      batch_hint: expected query batch size (None/0 = trickle queries).
      mesh: an optional ``jax.sharding.Mesh``.  A mesh with more than one
        device opts the workload into distribution: if the dense closure
        working set (~12·m² bytes: operand + two gathered f32 panels)
        exceeds ``device_budget_bytes``, the planner picks ``sharded``.
        A unit mesh (1 device) never routes to ``sharded``.
      device_budget_bytes: per-device memory budget for the closure
        working set; defaults to ``SINGLE_DEVICE_CLOSURE_BUDGET``.

    Policy (documented in README.md and docs/ARCHITECTURE.md):
      * multi-device mesh + closure beyond one device -> ``sharded``
        (2-D block-sharded semiring closure, mesh-sharded snapshot);
      * tiny line graphs with real batches -> dense semiring ``closure``
        (one fused device program, no per-root host traversal);
      * anything where HL-index construction is tractable -> ``hl-index``
        (the paper's answer: microsecond merge-joins, batch via
        snapshot).  On a multi-device mesh the tractability ceiling
        scales with the parallelism actually deliverable — the device
        count capped by the host's cores: construction itself shards
        across the mesh (``build_engine`` forwards the mesh, so
        ``HLIndexEngine.build`` picks ``construction="sharded"`` and
        ``build_sharded`` defaults a matching worker pool — see
        ``repro.core.hlindex``), so larger graphs still label-build
        instead of falling back to traversal backends.  Known limit of
        the heuristic: shards stop at line-graph component boundaries,
        so a single-component graph cannot actually parallelize — the
        planner cannot see that without computing the neighbor index it
        exists to avoid, so the scaled budget is optimistic there
        (sub-component root-range sharding is the ROADMAP item that
        closes this);
      * huge graphs, batched workload -> ``frontier`` (index-free sparse
        sweeps; build cost is one line-graph pass);
      * huge graphs, trickle queries -> ``online`` (no build at all).
    """
    q = int(batch_hint) if batch_hint else 0
    if h.m == 0:
        return "hl-index"
    devices = int(mesh.devices.size) if mesh is not None else 1
    if devices > 1 and len(mesh.axis_names) >= 2:
        # sharded needs two mesh axes to 2-D block-shard over; a 1-D mesh
        # falls through to the single-device policy rather than routing
        # to a backend that cannot be built on it
        budget = (SINGLE_DEVICE_CLOSURE_BUDGET if device_budget_bytes is None
                  else int(device_budget_bytes))
        if 12 * h.m * h.m > budget:
            return "sharded"
    if h.m <= 256 and q >= 64:
        return "closure"
    # label mass proxy: construction walks ~nnz * avg-degree host work;
    # sharded construction divides it across forked workers, so the
    # budget scales with the parallelism actually deliverable — the
    # mesh device count capped by the host's cores (build_engine
    # forwards the mesh, and build_sharded defaults its worker pool to
    # exactly this on a multi-device mesh)
    parallel = min(devices, os.cpu_count() or 1) if devices > 1 else 1
    label_budget = 2e6 * max(parallel, 1)
    if h.nnz * max(float(h.vertex_degrees.mean()) if h.n else 0.0, 1.0) \
            <= label_budget:
        return "hl-index"
    if q >= 256:
        return "frontier"
    return "online"


def build(h: Optional[Hypergraph] = None, backend: str = "auto", *,
          restore=None, batch_hint: Optional[int] = None, mesh=None,
          **opts) -> "ReachabilityEngine":
    """Build a reachability engine over ``h`` — or restore one from disk.

    Args:
      h: the hypergraph to serve (omit iff ``restore`` is given).
      backend: a registry key (see ``available_backends()``) or
        ``"auto"`` to let ``plan_backend`` choose.  With ``restore`` a
        non-auto value asserts what the persisted engine must be.
      restore: path to a ``repro.store`` artifact — an ``IndexStore``
        directory (checkpoint + WAL replay + re-attach, the warm-restart
        path) or a single ``save_index`` file.  No construction runs:
        the index loads mmap-backed and only the journaled update suffix
        replays.
      batch_hint: expected query batch size, consumed by the planner.
      mesh: optional ``jax.sharding.Mesh``.  Consulted by the planner
        (see ``plan_backend``) and forwarded to the ``sharded`` backend;
        ignored by single-device backends.  A restored ``sharded``
        engine re-shards onto it.
      **opts: backend-specific options, passed to the backend's
        ``build`` (e.g. ``minimize_labels=False`` or
        ``construction="sharded"`` for "hl-index", ``schedule="ring"``
        or ``build_labels=True`` for "sharded", ``device_budget_bytes``
        for the planner) — or, with ``restore``, the
        ``restore_engine`` options (``verify``, ``checkpoint_every``,
        ``attach``).
    """
    if restore is not None:
        if h is not None:
            raise ValueError(
                "build(restore=...) loads a persisted engine; passing a "
                "hypergraph too is ambiguous — use one or the other")
        from ..store import restore_engine
        return restore_engine(
            restore, mesh=mesh,
            expect_backend=None if backend == "auto" else backend, **opts)
    if h is None:
        raise ValueError("build() needs a hypergraph (or restore=<path>)")
    budget = opts.pop("device_budget_bytes", None)
    if backend == "auto":
        backend = plan_backend(h, batch_hint, mesh=mesh,
                               device_budget_bytes=budget)
    try:
        cls = _REGISTRY[backend]
    except KeyError:
        raise ValueError(
            f"unknown backend {backend!r}; available: {available_backends()}"
        ) from None
    if mesh is not None and backend in _MESH_AWARE_BACKENDS:
        opts.setdefault("mesh", mesh)
    return cls.build(h, **opts)


# Backends whose ``build`` consumes a device mesh: "sharded" block-shards
# its closure over it; the HL-index backends shard *construction* over it
# (neighbor overlaps on device, per-device component shards).
_MESH_AWARE_BACKENDS = frozenset({"sharded", "hl-index", "hl-index-basic"})


# ---------------------------------------------------------------------------
# HL-index backends (the paper's structure)
# ---------------------------------------------------------------------------

def _resolve_construction(construction: str, mesh, workers,
                          num_shards) -> str:
    """The one auto-resolution rule both HL-index backends share:
    ``"auto"`` means sharded construction iff a multi-device mesh,
    ``workers``, or ``num_shards`` asks for it; anything else must be a
    ``CONSTRUCTION_MODES`` key."""
    if construction == "auto":
        return ("sharded"
                if (workers or num_shards
                    or (mesh is not None and int(mesh.devices.size) > 1))
                else "serial")
    if construction not in CONSTRUCTION_MODES:
        raise ValueError(
            f"unknown construction {construction!r}; available: "
            f"{sorted(CONSTRUCTION_MODES)}")
    return construction

@register_backend("hl-index")
class HLIndexEngine(_EngineBase):
    """Algorithm 3 (+ Algorithm 4 minimization) served by Algorithm 5
    merge-joins; batches run on the padded device snapshot.  Updates are
    component-scoped: construction reruns only on the affected line-graph
    component(s) and is spliced into the surviving labels
    (``repro.core.maintenance``)."""

    name = "hl-index"
    update_capability = "scoped"
    workload_capability = _LABEL_OPS | _TRAVERSAL_OPS
    _gate_hop_bounded = True

    def __init__(self, h: Hypergraph, idx: HLIndex,
                 builder: Callable[[Hypergraph], HLIndex] = build_fast,
                 minimizer: Optional[Callable[[HLIndex], HLIndex]] = None):
        super().__init__(h)
        self.idx = idx
        self.construction = "serial"     # overwritten by ``build``
        self._builder = builder          # scoped-update (re)construction
        self._minimizer = minimizer      # applied to the sub-index too
        self._snap: Optional[DeviceSnapshot] = None

    @classmethod
    def build(cls, h: Hypergraph, *, minimize_labels: bool = True,
              index: Optional[HLIndex] = None,
              construction: str = "auto", mesh=None,
              workers: Optional[int] = None,
              num_shards: Optional[int] = None,
              use_kernels: bool = False) -> "HLIndexEngine":
        """``index`` reuses a prebuilt (unminimized) HL-index instead of
        running construction again — e.g. to derive the minimized engine
        from an ablation engine's labels.

        ``construction`` picks the builder from ``CONSTRUCTION_MODES``:
        ``"serial"`` (Algorithm 3 on one host thread), ``"sharded"``
        (component-sharded parallel construction — byte-identical labels,
        see ``repro.core.hlindex.build_sharded``), or ``"auto"``
        (sharded iff a multi-device ``mesh``, ``workers``, or
        ``num_shards`` asks for it).  ``mesh`` additionally routes the
        neighbor-overlap precompute onto the devices.  Scoped updates
        keep using the same construction mode on the affected
        component(s).

        ``use_kernels`` answers batch queries through the Pallas
        label-join kernel (``KernelSnapshot``) instead of the XLA
        ``batched_mr`` program — compiled on TPU, interpret-mode
        fallback elsewhere; answers are byte-identical either way
        (conformance-matrix rows pin both).
        """
        construction = _resolve_construction(construction, mesh, workers,
                                             num_shards)
        minimizer = minimize if minimize_labels else None
        if construction == "sharded":
            builder = functools.partial(build_sharded, workers=workers,
                                        num_shards=num_shards)
            if index is not None:
                idx = minimizer(index) if minimizer else index
            else:
                # minimization runs inside the shards too (exact: dual
                # sets are component-confined), so the whole build
                # parallelizes — byte-identical to minimize(build_fast(h))
                idx = build_sharded(h, minimizer=minimizer, workers=workers,
                                    num_shards=num_shards, mesh=mesh)
        else:
            builder = build_fast
            idx = index if index is not None else build_fast(h)
            if minimizer is not None:
                idx = minimizer(idx)
        eng = cls(h, idx, builder=builder, minimizer=minimizer)
        eng.construction = construction
        eng.use_kernels = bool(use_kernels)
        return eng

    def mr(self, u: int, v: int) -> int:
        self._check_vertex_ids(u, v)
        return mr_query(self.idx, int(u), int(v))

    def s_reach(self, u: int, v: int, s: int) -> bool:
        self._check_vertex_ids(u, v)
        return s_reach_query(self.idx, int(u), int(v), int(s))

    def _witness_hub(self, u: int, v: int, k: int) -> Optional[int]:
        """The Algorithm-5 join's meeting hub: a hyperedge labeled on
        both sides with min(s_u, s_v) = k (no label pair can exceed
        MR, so >= k is the argmax)."""
        label_v = self.idx.label_dict(v)
        for e, su in zip(self.idx.labels_edge[u], self.idx.labels_s[u]):
            sv = label_v.get(int(e))
            if sv is not None and min(int(su), sv) >= k:
                return int(e)
        return None

    def mr_batch(self, us, vs) -> np.ndarray:
        us, vs = validate_batch(us, vs, self.h.n)
        return np.asarray(self._query_snapshot().mr(us, vs))

    def s_reach_batch(self, us, vs, s: int) -> np.ndarray:
        us, vs = validate_batch(us, vs, self.h.n)
        return np.asarray(self._query_snapshot().s_reach(us, vs, int(s)))

    def snapshot(self) -> DeviceSnapshot:
        """Current padded device form.  After a scoped ``update`` the
        stale snapshot is patched: only the rows the ``UpdateReport``
        marked dirty are re-padded and scattered over the old tensors
        (byte-identical to a from-scratch derivation, asserted in
        tests/test_serving.py); a full-rebuild update re-derives whole.
        """
        if self._snapshot_current():
            return self._snap
        basis, dirty = self._snap, self._dirty_rows
        if basis is None or dirty is None:
            snap = DeviceSnapshot.from_hlindex(self.idx, self.name,
                                               version=self.version)
            self.last_snapshot_refresh_rows = self.h.n
        else:
            snap = self._patched_snapshot(basis, dirty)
            self.last_snapshot_refresh_rows = int(dirty.size)
        self._snap = snap
        self._dirty_rows = np.empty(0, np.int64)
        return snap

    def _patched_snapshot(self, basis: DeviceSnapshot,
                          dirty: np.ndarray) -> DeviceSnapshot:
        idx, n = self.idx, self.h.n
        lengths = np.zeros(n, np.int64)
        basis_n = int(basis.ranks.shape[0])
        lengths[:basis_n] = np.asarray(basis.lengths)
        lengths[dirty] = [idx.labels_s[int(u)].size for u in dirty]
        lmax = int(lengths.max()) if n else 0
        row_ranks, row_svals, row_lengths = pad_label_rows(
            [idx.labels_rank[int(u)] for u in dirty],
            [idx.labels_s[int(u)] for u in dirty], pad_to=lmax)
        return basis.patch_rows(dirty, row_ranks, row_svals, row_lengths,
                                n=n, lmax=lmax, version=self.version,
                                backend=self.name)

    def _apply_update(self, inserts=(), deletes=()) -> None:
        new_h, self.idx, report = apply_updates(
            self.h, self.idx, inserts, deletes,
            builder=self._builder, minimizer=self._minimizer)
        self._graph_changed(
            new_h, dirty_rows=(None if report.full_rebuild
                               else report.refreshed_vertices))

    def nbytes(self) -> int:
        return self.idx.nbytes()


@register_backend("hl-index-basic")
class HLIndexBasicEngine(HLIndexEngine):
    """Algorithm 2 construction (no MCD/neighbor-index pruning, no
    minimization) — the ablation baseline, same query and scoped-update
    paths (updates rebuild the affected components with Algorithm 2)."""

    name = "hl-index-basic"

    @classmethod
    def build(cls, h: Hypergraph, *, cover_check: bool = True,
              construction: str = "auto", mesh=None,
              workers: Optional[int] = None,
              num_shards: Optional[int] = None,
              use_kernels: bool = False) -> "HLIndexBasicEngine":
        base = functools.partial(build_basic, cover_check=cover_check)
        construction = _resolve_construction(construction, mesh, workers,
                                             num_shards)
        if construction == "sharded":
            builder = functools.partial(build_sharded, base=base,
                                        workers=workers,
                                        num_shards=num_shards)
            idx = build_sharded(h, base=base, workers=workers,
                                num_shards=num_shards, mesh=mesh)
        else:
            builder = base
            idx = base(h)
        eng = cls(h, idx, builder=builder)
        eng.construction = construction
        eng.use_kernels = bool(use_kernels)
        return eng


# ---------------------------------------------------------------------------
# Index-free backends
# ---------------------------------------------------------------------------

@register_backend("online")
class OnlineEngine(_EngineBase):
    """Algorithm 1 bidirectional search (the paper's Base*); zero build
    cost beyond the optional neighbor cache, which updates patch on the
    1-hop touched set only."""

    name = "online"
    update_capability = "incremental"
    workload_capability = _TRAVERSAL_OPS

    def __init__(self, h: Hypergraph, cache: Optional[NeighborCache]):
        super().__init__(h)
        self.cache = cache

    @classmethod
    def build(cls, h: Hypergraph, *, precompute: bool = True) -> "OnlineEngine":
        return cls(h, NeighborCache(h) if precompute else None)

    def mr(self, u: int, v: int) -> int:
        self._check_vertex_ids(u, v)
        return mr_online(self.h, int(u), int(v), self.cache)

    def _apply_update(self, inserts=(), deletes=()) -> None:
        new_h, old_to_new, touched = apply_edge_edits(self.h, inserts,
                                                      deletes)
        if self.cache is not None:
            self.cache = self.cache.updated(new_h, old_to_new, touched)
        self._graph_changed(new_h)

    def nbytes(self) -> Optional[int]:
        return self.cache.nbytes() if self.cache is not None else 0


@register_backend("frontier")
class FrontierEngine(_EngineBase):
    """Index-free sparse line-graph frontier sweeps — the batch path for
    graphs beyond dense-closure scale.  ``rounds`` bounds propagation
    (None = |E|, exact)."""

    name = "frontier"
    update_capability = "incremental"
    workload_capability = _TRAVERSAL_OPS

    def __init__(self, h: Hypergraph, g: SparseLineGraph,
                 rounds: Optional[int]):
        super().__init__(h)
        self.g = g
        self.rounds = rounds

    @classmethod
    def build(cls, h: Hypergraph, *,
              rounds: Optional[int] = None) -> "FrontierEngine":
        return cls(h, SparseLineGraph(h), rounds)

    def _apply_update(self, inserts=(), deletes=()) -> None:
        new_h, old_to_new, touched = apply_edge_edits(self.h, inserts,
                                                      deletes)
        self.g = self.g.updated(new_h, old_to_new, touched)
        self._graph_changed(new_h)

    def mr(self, u: int, v: int) -> int:
        return int(self.mr_batch([int(u)], [int(v)])[0])

    def s_reach(self, u: int, v: int, s: int) -> bool:
        return bool(self.s_reach_batch([int(u)], [int(v)], int(s))[0])

    def mr_batch(self, us, vs) -> np.ndarray:
        us, vs = validate_batch(us, vs, self.h.n)
        return frontier_batched_mr(self.g, us, vs, rounds=self.rounds)

    def s_reach_batch(self, us, vs, s: int) -> np.ndarray:
        us, vs = validate_batch(us, vs, self.h.n)
        return frontier_batched_s_reach(self.g, us, vs, int(s),
                                        rounds=self.rounds)

    def _bounded_s_reach(self, u: int, v: int, s: int, k: int) -> bool:
        # bounded *device* path: a walk of k hyperedges is k - 1
        # line-graph steps of the jitted frontier sweep
        return bool(frontier_batched_s_reach(
            self.g, [u], [v], s, rounds=k - 1)[0])


# ---------------------------------------------------------------------------
# Baseline backends (Section IV / VII structures)
# ---------------------------------------------------------------------------

@register_backend("ete")
class ETEEngine(_EngineBase):
    """Hyperedge-to-hyperedge 2-hop labeling; snapshot merges each
    vertex's incident label lists into the shared padded form."""

    name = "ete"
    # label-row reductions only: the structure is static (updates
    # unsupported), so the live-traversal ops stay off
    workload_capability = _LABEL_OPS

    def __init__(self, h: Hypergraph, ete: ETEIndex):
        super().__init__(h)
        self.ete = ete
        self._snap: Optional[DeviceSnapshot] = None

    @classmethod
    def build(cls, h: Hypergraph) -> "ETEEngine":
        return cls(h, build_ete(h))

    def mr(self, u: int, v: int) -> int:
        self._check_vertex_ids(u, v)
        return self.ete.mr(int(u), int(v))

    def mr_batch(self, us, vs) -> np.ndarray:
        us, vs = validate_batch(us, vs, self.h.n)
        return np.asarray(self._query_snapshot().mr(us, vs))

    def s_reach_batch(self, us, vs, s: int) -> np.ndarray:
        us, vs = validate_batch(us, vs, self.h.n)
        return np.asarray(self._query_snapshot().s_reach(us, vs, int(s)))

    def snapshot(self) -> DeviceSnapshot:
        if not self._snapshot_current():
            merged = [self.ete._merged(self.h.edges_of(u))
                      for u in range(self.h.n)]
            ranks, svals, lengths = pad_label_rows([r for r, _ in merged],
                                                   [s for _, s in merged])
            self._snap = DeviceSnapshot.from_padded(ranks, svals, lengths,
                                                    self.name,
                                                    version=self.version)
        return self._snap

    def nbytes(self) -> int:
        return self.ete.nbytes()


@register_backend("threshold")
class ThresholdEngine(_EngineBase):
    """HypED-style per-threshold union-find components (exact; storage
    O(S·m) — the blow-up the paper contrasts against)."""

    name = "threshold"

    def __init__(self, h: Hypergraph, tci: ThresholdComponentIndex):
        super().__init__(h)
        self.tci = tci

    @classmethod
    def build(cls, h: Hypergraph, *,
              cap: Optional[int] = None) -> "ThresholdEngine":
        return cls(h, ThresholdComponentIndex(h, cap=cap))

    def mr(self, u: int, v: int) -> int:
        self._check_vertex_ids(u, v)
        return self.tci.mr(int(u), int(v))

    def nbytes(self) -> int:
        return self.tci.nbytes()


@register_backend("mst-oracle")
class MSTOracleEngine(_EngineBase):
    """Maximum-spanning-forest bottleneck oracle — the independent exact
    reference the cross-validation suite pins every backend against."""

    name = "mst-oracle"

    def __init__(self, h: Hypergraph, oracle: MSTOracle):
        super().__init__(h)
        self.oracle = oracle

    @classmethod
    def build(cls, h: Hypergraph) -> "MSTOracleEngine":
        return cls(h, MSTOracle(h))

    def mr(self, u: int, v: int) -> int:
        self._check_vertex_ids(u, v)
        return self.oracle.mr(int(u), int(v))


@register_backend("closure")
class ClosureEngine(_EngineBase):
    """Dense (max, min)-semiring closure W* [m, m] (semiring.py).

    Its snapshot is the degenerate-but-exact label form: every hyperedge
    is a hub, ``L(u)[e] = max_{e_u ∋ u} W*[e_u, e]``.  Bottleneck triangle
    inequality makes the shared searchsorted join exact on these rows
    (equality is attained at the hub e = e_u of an optimal pair).
    """

    name = "closure"
    update_capability = "rebuild"
    workload_capability = _LABEL_OPS | _TRAVERSAL_OPS
    _gate_hop_bounded = True

    def __init__(self, h: Hypergraph, w_star: np.ndarray,
                 method: str = "maxmin"):
        super().__init__(h)
        self.w_star = w_star
        self._method = method
        self._snap: Optional[DeviceSnapshot] = None

    @classmethod
    def build(cls, h: Hypergraph, *, method: str = "maxmin") -> "ClosureEngine":
        return cls(h, mr_matrix(h, method=method), method)

    def _apply_update(self, inserts=(), deletes=()) -> None:
        # dense closures have no cheap incremental form (one new overlap
        # can rewrite O(m²) entries); recompute whole, same protocol
        new_h, _, _ = apply_edge_edits(self.h, inserts, deletes)
        self.w_star = mr_matrix(new_h, method=self._method)
        self._graph_changed(new_h)

    def mr(self, u: int, v: int) -> int:
        # scalar lookups stay on the host matrix (no reason to build the
        # [n, m] snapshot for a trickle of queries)
        self._check_vertex_ids(u, v)
        return int(vertex_mr_from_edge_mr(self.h, self.w_star,
                                          [int(u)], [int(v)])[0])

    def mr_batch(self, us, vs) -> np.ndarray:
        # batches go through the fused device join — the reason the
        # planner picks this backend for batched small-graph workloads
        us, vs = validate_batch(us, vs, self.h.n)
        return np.asarray(self._query_snapshot().mr(us, vs))

    def s_reach_batch(self, us, vs, s: int) -> np.ndarray:
        us, vs = validate_batch(us, vs, self.h.n)
        return np.asarray(self._query_snapshot().s_reach(us, vs, int(s)))

    def snapshot(self) -> DeviceSnapshot:
        if not self._snapshot_current():
            h, m = self.h, self.h.m
            svals = np.zeros((h.n, m), np.int32)
            deg = np.diff(h.v_ptr)
            nz = np.nonzero(deg > 0)[0]
            if nz.size:
                # segment-max of W* rows over each vertex's incidence list
                # (one gather + reduceat; degree-0 vertices keep zero rows)
                svals[nz] = np.maximum.reduceat(self.w_star[h.v_idx],
                                                h.v_ptr[nz], axis=0)
            ranks = np.broadcast_to(np.arange(m, dtype=np.int32), (h.n, m))
            lengths = np.full(h.n, m, np.int32)
            self._snap = DeviceSnapshot.from_padded(np.ascontiguousarray(ranks),
                                                    svals, lengths, self.name,
                                                    version=self.version)
            self.last_snapshot_refresh_rows = h.n
            self._dirty_rows = np.empty(0, np.int64)
        return self._snap

    def nbytes(self) -> int:
        return int(self.w_star.nbytes)


# ---------------------------------------------------------------------------
# Multi-device backend — lives in distributed.py; importing it here
# registers "sharded" so the registry is complete after `import engine`.
# ---------------------------------------------------------------------------

from . import distributed as _distributed  # noqa: E402,F401
