"""Landmark s-distance oracle (Hyper-distance Oracles, PAPERS.md,
restated under the paper's s-overlap walk semantics).

``DistanceOracle`` answers "how many hyperedges does an s-walk from u
to v need" with a *certified upper bound*: every returned value is the
length of an actual walk routed through a landmark hyperedge, so

    exact == 0  <=>  bound == 0        (reachability is never wrong)
    exact <= bound                      (and equal through a landmark)

Construction: on the >= s line graph, pick one landmark per connected
component (the max-degree hyperedge — high-degree roots cover the most
walks, the same importance intuition as the HL-index hub order) plus a
few extra global top-degree landmarks for tightness, and run one BFS
tree per landmark.  A query folds E(u) and E(v) onto each landmark's
tree: min over landmarks of d(E(u), l) + d(l, E(v)) + 1 hyperedges.
Per-component coverage is what certifies the zero case — any walk of
length >= 2 lives inside one component, whose landmark then yields a
finite bound; length-1 walks (a shared edge of size >= s) are checked
directly.
"""
from __future__ import annotations

from collections import deque
from typing import TYPE_CHECKING, List

import numpy as np

from repro.core.baselines import line_graph_edges

if TYPE_CHECKING:                      # annotation-only; no runtime import
    from repro.core.hypergraph import Hypergraph

__all__ = ["DistanceOracle"]


class DistanceOracle:
    """BFS trees rooted at high-degree landmarks of the >= s line
    graph; ``distance(u, v)`` serves certified upper bounds on the
    s-distance (0 = provably no s-walk)."""

    def __init__(self, h: Hypergraph, s: int, *, extra_landmarks: int = 4):
        self.h = h
        self.s = int(s)
        if self.s < 1:
            raise ValueError(f"s-distance needs s >= 1; got {s}")
        m = h.m
        src, dst, od = line_graph_edges(h)
        keep = od >= self.s
        src, dst = src[keep], dst[keep]
        adj: List[List[int]] = [[] for _ in range(m)]
        for a, b in zip(src, dst):
            adj[int(a)].append(int(b))
            adj[int(b)].append(int(a))
        self._adj = adj
        deg = np.fromiter((len(a) for a in adj), np.int64, m)
        # components of the alive graph; one landmark each certifies
        # the zero case (module docstring)
        comp = np.full(m, -1, np.int64)
        n_comp = 0
        for e0 in range(m):
            if comp[e0] >= 0 or not adj[e0]:
                continue
            comp[e0] = n_comp
            queue = deque([e0])
            while queue:
                e = queue.popleft()
                for nb in adj[e]:
                    if comp[nb] < 0:
                        comp[nb] = n_comp
                        queue.append(nb)
            n_comp += 1
        landmarks: List[int] = []
        for c in range(n_comp):
            members = np.nonzero(comp == c)[0]
            best = members[np.lexsort((members, -deg[members]))[0]]
            landmarks.append(int(best))
        for e in np.lexsort((np.arange(m), -deg)):
            if len(landmarks) >= n_comp + int(extra_landmarks):
                break
            if deg[e] > 0 and int(e) not in landmarks:
                landmarks.append(int(e))
        self.landmarks = tuple(landmarks)
        self._dist = np.full((len(landmarks), m), -1, np.int32)
        for i, lm in enumerate(landmarks):
            d = self._dist[i]
            d[lm] = 0
            queue = deque([lm])
            while queue:
                e = queue.popleft()
                for nb in adj[e]:
                    if d[nb] < 0:
                        d[nb] = d[e] + 1
                        queue.append(nb)

    @property
    def num_landmarks(self) -> int:
        return len(self.landmarks)

    def nbytes(self) -> int:
        return int(self._dist.nbytes)

    def distance(self, u: int, v: int) -> int:
        """Certified upper bound on the s-distance in hyperedges
        (0 = no s-walk; nonzero bounds are lengths of actual walks)."""
        h, s = self.h, self.s
        u, v = int(u), int(v)
        eu = [int(e) for e in h.edges_of(u)]
        ev = [int(e) for e in h.edges_of(v)]
        ev_set = set(ev)
        sizes = h.edge_sizes
        if any(e in ev_set and int(sizes[e]) >= s for e in eu):
            return 1
        best = None
        if eu and ev:
            for i in range(len(self.landmarks)):
                d = self._dist[i]
                du_all = d[eu]
                dv_all = d[ev]
                du = du_all[du_all >= 0]
                dv = dv_all[dv_all >= 0]
                if du.size == 0 or dv.size == 0:
                    continue
                # cand == 1 only when both sides sit on the landmark
                # itself; landmarks have an alive neighbor, so od >= s
                # forces |lm| >= s and the shared-edge check above
                # already answered — every surviving cand is a real
                # multi-edge walk through lm
                cand = int(du.min()) + int(dv.min()) + 1
                if best is None or cand < best:
                    best = cand
        return 0 if best is None else best
