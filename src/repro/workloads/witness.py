"""Witness extraction: turn a yes/no MR answer into the hyperedge walk
that achieves it.

The reconstruction is hub-anchored meet-in-the-middle.  An HL-index
query answers MR(u, v) = k through a common hub label (e*, s_u), (e*,
s_v) with min(s_u, s_v) = k — the hub names a hyperedge some optimal
walk passes through, but the labels deliberately do not store the walk
itself (that is what keeps them |label|-sized).  ``extract_witness``
re-expands the two halves: a forward BFS from u's incident edges and a
backward BFS from v's, both restricted to the >= k line graph, meeting
at the hub when one is known (label backends) or wherever the frontiers
first touch (closure backends, where every hyperedge is a hub).  Any
path in the >= k line graph is by construction a walk with overlap
degree >= k, and k = MR is the maximum possible, so the checker's
equality test (``verify_witness``) is exact, not approximate.

Completeness: MR(u, v) = k means some valid walk exists.  A one-edge
walk is a shared incident edge of size k (checked first).  A longer
walk e_1..e_t has every edge forward- and backward-reachable, so either
some meeting edge yields a combined walk of length >= 2, or — when both
frontiers only meet at shared *seed* edges too small to stand alone —
the adjacent pair (e_{t-1}, e_t) is caught by the pair scan.
"""
from __future__ import annotations

from collections import deque
from typing import TYPE_CHECKING, Dict, Iterable, Optional, Tuple

if TYPE_CHECKING:                      # annotation-only; no runtime import
    from repro.core.hypergraph import Hypergraph

__all__ = ["extract_witness"]


def _bfs(h: Hypergraph, seeds: Iterable[int], k: int,
         ) -> Tuple[Dict[int, int], Dict[int, int]]:
    """Multi-source BFS over the >= k line graph.  Returns (parent,
    depth) maps; seeds have parent -1, depth 0.  Deterministic: seeds
    and neighbors are visited in sorted / stored order."""
    parent: Dict[int, int] = {}
    depth: Dict[int, int] = {}
    queue: deque = deque()
    for e in sorted(int(e) for e in seeds):
        if e not in parent:
            parent[e] = -1
            depth[e] = 0
            queue.append(e)
    while queue:
        e = queue.popleft()
        nbrs, ods = h.neighbors_od(e)
        for nb, od in zip(nbrs, ods):
            nb = int(nb)
            if int(od) >= k and nb not in parent:
                parent[nb] = e
                depth[nb] = depth[e] + 1
                queue.append(nb)
    return parent, depth


def _path_to_seed(parent: Dict[int, int], e: int) -> Tuple[int, ...]:
    """Walk ``e`` back to its seed: returns (seed, ..., e)."""
    out = [e]
    while parent[out[-1]] != -1:
        out.append(parent[out[-1]])
    return tuple(reversed(out))


def extract_witness(h: Hypergraph, u: int, v: int, k: int,
                    hub: Optional[int] = None) -> Tuple[int, ...]:
    """The hyperedge walk certifying MR(u, v) = k (see module
    docstring).  ``k`` must be the true MR — the caller computes it
    through whatever index it owns; a wrong k either fails the search
    (k too large) or yields a walk the checker rejects (k too small).
    Returns () for k <= 0."""
    if k <= 0:
        return ()
    eu = [int(e) for e in h.edges_of(int(u))]
    ev = [int(e) for e in h.edges_of(int(v))]
    ev_set = set(ev)
    sizes = h.edge_sizes
    # one-edge walk: WOD = |e|, and no walk can beat k = MR, so a shared
    # edge of size >= k has size exactly k and is itself optimal
    shared = sorted(e for e in eu if e in ev_set and int(sizes[e]) >= k)
    if shared:
        return (shared[0],)
    par_f, dep_f = _bfs(h, eu, k)
    par_b, dep_b = _bfs(h, ev, k)
    # meeting edges: combined walk fwd-half + bwd-half; a length-1
    # combination (both halves are the same seed edge) was ruled out by
    # the shared-edge check unless |e| < k, in which case it is invalid
    # and skipped here
    best = None                        # (total_hops, meet_edge)
    for e, df in dep_f.items():
        db = dep_b.get(e)
        if db is None or (df + db == 0 and int(sizes[e]) < k):
            continue
        cand = (df + db, e)
        if hub is not None and e == int(hub):
            best = cand                # prefer the label-named hub
            break
        if best is None or cand < best:
            best = cand
    if best is not None:
        e = best[1]
        fwd = _path_to_seed(par_f, e)
        bwd = _path_to_seed(par_b, e)
        return fwd + tuple(reversed(bwd))[1:]
    # frontiers only meet at undersized shared seeds: stitch an adjacent
    # pair (a, b) with a forward-reached, b backward-reached, od >= k
    pair = None                        # (total_hops, a, b)
    for a in sorted(par_f):
        nbrs, ods = h.neighbors_od(a)
        for nb, od in zip(nbrs, ods):
            nb = int(nb)
            if int(od) >= k and nb in par_b:
                cand = (dep_f[a] + dep_b[nb], a, nb)
                if pair is None or cand < pair:
                    pair = cand
    if pair is None:
        raise ValueError(
            f"no >= {k} walk joins {u} and {v}: k is not their MR")
    _, a, b = pair
    fwd = _path_to_seed(par_f, a)
    bwd = _path_to_seed(par_b, b)
    return fwd + tuple(reversed(bwd))
