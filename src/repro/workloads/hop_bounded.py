"""Hop-bounded s-reachability: is there an s-walk of at most k
hyperedges joining u and v (K-Reach's question, PAPERS.md, under the
paper's s-overlap walk semantics).

Two serving paths share one contract:

* ``bounded_s_distance`` — host BFS over the >= s line graph with an
  explicit hop budget; the generic engine path, and the k-bounded
  building block the landmark oracle's exactness tests lean on.
* ``FrontierEngine`` overrides ``s_reach_k`` with the jitted frontier
  sweep at ``rounds = k - 1`` (a walk of k hyperedges is k - 1
  line-graph steps) — the bounded *device* path.

Index-backed engines wrap either path in a pruning gate: an HL-index /
closure lookup answers unbounded s-reach in O(label) time, so ``mr(u,
v) < s`` rejects immediately (no bounded walk can exist where no walk
exists), and ``k >= m`` accepts immediately (a shortest walk never
repeats a hyperedge, so m edges always suffice).
"""
from __future__ import annotations

from collections import deque
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:                      # annotation-only; no runtime import
    from repro.core.hypergraph import Hypergraph

__all__ = ["bounded_s_distance", "hop_bounded_s_reach"]


def bounded_s_distance(h: Hypergraph, u: int, v: int, s: int,
                       max_hyperedges: Optional[int] = None) -> int:
    """Fewest hyperedges in an s-walk joining ``u`` and ``v`` (0 = none
    within the budget).  A one-edge walk needs a shared edge of size
    >= s; longer walks BFS the >= s line graph, where every edge on the
    walk has size >= s automatically (od <= min size)."""
    u, v, s = int(u), int(v), int(s)
    budget = h.m if max_hyperedges is None else int(max_hyperedges)
    if budget < 1:
        return 0
    eu = [int(e) for e in h.edges_of(u)]
    ev_set = {int(e) for e in h.edges_of(v)}
    sizes = h.edge_sizes
    if any(e in ev_set and int(sizes[e]) >= s for e in eu):
        return 1
    if budget < 2:
        return 0
    seen = set(eu)
    frontier = deque((e, 1) for e in eu)
    while frontier:
        e, d = frontier.popleft()
        if d >= budget:
            continue
        nbrs, ods = h.neighbors_od(e)
        for nb, od in zip(nbrs, ods):
            nb = int(nb)
            if int(od) < s or nb in seen:
                continue
            if nb in ev_set:
                return d + 1
            seen.add(nb)
            frontier.append((nb, d + 1))
    return 0


def hop_bounded_s_reach(h: Hypergraph, u: int, v: int, s: int,
                        k: int) -> bool:
    """``s_reach_k``: an s-walk of at most ``k`` hyperedges exists."""
    return bounded_s_distance(h, u, v, s, max_hyperedges=int(k)) > 0
