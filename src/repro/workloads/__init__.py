"""Query-workload subsystem: one HL-index, many workloads.

Five query families answered off the existing label/closure machinery
(engine methods gate them per backend; ``WorkloadUnsupported`` when a
backend can't serve one):

* witness extraction — ``engine.mr_witness(u, v)`` -> ``Witness``
* hop-bounded s-reach — ``engine.s_reach_k(u, v, s, k)`` -> bool
* set-to-set / multi-source MR — ``engine.mr_set(U, V)`` /
  ``engine.mr_from_set(U, targets)``
* top-k strongest-s ranking — ``engine.top_s(u, k)``
* landmark s-distance — ``engine.s_distance(u, v, s)`` (certified
  upper bounds; ``DistanceOracle`` is the standalone structure)

Brute-force references live in ``repro.core.baselines``; the
conformance matrix (tests/test_conformance.py) pins every backend x op
cell against them.
"""
from repro.core.engine import (WORKLOAD_OPS, WorkloadUnsupported,
                               workload_capabilities)

from .base import Witness, walk_wod, verify_witness
from .hop_bounded import bounded_s_distance, hop_bounded_s_reach
from .oracle import DistanceOracle
from .setops import cross_pairs, normalize_vertex_set
from .topk import select_top_s
from .witness import extract_witness

__all__ = [
    "WORKLOAD_OPS", "Witness", "WorkloadUnsupported",
    "workload_capabilities", "walk_wod", "verify_witness",
    "extract_witness", "bounded_s_distance", "hop_bounded_s_reach",
    "DistanceOracle", "cross_pairs", "normalize_vertex_set",
    "select_top_s",
]
