"""Shared workload types: the op registry, the ``Witness`` answer
shape, and the s-walk validity checker.

The workload subsystem answers five query families on top of the
engine protocol (see docs/ARCHITECTURE.md "Workloads"):

  witness     MR answers that return the actual hyperedge walk
  s_reach_k   hop-bounded s-reachability (at most k hyperedges)
  mr_set      set-to-set / multi-source MR reductions
  top_s       top-k strongest-s ranking per source vertex
  s_distance  landmark s-distance oracle (certified upper bounds)

Every op is gated per backend through ``workload_capability`` on the
engine class; asking an incapable backend raises
``WorkloadUnsupported`` — loud and typed, never a silent fallback.
The gate, the op tuple (``WORKLOAD_OPS``) and the exception live with
the registry in ``repro.core.engine`` (re-exported from
``repro.workloads``); this module holds the graph-level answer shapes
the engine layer lazily imports, keeping the dependency one-way.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Tuple

if TYPE_CHECKING:                      # annotation-only; no runtime import
    from repro.core.hypergraph import Hypergraph

__all__ = ["Witness", "walk_wod", "verify_witness"]


@dataclasses.dataclass(frozen=True)
class Witness:
    """An MR answer plus its certificate: the hyperedge walk achieving
    it.  ``s == MR(u, v)``; ``walk`` is a sequence of hyperedge ids with
    ``u`` in the first edge, ``v`` in the last, and walk-overlap-degree
    exactly ``s`` (empty iff ``s == 0``).  ``verify_witness`` checks all
    of that from the hypergraph alone."""

    u: int
    v: int
    s: int
    walk: Tuple[int, ...]


def walk_wod(h: Hypergraph, walk) -> int:
    """Walk overlap degree: min consecutive hyperedge overlap, or the
    hyperedge size for a single-edge walk (Sec. II — a one-edge walk
    joins every pair inside that edge at s = |e|).  0 for an empty
    walk."""
    walk = [int(e) for e in walk]
    if not walk:
        return 0
    for e in walk:
        if not 0 <= e < h.m:
            raise IndexError(f"hyperedge id {e} out of range [0, {h.m})")
    if len(walk) == 1:
        return int(h.edge_size(walk[0]))
    return min(h.overlap(a, b) for a, b in zip(walk, walk[1:]))


def verify_witness(h: Hypergraph, w: Witness) -> bool:
    """True iff ``w`` is internally consistent: an unreachable answer
    carries no walk, and a reachable one carries a valid s-walk from
    ``u`` to ``v`` whose overlap degree equals the reported ``s``."""
    if w.s < 0:
        return False
    if w.s == 0:
        return len(w.walk) == 0
    if not w.walk:
        return False
    first, last = int(w.walk[0]), int(w.walk[-1])
    if int(w.u) not in map(int, h.edge(first)):
        return False
    if int(w.v) not in map(int, h.edge(last)):
        return False
    return walk_wod(h, w.walk) == int(w.s)
