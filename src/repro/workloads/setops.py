"""Set-to-set and multi-source MR: batched reductions over label rows.

``mr_set(U, V) = max over (u, v) in U x V of MR(u, v)`` — "can any
seed reach any target, and how strongly".  The engine path materializes
the |U| x |V| cross-product as one query batch and routes it through
``mr_batch``, i.e. through the same vectorized ``DeviceSnapshot`` label
join every other batch takes — and therefore through the Pallas
``KernelSnapshot`` bucket geometry when the engine serves kernels.  The
reduction (max, or per-target max for multi-source) happens on the
result row; no new device code is needed, which is the point: one label
layout, many workloads.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["normalize_vertex_set", "cross_pairs"]


def normalize_vertex_set(vs, n: int, name: str = "vertex set",
                         ) -> np.ndarray:
    """Validate and canonicalize one side of a set query: non-empty,
    integer dtype, ids in [0, n), duplicates dropped (a set), sorted."""
    arr = np.asarray(vs)
    if arr.size == 0:
        raise ValueError(f"{name} must be non-empty")
    if arr.ndim != 1:
        raise ValueError(f"{name} must be 1-D; got shape {arr.shape}")
    if not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"{name} must have an integer dtype; got {arr.dtype}")
    arr = arr.astype(np.int64)
    if arr.size and (arr.min() < 0 or arr.max() >= n):
        bad = int(arr.min()) if arr.min() < 0 else int(arr.max())
        raise IndexError(f"{name} id {bad} out of range [0, {n})")
    return np.unique(arr)


def cross_pairs(us: np.ndarray, vs: np.ndarray,
                ) -> Tuple[np.ndarray, np.ndarray]:
    """The |U| x |V| query batch, row-major (us varies slowest) — the
    caller reshapes the answer row to [|U|, |V|] for reductions."""
    return (np.repeat(us, len(vs)), np.tile(vs, len(us)))
