"""Top-k strongest-s ranking: the k vertices with the largest
MR(u, .) from one label-row sweep.

The engine path batches ``u`` against every vertex — one row of the
vectorized label join (``mr_batch(full(n, u), arange(n))``), the same
sweep shape serving uses — and this module does the selection:
unreachable vertices (MR 0) and ``u`` itself are dropped, survivors are
ranked by (MR descending, vertex id ascending) so the answer is
deterministic across backends, and the top k are returned.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["select_top_s"]


def select_top_s(mr_row: np.ndarray, u: int, k: int,
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """(vertices [<=k], mr values [<=k]) from a full MR(u, .) row."""
    row = np.asarray(mr_row, np.int64)
    verts = np.arange(row.size, dtype=np.int64)
    keep = (row > 0) & (verts != int(u))
    verts, vals = verts[keep], row[keep]
    order = np.lexsort((verts, -vals))[:int(k)]
    return verts[order], vals[order]
