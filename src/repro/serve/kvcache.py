"""Serving cache utilities: prefill + decode drivers."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any

__all__ = ["prefill_with_decode", "greedy_decode"]


def prefill_with_decode(model, params: Params, cache: Params,
                        tokens: jax.Array) -> Tuple[jax.Array, Params]:
    """Fill the cache by running decode_step over the prompt with a scan.
    Works for every family (uniform fallback; attention archs can instead
    run the full-sequence path and scatter K/V, see serve_step.prefill)."""
    def step(carry, t):
        cache, pos = carry
        logits, cache = model.decode_step(params, cache, t[:, None], pos)
        return (cache, pos + 1), logits[:, 0]

    (cache, _), logits = jax.lax.scan(step, (cache, jnp.int32(0)), tokens.T)
    return logits[-1], cache


def greedy_decode(model, params: Params, cache: Params, last_logits,
                  start_pos: int, steps: int) -> Tuple[jax.Array, Params]:
    """Greedy continuation for ``steps`` tokens."""
    def step(carry, _):
        cache, logits, pos = carry
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits2, cache = model.decode_step(params, cache, tok[:, None], pos)
        return (cache, logits2[:, 0], pos + 1), tok

    (cache, _, _), toks = jax.lax.scan(
        step, (cache, last_logits, jnp.int32(start_pos)), None, length=steps)
    return toks.T, cache
