"""Read-replica snapshot serving: ``ReplicaGroup``.

One writer, N readers — the serving regime reachability indexes live in
(Hyper-distance Oracles' landmark serving model, PAPERS.md): queries
vastly outnumber updates, so the way to scale query throughput is to
hold several device-resident copies of one snapshot and spread batches
across them, while updates stay serialized on the single writer engine.

``ReplicaGroup`` is a ``ReachabilityService`` whose resident-snapshot
slot is replaced by a set of version-keyed, mesh-resident replicas:

* **Single writer** — ``update()`` applies edits on the one underlying
  engine (the group owns it; nothing else should call
  ``engine.snapshot()`` behind its back, or the dirty-row delta
  degrades to a full re-land — the identity guard in
  ``snapshot_delta`` makes that safe, just slower).
* **Dirty-row fan-out** — at the next micro-batch after an update, the
  group captures ``engine.snapshot_delta(basis)`` *once* and re-lands
  only those rows into every replica through the existing
  ``to_mesh(base=, dirty_rows=, donate_base=True)`` contract: N
  replicas cost N row-scatters of the touched rows, not N full label
  transfers.  All replicas therefore hold byte-identical label tensors
  at every version — the churn test asserts exactly that.  Every
  scoped-update backend reports true dirty rows — including ``sharded``
  in both regimes since its maintenance went scoped — so full re-lands
  happen only at first landing or after a genuine whole-index rebuild;
  a zero-row delta (version bump with no content change) re-keys the
  resident copies without touching the devices.
* **Round-robin serving** — each micro-batch is answered off the next
  replica in rotation (per-replica batch counters make the spread
  observable).  The version-keyed swap discipline is unchanged: all
  replicas are brought current *between* batches, never mid-batch.

On a multi-device host the replicas are sharded over the mesh the group
was given (default: ``default_line_graph_mesh()``), so "N replicas" are
N distinct device-resident copies, not N aliases of one buffer.

Snapshot-less backends (``online``, ``frontier``) cannot replicate — a
replica *is* a snapshot copy — so the group raises
``SnapshotUnsupported`` at construction instead of silently degrading
to single-copy serving.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.engine import SnapshotUnsupported
from repro.core.query import KernelSnapshot
from repro.serve.reach_service import ReachabilityService, ServiceConfig

__all__ = ["Replica", "ReplicaGroup"]


@dataclasses.dataclass
class Replica:
    """One device-resident snapshot copy plus its serving counters."""

    index: int
    snap: object = None                  # mesh-resident DeviceSnapshot
    kernel_view: Optional[KernelSnapshot] = None
    batches: int = 0                     # micro-batches served off this copy
    rows_patched: int = 0                # rows re-landed via dirty-row fan-out
    full_relands: int = 0                # whole-label transfers (incl. first)


class ReplicaGroup(ReachabilityService):
    """A ``ReachabilityService`` serving off N read replicas of one
    snapshot (see module docstring).  Built by ``repro.api.serve`` when
    ``ServiceConfig(replicas=N)`` with N > 1, or directly:

        group = ReplicaGroup(engine, 4, mesh=mesh, start=False)
        group.submit_many(reqs); group.drain()
        group.update(inserts=[[1, 2, 3]])   # writer; dirty rows fan out
    """

    _replica_aware = True

    def __init__(self, engine, n_replicas: Optional[int] = None, *,
                 config: Optional[ServiceConfig] = None, mesh=None,
                 start: bool = True, **overrides):
        cfg = config if config is not None else ServiceConfig()
        if n_replicas is not None:
            cfg = dataclasses.replace(cfg, replicas=int(n_replicas))
        try:
            engine.snapshot()
        except SnapshotUnsupported as exc:
            raise SnapshotUnsupported(
                f"replica serving holds device-resident snapshot copies, "
                f"which backend {getattr(engine, 'name', '?')!r} cannot "
                f"derive ({exc}); serve it through a plain "
                f"ReachabilityService instead") from None
        if mesh is None:
            # replicas should be device-resident copies even when the
            # caller didn't think about placement
            from repro.core.distributed import default_line_graph_mesh
            mesh = default_line_graph_mesh()
        super().__init__(engine, config=cfg, mesh=mesh, start=False,
                         **overrides)
        self.replicas: List[Replica] = [Replica(i)
                                        for i in range(cfg.replicas)]
        self._rr = 0                 # next replica in rotation
        if start:
            self.start()

    # -- replica snapshot lifecycle ----------------------------------------

    def _refresh_snapshot(self):
        """Bring every replica to the engine's version (dirty-row
        fan-out), then hand the next replica in rotation to the batch.
        Runs under ``_dispatch_lock`` like the base method."""
        eng = self.engine
        if self._host_snap is None or self._host_snap.version != eng.version:
            self._sync_replicas()
        replica = self.replicas[self._rr]
        self._rr = (self._rr + 1) % len(self.replicas)
        replica.batches += 1
        if not self.use_kernels:
            return replica.snap
        kv = replica.kernel_view
        if kv is None or kv.base is not replica.snap:
            kv = KernelSnapshot(replica.snap, min_bucket=self.min_bucket)
            replica.kernel_view = kv
        return kv

    def _sync_replicas(self) -> None:
        eng = self.engine
        # captured ONCE; the same delta then lands on every replica —
        # this is the point of the snapshot_delta hook
        host, dirty = eng.snapshot_delta(self._host_snap)
        if host is self._host_snap and all(r.snap is not None
                                           for r in self.replicas):
            return
        self._snapshot_ok = True
        self._stats.snapshot_refreshes += 1
        self._stats.rows_rederived += int(eng.last_snapshot_refresh_rows)
        self._stats.rows_full += int(eng.h.n)
        n_dirty = 0 if dirty is None else int(np.asarray(dirty).size)
        for replica in self.replicas:
            if (replica.snap is not None and dirty is not None
                    and n_dirty == 0
                    and tuple(replica.snap.ranks.shape)
                    == tuple(host.ranks.shape)):
                # zero-row delta (e.g. an empty update batch): the copy
                # is already byte-identical — re-key it to the new
                # version without touching the devices at all
                replica.snap = dataclasses.replace(replica.snap,
                                                   version=host.version)
                replica.kernel_view = None
                continue
            base = replica.snap if (replica.snap is not None
                                    and dirty is not None) else None
            snap = host.to_mesh(self.mesh, self.axes, base=base,
                                dirty_rows=dirty if base is not None
                                else None, donate_base=True)
            if base is not None and snap.ranks.shape == base.ranks.shape:
                replica.rows_patched += n_dirty
                self._stats.mesh_rows_patched += n_dirty
            else:
                replica.full_relands += 1
            replica.snap = snap
            replica.kernel_view = None
        self._host_snap = host

    def replica_stats(self) -> List[Dict[str, int]]:
        """Per-replica serving counters (read under the dispatch lock)."""
        with self._dispatch_lock:
            return [{"replica": r.index, "batches": r.batches,
                     "rows_patched": r.rows_patched,
                     "full_relands": r.full_relands}
                    for r in self.replicas]
