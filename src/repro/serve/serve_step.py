"""Serve steps: the lowerable units for the decode/prefill dry-run cells.

``make_serve_step``: one-token decode against a KV cache of ``seq_len``
(the ``decode_*`` / ``long_*`` cells lower THIS, not train_step).
``make_prefill_step``: full-sequence forward returning last-token logits
(the ``prefill_*`` cells).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax

from repro.models.common import ArchConfig

Params = Any

__all__ = ["make_serve_step", "make_prefill_step"]


def make_serve_step(model, cfg: ArchConfig) -> Callable:
    def serve_step(params: Params, cache: Params, tokens: jax.Array,
                   pos: jax.Array) -> Tuple[jax.Array, Params]:
        logits, cache = model.decode_step(params, cache, tokens, pos)
        return logits, cache
    return serve_step


def make_prefill_step(model, cfg: ArchConfig) -> Callable:
    if cfg.family == "encdec":
        def prefill(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
            logits, _ = model.apply(params, batch["tokens"], batch["frames"])
            return logits[:, -1]
    elif cfg.family == "vlm":
        def prefill(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
            logits, _ = model.apply(params, batch["tokens"],
                                    batch["patch_embeds"])
            return logits[:, -1]
    else:
        def prefill(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
            logits, _ = model.apply(params, batch["tokens"])
            return logits[:, -1]
    return prefill
