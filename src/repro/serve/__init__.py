"""Serving substrate."""
from .serve_step import make_serve_step, make_prefill_step
from .kvcache import prefill_with_decode, greedy_decode

__all__ = ["make_serve_step", "make_prefill_step", "prefill_with_decode",
           "greedy_decode"]
