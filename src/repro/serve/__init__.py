"""Serving substrate.

Two independent serving stacks live here:

* ``reach_service`` — the request-based reachability serving layer
  (``ReachabilityService``: typed requests, futures, admission
  micro-batching, version-keyed snapshot reuse) over any
  ``ReachabilityEngine`` backend;
* ``serve_step`` / ``kvcache`` — the LM decode/prefill dry-run cells.

Exports resolve lazily so importing the reachability service (or
``repro.api``) never pulls the LM model stack into the process.
"""
from typing import TYPE_CHECKING

_LAZY = {
    "make_serve_step": "serve_step",
    "make_prefill_step": "serve_step",
    "prefill_with_decode": "kvcache",
    "greedy_decode": "kvcache",
    "ReachabilityService": "reach_service",
    "Request": "reach_service",
    "MRRequest": "reach_service",
    "SReachRequest": "reach_service",
    "WitnessRequest": "reach_service",
    "SReachKRequest": "reach_service",
    "MRSetRequest": "reach_service",
    "TopSRequest": "reach_service",
    "SDistanceRequest": "reach_service",
    "ServiceConfig": "reach_service",
    "ServiceStats": "reach_service",
    "REQUEST_TYPES": "reach_service",
    "PRIORITY_CLASSES": "scheduler",
    "TenantSpec": "scheduler",
    "DeadlineExceeded": "scheduler",
    "WeightedFairScheduler": "scheduler",
    "Replica": "replicas",
    "ReplicaGroup": "replicas",
}

__all__ = sorted(_LAZY)

if TYPE_CHECKING:  # pragma: no cover - static analysis only
    from .kvcache import greedy_decode, prefill_with_decode      # noqa: F401
    from .reach_service import (MRRequest, MRSetRequest,         # noqa: F401
                                ReachabilityService, Request, REQUEST_TYPES,
                                SDistanceRequest, ServiceConfig,
                                ServiceStats, SReachKRequest, SReachRequest,
                                TopSRequest, WitnessRequest)
    from .replicas import Replica, ReplicaGroup                  # noqa: F401
    from .scheduler import (DeadlineExceeded, PRIORITY_CLASSES,  # noqa: F401
                            TenantSpec, WeightedFairScheduler)
    from .serve_step import make_prefill_step, make_serve_step   # noqa: F401


def __getattr__(name: str):
    try:
        module = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib
    value = getattr(importlib.import_module(f"{__name__}.{module}"), name)
    globals()[name] = value
    return value
