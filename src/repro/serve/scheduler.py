"""Multi-tenant admission scheduling: priority bands + weighted fairness.

``ReachabilityService`` used to drain its queue FIFO, which is the wrong
policy the moment two consumers share one index: a tenant flooding
``submit_many`` pushes everyone else's requests behind its own backlog,
and a latency-sensitive probe waits behind thousands of batch-analytics
queries.  This module is the admission policy that replaces it:

* **Priority classes** (``PRIORITY_CLASSES``) are *strict* bands: a
  micro-batch takes every schedulable ``interactive`` request before the
  first ``standard`` one, and so on.  Priorities order work; they do not
  starve it — a band only yields to a higher band's actual backlog, and
  fairness below operates within each band.
* **Deficit-weighted round-robin across tenants** within a band
  (Shreedhar & Varghese DRR): each (band, tenant) queue accrues
  ``quantum * weight`` credits per scheduling pass and releases one
  request per credit.  Over any backlogged interval, tenant throughput
  converges to the weight ratio, so a greedy tenant's flood cannot
  delay a light tenant by more than one micro-batch — the bound the
  starvation tests assert.  Deficits reset when a tenant's queue
  empties (idle tenants bank no credit) and are capped at one batch, so
  a returning tenant cannot burst past its fair share.
* **Deadlines fail fast**: requests carry an optional ``deadline_ms``;
  an expired request is dropped at scheduling time and its future fails
  with ``DeadlineExceeded`` — it never occupies a bucket slot that a
  live request could use.

The scheduler is deliberately not thread-safe on its own: the service
already serializes admission under its condition variable, and keeping
locking out of this module makes the policy directly unit-testable.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import Deque, Dict, List, Optional, Tuple

__all__ = ["PRIORITY_CLASSES", "TenantSpec", "DeadlineExceeded",
           "WeightedFairScheduler"]

# priority class -> band index; lower band = served strictly first.  The
# table in docs/ARCHITECTURE.md documents exactly this mapping and CI
# fails if they drift (tools/check_docs.py check 8).
PRIORITY_CLASSES: Dict[str, int] = {
    "interactive": 0,
    "standard": 1,
    "batch": 2,
}


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Declared share of one tenant: requests tagged ``tenant=name``
    receive service proportional to ``weight`` (relative to the other
    tenants backlogged in the same priority band).  Tenants never named
    in a spec get ``ServiceConfig.default_weight``."""

    name: str
    weight: float = 1.0

    def __post_init__(self):
        if not isinstance(self.name, str) or not self.name:
            raise ValueError(
                f"tenant name must be a non-empty string; got {self.name!r}")
        w = float(self.weight)
        if not w > 0:
            raise ValueError(
                f"tenant {self.name!r} weight must be > 0; got {self.weight!r}")
        object.__setattr__(self, "weight", w)


class DeadlineExceeded(TimeoutError):
    """A request's ``deadline_ms`` elapsed before a micro-batch could
    take it.  Raised *through the future* (fail-fast at scheduling time)
    — the request never reaches the device."""

    def __init__(self, request, waited_ms: float):
        self.request = request
        self.waited_ms = float(waited_ms)
        super().__init__(
            f"{type(request).__name__} expired after waiting "
            f"{self.waited_ms:.2f} ms (deadline_ms="
            f"{request.deadline_ms!r})")


@dataclasses.dataclass
class _Entry:
    """One queued request: the future to resolve plus scheduling state
    (absolute expiry precomputed so ``take`` compares, not adds)."""

    request: object
    future: Future
    enqueued: float                     # time.monotonic() at submit
    expiry: Optional[float]             # absolute monotonic deadline


class WeightedFairScheduler:
    """Two-level admission queue: strict priority bands, deficit-weighted
    round-robin (DRR) across tenants within each band, FIFO within a
    (band, tenant) queue.

    ``take(limit, now)`` fills a micro-batch: it always returns as many
    schedulable requests as the limit allows (fairness shapes the batch
    *composition* under backlog, it never leaves bucket slots idle), plus
    the expired entries it swept aside, for the caller to fail.
    """

    def __init__(self, tenants: Tuple[TenantSpec, ...] = (), *,
                 default_weight: float = 1.0, quantum: int = 8):
        if not float(default_weight) > 0:
            raise ValueError(
                f"default_weight must be > 0; got {default_weight!r}")
        if int(quantum) < 1:
            raise ValueError(f"quantum must be >= 1; got {quantum!r}")
        self.quantum = int(quantum)
        self.default_weight = float(default_weight)
        self._weights: Dict[str, float] = {}
        for spec in tenants:
            if not isinstance(spec, TenantSpec):
                spec = TenantSpec(*spec) if isinstance(spec, tuple) \
                    else TenantSpec(**spec) if isinstance(spec, dict) \
                    else TenantSpec(str(spec))
            if spec.name in self._weights:
                raise ValueError(f"duplicate tenant spec {spec.name!r}")
            self._weights[spec.name] = spec.weight
        # band -> tenant -> FIFO queue; OrderedDict keeps the round-robin
        # order deterministic (insertion order of first pending request)
        self._bands: Dict[int, "OrderedDict[str, Deque[_Entry]]"] = {}
        self._deficit: Dict[Tuple[int, str], float] = {}
        self._size = 0

    def weight(self, tenant: str) -> float:
        return self._weights.get(tenant, self.default_weight)

    def __len__(self) -> int:
        return self._size

    def push(self, entry: _Entry) -> None:
        req = entry.request
        band = PRIORITY_CLASSES[req.priority]
        tenants = self._bands.setdefault(band, OrderedDict())
        queue = tenants.get(req.tenant)
        if queue is None:
            queue = tenants[req.tenant] = deque()
        queue.append(entry)
        self._size += 1

    def take(self, limit: int,
             now: float) -> Tuple[List[_Entry], List[_Entry]]:
        """Select up to ``limit`` entries for the next micro-batch.

        Returns ``(selected, expired)``: ``selected`` in dispatch order
        (strict bands, DRR within each), ``expired`` the entries whose
        deadline passed — swept out without consuming any deficit, so an
        expired flood costs its tenant nothing *and* frees no one else's
        share.  Each full DRR pass over a band's backlogged tenants
        accrues ``quantum * weight`` credit per tenant, so the loop
        always progresses (weights are validated > 0)."""
        selected: List[_Entry] = []
        expired: List[_Entry] = []
        if limit < 1:
            return selected, expired
        for band in sorted(self._bands):
            tenants = self._bands[band]
            while tenants and len(selected) < limit:
                for name in list(tenants):
                    queue = tenants[name]
                    key = (band, name)
                    # cap at one batch: an idle-then-bursting tenant can
                    # claim at most a full micro-batch of banked credit
                    deficit = min(
                        self._deficit.get(key, 0.0)
                        + self.quantum * self.weight(name),
                        float(limit))
                    while queue and len(selected) < limit:
                        head = queue[0]
                        if head.expiry is not None and now >= head.expiry:
                            expired.append(queue.popleft())
                            self._size -= 1
                            continue
                        if deficit < 1.0:
                            break
                        deficit -= 1.0
                        selected.append(queue.popleft())
                        self._size -= 1
                    if queue:
                        self._deficit[key] = deficit
                    else:
                        # DRR: an emptied queue forfeits residual credit
                        del tenants[name]
                        self._deficit.pop(key, None)
                    if len(selected) >= limit:
                        break
            if len(selected) >= limit:
                break
        # drop emptied bands so sorted() stays O(#active bands)
        for band in [b for b, t in self._bands.items() if not t]:
            del self._bands[band]
        return selected, expired

    def backlog(self) -> Dict[str, int]:
        """Pending request count per tenant (diagnostics/tests)."""
        counts: Dict[str, int] = {}
        for tenants in self._bands.values():
            for name, queue in tenants.items():
                counts[name] = counts.get(name, 0) + len(queue)
        return counts
