"""Request-based reachability serving: ``ReachabilityService``.

The engine API (``repro.core.engine``) is imperative — callers invoke
``eng.mr_batch`` with batches they assembled themselves, and after an
``update`` they must notice staleness and re-derive snapshots by hand.
This module turns that surface into a *service*: callers submit typed
requests and get futures; an admission loop coalesces whatever is
pending into fused padded device batches and scatters the answers back.

    svc = repro.api.serve(h, config=ServiceConfig(max_batch=1024))
    f1 = svc.mr(4, 8)                           # Future[int]
    f2 = svc.submit(SReachRequest(4, 8, s=2))   # Future[bool]
    f1.result(), f2.result()
    svc.update(inserts=[[3, 7, 9]])             # serving continues
    svc.close()

Design (the mechanisms the module exists for):

* **Admission micro-batching** — pending requests are grouped by query
  kind (``MRRequest`` vs ``SReachRequest``) and each group is padded to
  a power-of-two bucket size (``min_bucket`` .. ``max_batch``) before
  dispatch.  The fused ``batched_mr`` join recompiles per batch *shape*,
  so bucketing bounds the number of distinct XLA programs to
  ``log2(max_batch / min_bucket) + 1`` per kind instead of one per
  distinct queue depth.  Padding slots repeat a real query pair, which
  is semantically inert (answers past the true count are dropped before
  scatter).  Mixed ``s`` values coalesce into one fused batch: on the
  snapshot path every s-reach answer is ``mr >= s`` off the same join.
* **Multi-tenant admission** — every request carries ``tenant`` /
  ``priority`` / ``deadline_ms`` metadata (defaults reproduce the old
  single-tenant behavior exactly).  The queue is a
  ``WeightedFairScheduler``: strict priority bands, deficit-weighted
  round-robin across tenants within a band, deadline-expired requests
  failed fast with ``DeadlineExceeded``.  A flooding tenant shapes only
  its own share of each micro-batch, never anyone else's wait.
* **Streaming delivery** — ``submit_stream()`` yields ``(request,
  future)`` pairs in *completion* order as micro-batches resolve them,
  and ``submit(..., on_result=fn)`` invokes a callback the moment one
  request's answer lands — both are thin layers over the same futures.
* **Version-keyed snapshot reuse** — the service serves every batch off
  one resident ``DeviceSnapshot`` keyed by ``engine.version``.  After
  ``update()`` the swap happens *between* micro-batches (never mid
  batch): the admission loop notices ``snap.version != engine.version``
  and asks the engine for ``snapshot_delta()`` — a fresh snapshot plus
  the dirty-row delta scoped maintenance reported — and installs it
  with a single atomic reference swap.
* **Mesh-resident serving** — pass ``mesh=`` and the resident snapshot
  lives sharded over the device mesh (``DeviceSnapshot.to_mesh``).
  After a scoped update, only the dirty rows are re-landed into the
  mesh-resident copy (``to_mesh(base=..., dirty_rows=...)``) instead of
  re-transferring the whole label mass.  ``repro.serve.replicas``
  builds read-replica fan-out on the same contract.

Backends with no snapshot form (``online``, ``frontier``, ...) are
served through their own ``mr_batch`` / ``s_reach_batch`` engines by the
same admission loop — the service degrades, never refuses.

Workload request kinds (``witness`` / ``s_reach_k`` / ``mr_set`` /
``top_s`` / ``s_distance``, see ``repro.workloads``) ride the same
admission pipeline: typed frozen requests, the same tenant/priority/
deadline metadata, and their own per-kind dispatch groups — so workload
traffic never perturbs the padded mr/s_reach bucket shapes.  Kinds a
backend cannot serve are refused at *admission* with
``WorkloadUnsupported`` (checked against ``engine.workload_capability``)
rather than failing futures later.

The request-type, priority-class, and request-field tables in
docs/ARCHITECTURE.md are CI-checked against ``REQUEST_TYPES``,
``PRIORITY_CLASSES``, and the ``Request`` base dataclass
(tools/check_docs.py).
"""
from __future__ import annotations

import dataclasses
import operator
import queue as queue_mod
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import (Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)

import numpy as np

from repro.core.engine import SnapshotUnsupported, WorkloadUnsupported
from repro.core.query import KernelSnapshot
from repro.serve.scheduler import (PRIORITY_CLASSES, DeadlineExceeded,
                                   TenantSpec, WeightedFairScheduler, _Entry)

__all__ = ["Request", "MRRequest", "SReachRequest", "WitnessRequest",
           "SReachKRequest", "MRSetRequest", "TopSRequest",
           "SDistanceRequest", "ReachabilityService",
           "ServiceConfig", "ServiceStats", "REQUEST_TYPES",
           "PRIORITY_CLASSES", "TenantSpec", "DeadlineExceeded"]


@dataclasses.dataclass(frozen=True)
class Request:
    """Frozen base every service request derives from.  Carries the
    multi-tenant scheduling metadata; all three fields are keyword-only
    with defaults that reproduce the pre-multi-tenant behavior exactly
    (one implicit tenant, one band, no deadline) — ``MRRequest(4, 8)``
    means what it always meant.

    The field table in docs/ARCHITECTURE.md documents exactly these
    fields and CI fails if they drift (tools/check_docs.py check 8).
    """

    tenant: str = dataclasses.field(default="default", kw_only=True)
    priority: str = dataclasses.field(default="standard", kw_only=True)
    deadline_ms: Optional[float] = dataclasses.field(default=None,
                                                     kw_only=True)


@dataclasses.dataclass(frozen=True)
class MRRequest(Request):
    """Problem 2: answer ``MR(u, v)`` — resolves to ``int``."""

    u: int
    v: int

    kind = "mr"


@dataclasses.dataclass(frozen=True)
class SReachRequest(Request):
    """Problem 1: is there an s-walk joining ``u`` and ``v`` — resolves
    to ``bool``.  Requests with different ``s`` coalesce into the same
    fused batch (the snapshot path answers all of them off one join)."""

    u: int
    v: int
    s: int

    kind = "s_reach"


@dataclasses.dataclass(frozen=True)
class WitnessRequest(Request):
    """Workload: MR with proof — resolves to a ``repro.workloads.Witness``
    whose hyperedge walk realizes ``MR(u, v)`` (empty walk when 0)."""

    u: int
    v: int

    kind = "witness"


@dataclasses.dataclass(frozen=True)
class SReachKRequest(Request):
    """Workload: hop-bounded s-reach — is there an s-walk of at most
    ``k`` hyperedges joining ``u`` and ``v``; resolves to ``bool``."""

    u: int
    v: int
    s: int
    k: int

    kind = "s_reach_k"


@dataclasses.dataclass(frozen=True)
class MRSetRequest(Request):
    """Workload: set-to-set MR — ``max`` of ``MR(u, v)`` over
    ``us x vs``; resolves to ``int``.  Vertex sets are stored as tuples
    so the request stays frozen/hashable."""

    us: Tuple[int, ...]
    vs: Tuple[int, ...]

    kind = "mr_set"

    def __post_init__(self):
        object.__setattr__(self, "us", tuple(self.us))
        object.__setattr__(self, "vs", tuple(self.vs))


@dataclasses.dataclass(frozen=True)
class TopSRequest(Request):
    """Workload: top-k strongest-s ranking — resolves to a tuple of
    ``(vertex, mr)`` pairs sorted by descending ``mr`` (ties by vertex
    id), zeros and ``u`` itself excluded."""

    u: int
    k: int

    kind = "top_s"


@dataclasses.dataclass(frozen=True)
class SDistanceRequest(Request):
    """Workload: landmark s-distance — resolves to an ``int`` certified
    upper bound on the number of hyperedges an s-walk from ``u`` to
    ``v`` needs (0 = provably no s-walk)."""

    u: int
    v: int
    s: int

    kind = "s_distance"


# kind -> request class; the serving section of docs/ARCHITECTURE.md
# documents exactly this table and CI fails if they drift apart
REQUEST_TYPES: Dict[str, type] = {MRRequest.kind: MRRequest,
                                  SReachRequest.kind: SReachRequest,
                                  WitnessRequest.kind: WitnessRequest,
                                  SReachKRequest.kind: SReachKRequest,
                                  MRSetRequest.kind: MRSetRequest,
                                  TopSRequest.kind: TopSRequest,
                                  SDistanceRequest.kind: SDistanceRequest}

# workload kinds gate on engine.workload_capability at submit; "mr" and
# "s_reach" (the padded-bucket kinds) every backend serves
_KIND_TO_OP: Dict[str, str] = {"witness": "witness",
                               "s_reach_k": "s_reach_k",
                               "mr_set": "mr_set",
                               "top_s": "top_s",
                               "s_distance": "s_distance"}


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Typed service configuration — the one documented way to set
    serving knobs (``repro.api.serve(h, config=ServiceConfig(...))``).

    Batching: ``max_batch`` (admission cap / largest bucket),
    ``min_bucket`` (smallest padded shape), ``max_wait_ms`` (coalescing
    linger; 0 dispatches immediately).

    Placement: ``axes`` (mesh (row, column) axis names for ``to_mesh``),
    ``use_kernels`` (serve snapshot batches through the Pallas
    label-join ``KernelSnapshot``; ``None`` inherits the engine flag).

    Scheduling: ``tenants`` (``TenantSpec`` shares; unlisted tenants get
    ``default_weight``), ``quantum`` (DRR credits per pass — larger
    means coarser interleaving within a batch, same long-run shares).

    Fan-out: ``replicas`` — when > 1, ``repro.api.serve`` builds a
    ``ReplicaGroup`` of that many mesh-resident snapshot replicas
    instead of a single-snapshot service.
    """

    max_batch: int = 4096
    min_bucket: int = 8
    max_wait_ms: float = 0.5
    axes: Optional[Tuple[str, str]] = None
    use_kernels: Optional[bool] = None
    tenants: Tuple[TenantSpec, ...] = ()
    default_weight: float = 1.0
    quantum: int = 8
    replicas: int = 1

    def __post_init__(self):
        object.__setattr__(self, "max_batch", int(self.max_batch))
        object.__setattr__(self, "min_bucket", int(self.min_bucket))
        object.__setattr__(self, "max_wait_ms", float(self.max_wait_ms))
        object.__setattr__(self, "tenants", tuple(self.tenants))
        object.__setattr__(self, "quantum", int(self.quantum))
        object.__setattr__(self, "replicas", int(self.replicas))
        if (self.max_batch < 1 or self.min_bucket < 1
                or self.min_bucket > self.max_batch):
            raise ValueError(
                f"need 1 <= min_bucket <= max_batch; got min_bucket="
                f"{self.min_bucket} max_batch={self.max_batch}")
        for spec in self.tenants:
            if not isinstance(spec, TenantSpec):
                raise TypeError(
                    f"ServiceConfig.tenants entries must be TenantSpec; "
                    f"got {spec!r}")
        if not float(self.default_weight) > 0:
            raise ValueError(
                f"default_weight must be > 0; got {self.default_weight!r}")
        if self.quantum < 1:
            raise ValueError(f"quantum must be >= 1; got {self.quantum}")
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1; got {self.replicas}")


@dataclasses.dataclass
class ServiceStats:
    """Counters the admission loop maintains (read via ``stats()``)."""

    submitted: int = 0
    answered: int = 0
    expired: int = 0                 # failed fast with DeadlineExceeded
    batches: int = 0
    padded_queries: int = 0          # bucket padding slots dispatched
    bucket_histogram: Dict[int, int] = dataclasses.field(default_factory=dict)
    tenant_submitted: Dict[str, int] = dataclasses.field(default_factory=dict)
    tenant_answered: Dict[str, int] = dataclasses.field(default_factory=dict)
    tenant_expired: Dict[str, int] = dataclasses.field(default_factory=dict)
    snapshot_refreshes: int = 0
    rows_rederived: int = 0          # label rows re-derived across refreshes
    rows_full: int = 0               # rows a from-scratch refresh would cost
    mesh_rows_patched: int = 0       # rows re-landed into a mesh-resident copy
    kernel_batches: int = 0          # batches answered by the Pallas join
    workload_answered: Dict[str, int] = dataclasses.field(
        default_factory=dict)        # per-kind workload answers served
    updates: int = 0

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        for key in ("bucket_histogram", "tenant_submitted",
                    "tenant_answered", "tenant_expired",
                    "workload_answered"):
            d[key] = dict(sorted(d[key].items()))
        return d


def _resolve(fut: Future, value) -> None:
    """Resolve one future, tolerating a caller's concurrent ``cancel()``
    (a bare ``cancelled()`` pre-check races: the cancel can land between
    the check and ``set_result``, and the resulting InvalidStateError
    would poison the whole micro-batch through the dispatch error
    handler)."""
    try:
        fut.set_result(value)
    except InvalidStateError:
        pass                         # cancelled mid-dispatch: drop quietly


def _bucket_size(q: int, min_bucket: int, max_batch: int) -> int:
    """Smallest power-of-two >= q, clamped to [min_bucket, max_batch]."""
    b = 1 << max(q - 1, 0).bit_length()
    return max(min(max(b, min_bucket), max_batch), q)


class ReachabilityService:
    """Request-based serving over any ``ReachabilityEngine``.

    Args:
      engine: a built engine (``repro.api.build_engine``) — the service
        owns its snapshot lifecycle from here on.
      config: a ``ServiceConfig``; the typed home of every serving knob
        (batching, scheduling, placement).  Defaults to
        ``ServiceConfig()``.
      mesh: optional ``jax.sharding.Mesh``; the resident snapshot is
        kept mesh-sharded (``to_mesh``) and refreshed row-wise after
        scoped updates.  Ignored for backends with no snapshot form.
      start: start the background admission thread.  With
        ``start=False`` the service is synchronous: call ``drain()`` to
        process everything pending (deterministic; what the tests and
        benchmarks use).
      axes / max_batch / min_bucket / max_wait_ms / use_kernels: direct
        overrides of the matching ``config`` field (convenience for
        call sites tuning one knob; ``None`` = take the config value).

    ``use_kernels=None`` inherits the engine's own ``use_kernels`` flag,
    so ``serve(h, backend, config=ServiceConfig(use_kernels=True))``
    flips both build and serving.  The kernel view shares this service's
    admission buckets (``min_bucket``), so traffic compiles one kernel
    program per bucket shape.
    """

    # ReplicaGroup flips this; a plain service refuses a replicated
    # config rather than silently serving one copy
    _replica_aware = False

    def __init__(self, engine, *, config: Optional[ServiceConfig] = None,
                 mesh=None, axes: Optional[Tuple[str, str]] = None,
                 max_batch: Optional[int] = None,
                 min_bucket: Optional[int] = None,
                 max_wait_ms: Optional[float] = None,
                 use_kernels: Optional[bool] = None, start: bool = True):
        cfg = config if config is not None else ServiceConfig()
        overrides = {k: v for k, v in (("axes", axes),
                                       ("max_batch", max_batch),
                                       ("min_bucket", min_bucket),
                                       ("max_wait_ms", max_wait_ms),
                                       ("use_kernels", use_kernels))
                     if v is not None}
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        if cfg.replicas > 1 and not self._replica_aware:
            raise ValueError(
                f"ServiceConfig(replicas={cfg.replicas}) needs replica "
                f"fan-out — use repro.api.serve (which builds a "
                f"ReplicaGroup) or repro.serve.replicas.ReplicaGroup "
                f"directly")
        self.config = cfg
        self.engine = engine
        self.mesh = mesh
        self.axes = cfg.axes
        self.max_batch = cfg.max_batch
        self.min_bucket = cfg.min_bucket
        self.max_wait_s = cfg.max_wait_ms / 1e3
        self._stats = ServiceStats()
        self._queue = WeightedFairScheduler(
            cfg.tenants, default_weight=cfg.default_weight,
            quantum=cfg.quantum)
        self._cv = threading.Condition()
        # serializes dispatch against update(): a micro-batch always runs
        # against one coherent (engine, snapshot) pair, and the snapshot
        # swap happens strictly between batches
        self._dispatch_lock = threading.Lock()
        self._snap = None            # resident serving snapshot (mesh or host)
        self._host_snap = None       # the engine-derived snapshot _snap mirrors
        self._snapshot_ok: Optional[bool] = None   # None = not probed yet
        self.use_kernels = (bool(getattr(engine, "use_kernels", False))
                            if cfg.use_kernels is None
                            else bool(cfg.use_kernels))
        self._kernel_snap: Optional[KernelSnapshot] = None
        self._running = False
        self._thread: Optional[threading.Thread] = None
        if start:
            self.start()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "ReachabilityService":
        with self._cv:
            if self._running:
                return self
            self._running = True
        self._thread = threading.Thread(target=self._loop,
                                        name="reach-service", daemon=True)
        self._thread.start()
        return self

    def close(self) -> None:
        """Stop the admission thread; everything already submitted is
        resolved first — answered, or failed with ``DeadlineExceeded``
        if its deadline passed (no future is left unresolved)."""
        with self._cv:
            self._running = False
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.drain()                 # no-thread mode: flush synchronously

    def __enter__(self) -> "ReachabilityService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- request admission -------------------------------------------------

    def submit(self, request: Request, *,
               on_result: Optional[Callable[[Request, Future], None]] = None,
               ) -> Future:
        """Enqueue one typed request; returns a ``Future`` resolving to
        the kind's answer type (``int`` for ``MRRequest`` /
        ``MRSetRequest`` / ``SDistanceRequest``, ``bool`` for
        ``SReachRequest`` / ``SReachKRequest``, a ``Witness`` for
        ``WitnessRequest``, a ``(vertex, mr)`` tuple for
        ``TopSRequest``) — or raising ``DeadlineExceeded`` if
        ``deadline_ms`` elapses first.  Workload kinds the backend
        cannot serve are refused at admission with
        ``WorkloadUnsupported`` (see ``engine.workload_capability``).

        ``on_result`` is the callback delivery hook: called as
        ``on_result(request, future)`` the moment this request's future
        resolves (from the dispatching thread), whatever the outcome.

        Validation is the same contract as ``validate_batch`` (integer
        ids in ``[0, n)``) on a scalar fast path — admission is the
        per-request hot loop, so it avoids array round-trips."""
        if not isinstance(request, tuple(REQUEST_TYPES.values())):
            raise TypeError(
                f"expected one of {sorted(REQUEST_TYPES)} requests, got "
                f"{type(request).__name__}")
        self._validate_fields(request)
        op = _KIND_TO_OP.get(request.kind)
        if op is not None and op not in getattr(
                self.engine, "workload_capability", frozenset()):
            raise WorkloadUnsupported(
                f"backend {getattr(self.engine, 'name', '?')!r} does not "
                f"serve the {op!r} workload")
        if not isinstance(request.tenant, str) or not request.tenant:
            raise ValueError(
                f"request tenant must be a non-empty string; got "
                f"{request.tenant!r}")
        if request.priority not in PRIORITY_CLASSES:
            raise ValueError(
                f"unknown priority class {request.priority!r}; available: "
                f"{sorted(PRIORITY_CLASSES)}")
        deadline_ms = None
        if request.deadline_ms is not None:
            deadline_ms = float(request.deadline_ms)
            if not deadline_ms > 0:
                raise ValueError(
                    f"deadline_ms must be > 0 (or None); got "
                    f"{request.deadline_ms!r}")
        fut: Future = Future()
        if on_result is not None:
            fut.add_done_callback(
                lambda f, _cb=on_result, _req=request: _cb(_req, f))
        now = time.monotonic()
        expiry = None if deadline_ms is None else now + deadline_ms / 1e3
        entry = _Entry(request, fut, now, expiry)
        with self._cv:
            self._queue.push(entry)
            self._stats.submitted += 1
            t = request.tenant
            self._stats.tenant_submitted[t] = \
                self._stats.tenant_submitted.get(t, 0) + 1
            self._cv.notify()
        return fut

    def _validate_fields(self, request: Request) -> None:
        """Per-kind query-field validation (the shared tenant/priority/
        deadline metadata checks stay in ``submit``).  Scalar fast path
        with the same contract as ``validate_batch``."""
        n = self.engine.h.n
        kind = request.kind

        def _vertex(x) -> int:
            try:
                i = operator.index(x)
            except TypeError:
                raise ValueError(
                    f"request vertex ids must have an integer dtype; got "
                    f"{x!r}") from None
            if not 0 <= i < n:
                raise IndexError(
                    f"request vertex id {i} out of range [0, {n})")
            return i

        def _count(x, name: str) -> int:
            try:
                i = operator.index(x)
            except TypeError:
                raise ValueError(
                    f"request {name} must have an integer dtype; got "
                    f"{x!r}") from None
            if i < 1:
                raise ValueError(f"request {name} must be >= 1; got {i}")
            return i

        if kind == "mr_set":
            for name, ids in (("us", request.us), ("vs", request.vs)):
                if not ids:
                    raise ValueError(
                        f"mr_set request field {name!r} must be a non-empty "
                        f"vertex set")
                for x in ids:
                    _vertex(x)
            return
        if kind == "top_s":
            _vertex(request.u)
            _count(request.k, "k")
            return
        # every remaining kind is a (u, v) pair query
        try:
            u = operator.index(request.u)
            v = operator.index(request.v)
        except TypeError:
            raise ValueError(
                f"request vertex ids must have an integer dtype; got "
                f"({request.u!r}, {request.v!r})") from None
        if not 0 <= u < n or not 0 <= v < n:
            bad = u if not 0 <= u < n else v
            raise IndexError(
                f"request vertex id {bad} out of range [0, {n})")
        if kind in ("s_reach", "s_reach_k", "s_distance"):
            try:
                s = operator.index(request.s)
            except TypeError:
                raise ValueError(
                    f"request s must have an integer dtype; got "
                    f"{request.s!r}") from None
            if s < 1:
                raise ValueError(f"s-reachability needs s >= 1; got {s}")
        if kind == "s_reach_k":
            _count(request.k, "k")

    def submit_many(self, requests: Sequence[Request]) -> List[Future]:
        return [self.submit(r) for r in requests]

    def submit_stream(self, requests: Iterable[Request],
                      ) -> Iterator[Tuple[Request, Future]]:
        """Submit ``requests`` and yield ``(request, resolved_future)``
        pairs in *completion* order, as micro-batches finish — the
        long-poll client surface: a consumer iterates and sees each
        answer the moment its batch lands, not when the whole stream is
        done.  Futures arrive resolved; a deadline-expired request
        yields with ``DeadlineExceeded`` set rather than being dropped.

        In synchronous mode (``start=False``) the pending queue is
        drained inline after submission, so iteration still completes
        without a background thread."""
        done: "queue_mod.Queue[Tuple[Request, Future]]" = queue_mod.Queue()
        pairs = [(r, self.submit(
            r, on_result=lambda req, fut, _q=done: _q.put((req, fut))))
            for r in requests]
        if not self._running:
            self.drain()
        for _ in range(len(pairs)):
            yield done.get()

    def mr(self, u: int, v: int) -> Future:
        return self.submit(MRRequest(int(u), int(v)))

    def s_reach(self, u: int, v: int, s: int) -> Future:
        return self.submit(SReachRequest(int(u), int(v), int(s)))

    def witness(self, u: int, v: int) -> Future:
        return self.submit(WitnessRequest(int(u), int(v)))

    def s_reach_k(self, u: int, v: int, s: int, k: int) -> Future:
        return self.submit(SReachKRequest(int(u), int(v), int(s), int(k)))

    def mr_set(self, us: Iterable[int], vs: Iterable[int]) -> Future:
        return self.submit(MRSetRequest(tuple(int(x) for x in us),
                                        tuple(int(x) for x in vs)))

    def top_s(self, u: int, k: int) -> Future:
        return self.submit(TopSRequest(int(u), int(k)))

    def s_distance(self, u: int, v: int, s: int) -> Future:
        return self.submit(SDistanceRequest(int(u), int(v), int(s)))

    def update(self, inserts=(), deletes=()) -> None:
        """Apply hyperedge edits through the engine.  Serving continues:
        the stale resident snapshot keeps answering until the admission
        loop swaps in the refreshed one before the next micro-batch."""
        with self._dispatch_lock:
            self.engine.update(inserts, deletes)
            self._stats.updates += 1

    # -- durability (repro.store) ------------------------------------------

    def checkpoint(self, store) -> int:
        """Durably checkpoint the engine into ``store`` (a
        ``repro.store.IndexStore``) and attach the store as the engine's
        WAL sink — every subsequent ``update`` then journals (fsync)
        before applying, so a crash at any point is recoverable via
        ``restore``.  Runs under the dispatch lock, never mid-batch.
        Returns the checkpointed engine version."""
        with self._dispatch_lock:
            store.checkpoint(self.engine)
            store.attach(self.engine)
            return int(self.engine.version)

    @classmethod
    def restore(cls, store_or_path, *, mesh=None,
                axes: Optional[Tuple[str, str]] = None, verify: bool = True,
                expect_backend: Optional[str] = None,
                **service_opts) -> "ReachabilityService":
        """Warm-restart serving from a ``repro.store`` artifact (an
        ``IndexStore`` instance, a store directory, or a single
        ``save_index`` file): the checkpoint loads mmap-backed — no
        construction — the WAL suffix replays, the store re-attaches as
        the WAL sink, and the service starts around the restored engine.
        The engine arrives at its persisted version, so the first
        micro-batch installs a resident snapshot keyed to exactly that
        version — the same version-keyed swap a live ``update`` takes."""
        from repro.store import IndexStore, restore_engine
        if isinstance(store_or_path, IndexStore):
            engine = store_or_path.restore(mesh=mesh, verify=verify,
                                           expect_backend=expect_backend)
        else:
            engine = restore_engine(store_or_path, mesh=mesh, verify=verify,
                                    expect_backend=expect_backend)
        return cls(engine, mesh=mesh, axes=axes, **service_opts)

    def stats(self) -> ServiceStats:
        with self._dispatch_lock:
            return dataclasses.replace(
                self._stats,
                bucket_histogram=dict(self._stats.bucket_histogram),
                tenant_submitted=dict(self._stats.tenant_submitted),
                tenant_answered=dict(self._stats.tenant_answered),
                tenant_expired=dict(self._stats.tenant_expired),
                workload_answered=dict(self._stats.workload_answered))

    def pending(self) -> int:
        with self._cv:
            return len(self._queue)

    def backlog(self) -> Dict[str, int]:
        """Pending request count per tenant."""
        with self._cv:
            return self._queue.backlog()

    # -- admission loop ----------------------------------------------------

    def _loop(self) -> None:
        while True:
            with self._cv:
                while self._running and not len(self._queue):
                    self._cv.wait(timeout=0.05)
                if not self._running and not len(self._queue):
                    return
                # linger for the full coalescing window (each submit()
                # notify wakes the wait, so loop until the deadline or a
                # full batch) — the latency/throughput admission knob
                deadline = time.monotonic() + self.max_wait_s
                while (self._running
                        and len(self._queue) < self.max_batch):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cv.wait(timeout=remaining)
                batch, expired = self._queue.take(self.max_batch,
                                                  time.monotonic())
            self._fail_expired(expired)
            if batch:
                self._dispatch(batch)

    def drain(self, max_batches: Optional[int] = None) -> int:
        """Synchronously dispatch pending requests in the caller's
        thread; returns the number of requests resolved (answered or
        deadline-failed).  This is the deterministic serving mode
        (``start=False``).  ``max_batches`` bounds the number of
        micro-batches taken — the fairness tests step one batch at a
        time to observe its composition."""
        total = 0
        batches = 0
        while max_batches is None or batches < max_batches:
            with self._cv:
                batch, expired = self._queue.take(self.max_batch,
                                                  time.monotonic())
            self._fail_expired(expired)
            if not batch and not expired:
                return total
            if batch:
                self._dispatch(batch)
                batches += 1
            total += len(batch) + len(expired)
        return total

    def _fail_expired(self, expired: List[_Entry]) -> None:
        if not expired:
            return
        now = time.monotonic()
        with self._dispatch_lock:
            self._stats.expired += len(expired)
            for entry in expired:
                t = entry.request.tenant
                self._stats.tenant_expired[t] = \
                    self._stats.tenant_expired.get(t, 0) + 1
        for entry in expired:
            waited_ms = (now - entry.enqueued) * 1e3
            try:
                entry.future.set_exception(
                    DeadlineExceeded(entry.request, waited_ms))
            except InvalidStateError:
                pass                 # cancelled while queued: drop quietly

    # -- dispatch ----------------------------------------------------------

    def _dispatch(self, batch: List[_Entry]) -> None:
        try:
            with self._dispatch_lock:
                snap = self._refresh_snapshot()
                groups: Dict[str, List[_Entry]] = {}
                for entry in batch:
                    groups.setdefault(entry.request.kind, []).append(entry)
                for kind, group in groups.items():
                    self._dispatch_group(kind, group, snap)
                self._stats.answered += len(batch)
                for entry in batch:
                    t = entry.request.tenant
                    self._stats.tenant_answered[t] = \
                        self._stats.tenant_answered.get(t, 0) + 1
        except Exception as exc:                       # noqa: BLE001
            for entry in batch:
                if not entry.future.done():
                    entry.future.set_exception(exc)

    def _dispatch_group(self, kind: str, group: List[_Entry], snap) -> None:
        if kind in _KIND_TO_OP:
            self._dispatch_workload_group(kind, group)
            return
        q = len(group)
        us = np.fromiter((e.request.u for e in group), np.int64, q)
        vs = np.fromiter((e.request.v for e in group), np.int64, q)
        bucket = _bucket_size(q, self.min_bucket, self.max_batch)
        if bucket > q:
            # pad with a repeat of the first (real, validated) pair —
            # inert: answers past q are dropped before the scatter
            us = np.concatenate([us, np.full(bucket - q, us[0])])
            vs = np.concatenate([vs, np.full(bucket - q, vs[0])])
        self._stats.batches += 1
        self._stats.padded_queries += bucket - q
        self._stats.bucket_histogram[bucket] = \
            self._stats.bucket_histogram.get(bucket, 0) + 1
        if isinstance(snap, KernelSnapshot):
            self._stats.kernel_batches += 1

        if kind == "mr":
            if snap is not None:
                mr = np.asarray(snap.mr(us, vs))[:q]
            else:
                mr = np.asarray(self.engine.mr_batch(us, vs))[:q]
            for entry, val in zip(group, mr):
                _resolve(entry.future, int(val))
            return

        svals = np.fromiter((e.request.s for e in group), np.int64, q)
        if snap is not None:
            # one fused join answers every s at once: s_reach == mr >= s
            ok = np.asarray(snap.mr(us, vs))[:q] >= svals
        elif svals.size and (svals == svals[0]).all():
            # uniform s: the backend's native (possibly cheaper) batch path
            ok = np.asarray(
                self.engine.s_reach_batch(us, vs, int(svals[0])))[:q]
        else:
            ok = np.asarray(self.engine.mr_batch(us, vs))[:q] >= svals
        for entry, val in zip(group, ok):
            _resolve(entry.future, bool(val))

    def _dispatch_workload_group(self, kind: str, group: List[_Entry]) -> None:
        """Workload kinds dispatch per-request through the engine's
        workload methods — witness reconstruction and the BFS-gated ops
        are host-side, while ``mr_set`` / ``top_s`` batch internally
        through ``mr_batch`` (which serves the kernel path when the
        engine enables it).  Each kind still arrives as its own group
        (bucket stream), so workload traffic never perturbs the padded
        mr/s_reach bucket shapes or their compiled-program count."""
        eng = self.engine
        self._stats.batches += 1
        self._stats.workload_answered[kind] = \
            self._stats.workload_answered.get(kind, 0) + len(group)
        for entry in group:
            r = entry.request
            if kind == "witness":
                val = eng.mr_witness(r.u, r.v)
            elif kind == "s_reach_k":
                val = bool(eng.s_reach_k(r.u, r.v, r.s, r.k))
            elif kind == "mr_set":
                val = int(eng.mr_set(np.asarray(r.us, np.int64),
                                     np.asarray(r.vs, np.int64)))
            elif kind == "top_s":
                verts, vals = eng.top_s(r.u, r.k)
                val = tuple(zip(verts.tolist(), vals.tolist()))
            else:                    # s_distance (admission pinned kinds)
                val = int(eng.s_distance(r.u, r.v, r.s))
            _resolve(entry.future, val)

    # -- snapshot lifecycle ------------------------------------------------

    def _refresh_snapshot(self):
        """The version-keyed snapshot swap, run between micro-batches
        (callers hold ``_dispatch_lock``).  Returns the resident serving
        snapshot, or None for snapshot-less backends."""
        eng = self.engine
        if self._snapshot_ok is False:
            return None
        if self._snap is not None and self._snap.version == eng.version:
            return self._serving_view()
        prev_host = self._host_snap
        try:
            # the fan-out hook: fresh snapshot + the row delta relative
            # to prev_host (None if the delta is unknowable and we must
            # re-land in full)
            host, dirty = eng.snapshot_delta(prev_host)
        except SnapshotUnsupported:
            self._snapshot_ok = False
            return None
        self._snapshot_ok = True
        if host is prev_host and self._snap is not None:
            return self._serving_view()
        self._stats.snapshot_refreshes += 1
        self._stats.rows_rederived += int(eng.last_snapshot_refresh_rows)
        self._stats.rows_full += int(eng.h.n)
        if self.mesh is not None and not self._already_on_mesh(host):
            base = self._snap if (prev_host is not None
                                  and dirty is not None) else None
            # base is private to the service and dropped at the swap, so
            # its buffers are safe to donate (in-place patch on device)
            snap = host.to_mesh(self.mesh, self.axes, base=base,
                                dirty_rows=dirty if base is not None
                                else None, donate_base=True)
            if base is not None and snap.ranks.shape == base.ranks.shape:
                self._stats.mesh_rows_patched += int(np.asarray(dirty).size)
        else:
            snap = host
        # single reference assignment = the atomic swap; in-flight code
        # never observes a half-updated snapshot
        self._host_snap, self._snap = host, snap
        return self._serving_view()

    def _serving_view(self):
        """The view micro-batches answer through: the resident snapshot,
        or — with ``use_kernels`` — a ``KernelSnapshot`` wrapper over it,
        rebuilt at every swap (so a re-landed or patched resident copy
        can never be served through a stale wrapper).  The wrapper
        shares this service's admission buckets, which is what bounds
        kernel-program count to one per bucket shape."""
        if not self.use_kernels or self._snap is None:
            return self._snap
        kv = self._kernel_snap
        if kv is None or kv.base is not self._snap:
            kv = KernelSnapshot(self._snap, min_bucket=self.min_bucket)
            self._kernel_snap = kv
        return kv

    def _already_on_mesh(self, snap) -> bool:
        """True when the engine's snapshot is already sharded over this
        service's mesh (the ``sharded`` backend derives mesh-resident
        snapshots) — re-landing it through ``to_mesh`` would gather the
        whole label mass to host and keep a duplicate device copy."""
        try:
            from jax.sharding import NamedSharding
            sharding = snap.ranks.sharding
        except Exception:                              # noqa: BLE001
            return False
        return (isinstance(sharding, NamedSharding)
                and sharding.mesh == self.mesh)
